//! Minimal JSON parser/serializer (substrate — no serde in this environment).
//!
//! Parses the `artifacts/manifest.json` written by `python/compile/aot.py`
//! and the framework's config files. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (not produced by our writers).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context. (Hand-rolled Display/Error —
/// `thiserror` is not among this workspace's dependencies.)
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Required-field helpers used by the manifest/config loaders.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` is not a string"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` is not a number"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` is not an integer"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` is not an array"))
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("invalid utf8 in number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy one UTF-8 scalar (may be multi-byte).
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Serialization
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience constructors used by config/metrics writers.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
    }

    #[test]
    fn parses_whitespace_and_unicode() {
        let v = Json::parse(" { \"k\" : [ 1 , 2 ] , \"u\" : \"héllo\" } ")
            .unwrap();
        assert_eq!(v.get("u").unwrap().as_str(), Some("héllo"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrips() {
        let cases = [
            r#"{"a":[1,2,3],"b":{"c":"d"},"e":null,"f":true}"#,
            r#"[1.5,-2,"x\ny"]"#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let s = v.to_string();
            assert_eq!(Json::parse(&s).unwrap(), v, "case {c}");
        }
    }

    #[test]
    fn accessor_errors_are_descriptive() {
        let v = Json::parse(r#"{"a":1}"#).unwrap();
        assert!(v.req("missing").is_err());
        assert!(v.req_str("a").is_err());
        assert_eq!(v.req_usize("a").unwrap(), 1);
    }

    #[test]
    fn large_integer_precision() {
        let v = Json::parse("3504872").unwrap();
        assert_eq!(v.as_u64(), Some(3504872));
    }
}
