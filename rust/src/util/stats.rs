//! Summary statistics over latency/throughput samples (substrate).

/// Online + batch summary of a set of f64 samples (milliseconds, usually).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { samples: Vec::new() }
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn extend(&mut self, vs: &[f64]) {
        self.samples.extend_from_slice(vs);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / (n - 1) as f64)
            .sqrt()
    }

    /// Coefficient of variation (std / mean); 0 for degenerate inputs.
    pub fn cv(&self) -> f64 {
        let m = self.mean();
        if m.abs() < 1e-12 {
            0.0
        } else {
            self.std() / m
        }
    }

    /// Linear-interpolated percentile, `q` in `[0, 100]`.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (q / 100.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = rank - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(vs: &[f64]) -> Summary {
        let mut s = Summary::new();
        s.extend(vs);
        s
    }

    #[test]
    fn empty_is_zeroes() {
        let s = Summary::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn mean_and_std() {
        let s = filled(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138).abs() < 0.01);
    }

    #[test]
    fn percentiles_interpolate() {
        let s = filled(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 4.0);
        assert!((s.p50() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let s = filled(&[9.0, 1.0, 5.0]);
        assert_eq!(s.p50(), 5.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn cv_degenerate() {
        assert_eq!(filled(&[0.0, 0.0]).cv(), 0.0);
        let s = filled(&[10.0, 10.0, 10.0]);
        assert_eq!(s.cv(), 0.0);
    }
}
