//! Tiny CLI argument parser (substrate — no clap in this environment).
//!
//! Supports `program <subcommand> --key value --flag` style invocations,
//! which is all the `amp4ec` launcher needs.

use std::collections::BTreeMap;

/// Parsed command line: a positional subcommand plus `--key value` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(name) = a.strip_prefix("--") {
                // `--key=value`, `--key value`, or bare `--flag`.
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got `{v}`")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("serve --nodes 3 --batch-size 8");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("nodes"), Some("3"));
        assert_eq!(a.get_usize("batch-size", 1).unwrap(), 8);
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse("bench --requests=100 --verbose");
        assert_eq!(a.get("requests"), Some("100"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn positional_args() {
        let a = parse("run artifacts extra");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["artifacts", "extra"]);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("x --n abc");
        assert_eq!(a.get_usize("m", 7).unwrap(), 7);
        assert!(a.get_usize("n", 0).is_err());
        assert_eq!(a.get_f64("f", 1.5).unwrap(), 1.5);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --a --b v");
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }
}
