//! Fixed-size worker thread pool (substrate — no tokio in this environment).
//!
//! The router uses this to run several in-flight requests concurrently so
//! that different pipeline stages (on different virtual nodes) overlap —
//! AMP4EC's throughput win over the monolithic baseline comes from exactly
//! this pipelining.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A classic shared-queue thread pool with graceful shutdown on drop.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize, name: &str) -> ThreadPool {
        assert!(threads > 0, "ThreadPool needs >= 1 thread");
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Submit a job; never blocks.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("pool workers alive");
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel, workers drain then exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Go-style wait group: a shared counter awaited once at drain. Long
/// dispatch loops `add(1)` per submitted job and workers `done()` —
/// bookkeeping stays O(1) no matter how many jobs pass through (the
/// router used to push one group per batch into a Vec for the whole
/// run).
pub struct WaitGroup {
    counter: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl WaitGroup {
    pub fn new(count: usize) -> WaitGroup {
        WaitGroup {
            counter: Arc::new((Mutex::new(count), std::sync::Condvar::new())),
        }
    }

    /// Register `n` more outstanding jobs. Must happen-before the
    /// matching `done()` calls (i.e. call it before submitting the job).
    pub fn add(&self, n: usize) {
        let (lock, _) = &*self.counter;
        *lock.lock().unwrap() += n;
    }

    pub fn done(&self) {
        let (lock, cv) = &*self.counter;
        let mut n = lock.lock().unwrap();
        *n = n.saturating_sub(1);
        if *n == 0 {
            cv.notify_all();
        }
    }

    /// Currently outstanding count (diagnostics/tests).
    pub fn pending(&self) -> usize {
        *self.counter.0.lock().unwrap()
    }

    pub fn wait(&self) {
        let (lock, cv) = &*self.counter;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }

    pub fn clone_handle(&self) -> WaitGroup {
        WaitGroup { counter: Arc::clone(&self.counter) }
    }
}

/// Reusable f32 buffer pool backing the activation data plane's
/// *genuine* copies (micro-batch padding, stacking disjoint request
/// rows, collector reassembly). The zero-copy tensor refactor turned
/// every split/slice into an `Arc` view; what remains is a small number
/// of fresh-contiguous-storage sites, and this pool lets them reuse
/// buffers reclaimed by [`crate::runtime::Tensor::recycle`] instead of
/// hitting the allocator per batch.
///
/// Buffers are stored cleared (`len == 0`, capacity intact);
/// [`BufferPool::take`] returns the pooled buffer with the largest
/// capacity (best fit for wide activations) or a fresh one. The pool is
/// bounded: beyond `MAX_POOLED` buffers or `MAX_POOLED_ELEMS` capacity a
/// returned buffer is simply dropped.
pub struct BufferPool {
    buffers: Mutex<Vec<Vec<f32>>>,
    hits: std::sync::atomic::AtomicU64,
    misses: std::sync::atomic::AtomicU64,
    returns: std::sync::atomic::AtomicU64,
}

/// Pooled-buffer counters (diagnostics + the dataplane bench).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// `take` calls served by a pooled buffer.
    pub hits: u64,
    /// `take` calls that had to allocate.
    pub misses: u64,
    /// Buffers accepted back by `give`.
    pub returns: u64,
}

impl PoolStats {
    /// Counter movement since an earlier snapshot (saturating, so a
    /// snapshot pair taken across unrelated resets stays non-negative).
    pub fn since(&self, earlier: &PoolStats) -> PoolStats {
        PoolStats {
            hits: self.hits.saturating_sub(earlier.hits),
            misses: self.misses.saturating_sub(earlier.misses),
            returns: self.returns.saturating_sub(earlier.returns),
        }
    }
}

const MAX_POOLED: usize = 32;
const MAX_POOLED_ELEMS: usize = 1 << 22; // 16 MiB of f32 per buffer

impl BufferPool {
    fn new() -> BufferPool {
        BufferPool {
            buffers: Mutex::new(Vec::new()),
            hits: std::sync::atomic::AtomicU64::new(0),
            misses: std::sync::atomic::AtomicU64::new(0),
            returns: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// The process-global pool the tensor data plane recycles through.
    pub fn global() -> &'static BufferPool {
        static POOL: std::sync::OnceLock<BufferPool> = std::sync::OnceLock::new();
        POOL.get_or_init(BufferPool::new)
    }

    /// An empty buffer with capacity for at least `min_capacity`
    /// elements: best-fit from the pool (smallest buffer that already
    /// fits, so a tiny tensor never pins a wide batch's storage through
    /// its `Arc` views), falling back to the largest pooled buffer
    /// (grown via `reserve`), else a fresh allocation.
    pub fn take(&self, min_capacity: usize) -> Vec<f32> {
        use std::sync::atomic::Ordering;
        let pooled = {
            let mut buffers = self.buffers.lock().unwrap();
            let idx = buffers
                .iter()
                .enumerate()
                .filter(|(_, b)| b.capacity() >= min_capacity)
                .min_by_key(|(_, b)| b.capacity())
                .map(|(i, _)| i)
                .or_else(|| {
                    buffers
                        .iter()
                        .enumerate()
                        .max_by_key(|(_, b)| b.capacity())
                        .map(|(i, _)| i)
                });
            idx.map(|i| buffers.swap_remove(i))
        };
        match pooled {
            Some(mut b) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                b.clear();
                b.reserve(min_capacity);
                b
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(min_capacity)
            }
        }
    }

    /// Return a buffer for reuse (dropped when the pool is full or the
    /// buffer is outsized).
    pub fn give(&self, mut buf: Vec<f32>) {
        if buf.capacity() == 0 || buf.capacity() > MAX_POOLED_ELEMS {
            return;
        }
        buf.clear();
        let mut buffers = self.buffers.lock().unwrap();
        if buffers.len() < MAX_POOLED {
            self.returns
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            buffers.push(buf);
        }
    }

    pub fn stats(&self) -> PoolStats {
        use std::sync::atomic::Ordering;
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            returns: self.returns.load(Ordering::Relaxed),
        }
    }

    /// Buffers currently pooled.
    pub fn pooled(&self) -> usize {
        self.buffers.lock().unwrap().len()
    }

    /// Total bytes of f32 capacity currently held by the pool — the
    /// memory-pressure signal the engine's window controller watches
    /// (a wide window inflates pooled storage on small-memory nodes).
    pub fn pooled_bytes(&self) -> u64 {
        self.buffers
            .lock()
            .unwrap()
            .iter()
            .map(|b| (b.capacity() * std::mem::size_of::<f32>()) as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, "t");
        let counter = Arc::new(AtomicUsize::new(0));
        let wg = WaitGroup::new(100);
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let w = wg.clone_handle();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                w.done();
            });
        }
        wg.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_joins_workers() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2, "d");
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits for queue drain
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallelism_actually_happens() {
        let pool = ThreadPool::new(4, "p");
        let wg = WaitGroup::new(4);
        let start = std::time::Instant::now();
        for _ in 0..4 {
            let w = wg.clone_handle();
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(50));
                w.done();
            });
        }
        wg.wait();
        // 4 x 50ms serial would be 200ms; parallel should be well under.
        assert!(start.elapsed().as_millis() < 150);
    }

    #[test]
    fn waitgroup_zero_is_immediate() {
        WaitGroup::new(0).wait();
    }

    #[test]
    fn buffer_pool_reuses_returned_storage() {
        // A private pool (not the global one) so the assertions are
        // exact under parallel tests.
        let pool = BufferPool::new();
        let first = pool.take(128);
        assert!(first.capacity() >= 128);
        assert_eq!(pool.stats().misses, 1);
        pool.give(first);
        assert_eq!(pool.pooled(), 1);
        let again = pool.take(16);
        assert!(again.is_empty());
        assert!(again.capacity() >= 128, "pooled capacity lost");
        let s = pool.stats();
        assert_eq!((s.hits, s.misses, s.returns), (1, 1, 1));
        // Zero-capacity buffers are not worth pooling.
        pool.give(Vec::new());
        assert_eq!(pool.pooled(), 0);
    }

    #[test]
    fn buffer_pool_reports_pooled_bytes() {
        let pool = BufferPool::new();
        assert_eq!(pool.pooled_bytes(), 0);
        pool.give(Vec::with_capacity(16));
        pool.give(Vec::with_capacity(48));
        // Capacity is a lower bound, so pooled_bytes is at least the
        // requested capacities.
        assert!(pool.pooled_bytes() >= (16 + 48) * 4);
        let _ = pool.take(16);
        let _ = pool.take(16);
        assert_eq!(pool.pooled_bytes(), 0);
    }

    #[test]
    fn buffer_pool_is_best_fit() {
        let pool = BufferPool::new();
        pool.give(Vec::with_capacity(8));
        pool.give(Vec::with_capacity(64));
        // A small request takes the smallest buffer that fits — the
        // wide one stays pooled for the next wide activation instead of
        // being pinned under a tiny tensor's views.
        let small = pool.take(4);
        let wide = pool.take(4);
        assert!(small.capacity() >= 4);
        assert!(
            small.capacity() < wide.capacity(),
            "best-fit must not hand out the widest buffer first ({} vs {})",
            small.capacity(),
            wide.capacity()
        );
        assert!(wide.capacity() >= 64);
        // A request nothing fits falls back to the largest (grown).
        pool.give(Vec::with_capacity(8));
        assert!(pool.take(32).capacity() >= 32);
        assert_eq!(pool.pooled(), 0);
    }

    #[test]
    fn waitgroup_add_reuses_one_counter() {
        // The drain pattern: one group, add-before-submit, wait once.
        let pool = ThreadPool::new(2, "wg");
        let wg = WaitGroup::new(0);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            wg.add(1);
            let w = wg.clone_handle();
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                w.done();
            });
        }
        wg.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 50);
        assert_eq!(wg.pending(), 0);
        // Reusable after a full drain.
        wg.add(1);
        assert_eq!(wg.pending(), 1);
        wg.done();
        wg.wait();
    }
}
