//! Fixed-size worker thread pool (substrate — no tokio in this environment).
//!
//! The router uses this to run several in-flight requests concurrently so
//! that different pipeline stages (on different virtual nodes) overlap —
//! AMP4EC's throughput win over the monolithic baseline comes from exactly
//! this pipelining.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A classic shared-queue thread pool with graceful shutdown on drop.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize, name: &str) -> ThreadPool {
        assert!(threads > 0, "ThreadPool needs >= 1 thread");
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Submit a job; never blocks.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("pool workers alive");
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel, workers drain then exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Go-style wait group: a shared counter awaited once at drain. Long
/// dispatch loops `add(1)` per submitted job and workers `done()` —
/// bookkeeping stays O(1) no matter how many jobs pass through (the
/// router used to push one group per batch into a Vec for the whole
/// run).
pub struct WaitGroup {
    counter: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl WaitGroup {
    pub fn new(count: usize) -> WaitGroup {
        WaitGroup {
            counter: Arc::new((Mutex::new(count), std::sync::Condvar::new())),
        }
    }

    /// Register `n` more outstanding jobs. Must happen-before the
    /// matching `done()` calls (i.e. call it before submitting the job).
    pub fn add(&self, n: usize) {
        let (lock, _) = &*self.counter;
        *lock.lock().unwrap() += n;
    }

    pub fn done(&self) {
        let (lock, cv) = &*self.counter;
        let mut n = lock.lock().unwrap();
        *n = n.saturating_sub(1);
        if *n == 0 {
            cv.notify_all();
        }
    }

    /// Currently outstanding count (diagnostics/tests).
    pub fn pending(&self) -> usize {
        *self.counter.0.lock().unwrap()
    }

    pub fn wait(&self) {
        let (lock, cv) = &*self.counter;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }

    pub fn clone_handle(&self) -> WaitGroup {
        WaitGroup { counter: Arc::clone(&self.counter) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, "t");
        let counter = Arc::new(AtomicUsize::new(0));
        let wg = WaitGroup::new(100);
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let w = wg.clone_handle();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                w.done();
            });
        }
        wg.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_joins_workers() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(2, "d");
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits for queue drain
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallelism_actually_happens() {
        let pool = ThreadPool::new(4, "p");
        let wg = WaitGroup::new(4);
        let start = std::time::Instant::now();
        for _ in 0..4 {
            let w = wg.clone_handle();
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(50));
                w.done();
            });
        }
        wg.wait();
        // 4 x 50ms serial would be 200ms; parallel should be well under.
        assert!(start.elapsed().as_millis() < 150);
    }

    #[test]
    fn waitgroup_zero_is_immediate() {
        WaitGroup::new(0).wait();
    }

    #[test]
    fn waitgroup_add_reuses_one_counter() {
        // The drain pattern: one group, add-before-submit, wait once.
        let pool = ThreadPool::new(2, "wg");
        let wg = WaitGroup::new(0);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            wg.add(1);
            let w = wg.clone_handle();
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                w.done();
            });
        }
        wg.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 50);
        assert_eq!(wg.pending(), 0);
        // Reusable after a full drain.
        wg.add(1);
        assert_eq!(wg.pending(), 1);
        wg.done();
        wg.wait();
    }
}
