//! In-tree property-testing harness (substrate — no proptest available).
//!
//! `forall(cases, seed, |rng| { ... })` runs a property over `cases`
//! randomly generated inputs. On failure it reports the *case seed* so the
//! exact failing input can be replayed deterministically:
//!
//! ```text
//! property failed at case 37 (replay seed 0x1234abcd): <panic payload>
//! ```

use super::rng::Rng;

/// Run `prop` over `cases` random cases. The closure receives a per-case
/// deterministic RNG; panic (assert) inside the closure to fail the case.
pub fn forall<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(
    cases: usize,
    seed: u64,
    prop: F,
) {
    let mut master = Rng::new(seed);
    for case in 0..cases {
        let case_seed = master.next_u64();
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(case_seed);
            prop(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property failed at case {case} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single case by seed (use after a `forall` failure).
pub fn replay<F: Fn(&mut Rng)>(case_seed: u64, prop: F) {
    let mut rng = Rng::new(case_seed);
    prop(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counter = std::sync::atomic::AtomicUsize::new(0);
        forall(50, 1, |rng| {
            let v = rng.below(10);
            assert!(v < 10);
            counter.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        assert_eq!(counter.load(std::sync::atomic::Ordering::SeqCst), 50);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            forall(100, 2, |rng| {
                assert!(rng.below(4) != 0, "hit the forbidden value");
            });
        });
        let err = result.expect_err("property should fail eventually");
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("replay seed"), "got: {msg}");
    }

    #[test]
    fn replay_is_deterministic() {
        let mut captured = 0u64;
        // Find a failing seed first.
        let mut master = Rng::new(2);
        for _ in 0..100 {
            let s = master.next_u64();
            let mut r = Rng::new(s);
            if r.below(100) == 42 {
                captured = s;
                break;
            }
        }
        if captured != 0 {
            replay(captured, |rng| {
                assert_eq!(rng.below(100), 42);
            });
        }
    }
}
