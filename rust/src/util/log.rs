//! Minimal leveled logger (substrate — no `log`/`env_logger` offline).
//!
//! Global level from `AMP4EC_LOG` (`error|warn|info|debug|trace`), default
//! `warn` so benches stay quiet. Timestamps are millis since process
//! start; output goes to stderr to keep stdout clean for table output.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    fn from_env(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" | "warning" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Warn,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // unset sentinel
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let cur = LEVEL.load(Ordering::Relaxed);
    if cur != u8::MAX {
        return cur;
    }
    let from_env = std::env::var("AMP4EC_LOG")
        .map(|v| Level::from_env(&v))
        .unwrap_or(Level::Warn) as u8;
    LEVEL.store(from_env, Ordering::Relaxed);
    from_env
}

/// Override the level programmatically (tests, CLI `--verbose`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

/// Core sink. Prefer the `log_*!` macros.
pub fn write(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t0 = START.get_or_init(Instant::now);
    eprintln!(
        "[{:>9.3}ms {:<5} {target}] {msg}",
        t0.elapsed().as_secs_f64() * 1e3,
        l.as_str()
    );
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Error, $target,
                                 format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Warn, $target,
                                 format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Info, $target,
                                 format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::write($crate::util::log::Level::Debug, $target,
                                 format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        assert_eq!(Level::from_env("DEBUG"), Level::Debug);
        assert_eq!(Level::from_env("bogus"), Level::Warn);
    }

    #[test]
    fn set_level_gates_output() {
        set_level(Level::Error);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        set_level(Level::Warn); // restore default-ish
    }

    #[test]
    fn macros_compile_and_run() {
        set_level(Level::Error);
        log_error!("test", "hello {}", 1);
        log_info!("test", "suppressed {}", 2);
    }
}
