//! In-tree substrates: this build environment is offline and only the `xla`
//! crate's dependency closure exists, so JSON, CLI parsing, thread pools,
//! PRNG, property testing, and the bench harness are implemented here
//! (see DESIGN.md "Substitutions").

pub mod bench;
pub mod check;
pub mod cli;
pub mod json;
pub mod log;
pub mod pool;
pub mod rng;
pub mod stats;
