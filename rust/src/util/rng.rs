//! Deterministic PRNG (substrate — no `rand` crate in this environment).
//!
//! SplitMix64 core with convenience samplers. Used by workload generators,
//! failure injection, and the in-tree property-test harness. Deterministic
//! across platforms, so experiment runs are exactly reproducible.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential inter-arrival sample with the given mean (for Poisson
    /// arrival processes in the workload generator).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal via Box-Muller (used for synthetic tensor inputs).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// A fresh child generator (stream split).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Fill a float tensor with N(0, 1) values.
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal() as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
        // All residues reachable.
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(5);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 5;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn exp_mean_roughly_right() {
        let mut r = Rng::new(6);
        let n = 20_000;
        let total: f64 = (0..n).map(|_| r.exp(10.0)).sum();
        let mean = total / n as f64;
        assert!((mean - 10.0).abs() < 0.5, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(8);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn split_streams_decorrelated() {
        let mut parent = Rng::new(9);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
