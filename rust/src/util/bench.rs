//! In-tree micro/meso benchmark harness (substrate — no criterion offline).
//!
//! `cargo bench` targets are `harness = false` binaries that call
//! [`BenchSuite`]. The harness does warmup + timed iterations and prints
//! aligned mean/p50/p95 rows, plus a machine-readable `BENCHJSON` line per
//! benchmark for EXPERIMENTS.md tooling.

use std::time::Instant;

use super::stats::Summary;

/// One benchmark's timing result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
}

/// Collects and prints benchmark rows.
pub struct BenchSuite {
    title: String,
    results: Vec<BenchResult>,
}

impl BenchSuite {
    pub fn new(title: &str) -> BenchSuite {
        println!("\n=== bench suite: {title} ===");
        BenchSuite { title: title.to_string(), results: Vec::new() }
    }

    /// Time `f` for `iters` iterations after `warmup` untimed runs.
    /// `f` is called once per iteration; per-iteration wall time is recorded.
    pub fn bench<F: FnMut()>(&mut self, name: &str, warmup: usize,
                             iters: usize, mut f: F) -> BenchResult {
        for _ in 0..warmup {
            f();
        }
        let mut summary = Summary::new();
        for _ in 0..iters {
            let t = Instant::now();
            f();
            summary.record(t.elapsed().as_secs_f64() * 1e3);
        }
        let r = BenchResult {
            name: name.to_string(),
            iters,
            mean_ms: summary.mean(),
            p50_ms: summary.p50(),
            p95_ms: summary.p95(),
            min_ms: summary.min(),
            max_ms: summary.max(),
        };
        println!(
            "{:<44} {:>8} iters  mean {:>9.3} ms  p50 {:>9.3} ms  p95 {:>9.3} ms",
            r.name, r.iters, r.mean_ms, r.p50_ms, r.p95_ms
        );
        println!(
            "BENCHJSON {{\"suite\":\"{}\",\"name\":\"{}\",\"mean_ms\":{:.6},\"p50_ms\":{:.6},\"p95_ms\":{:.6},\"iters\":{}}}",
            self.title, r.name, r.mean_ms, r.p50_ms, r.p95_ms, r.iters
        );
        self.results.push(r.clone());
        r
    }

    /// Record an externally-measured value as a row (for end-to-end drivers
    /// whose metric is throughput, not per-iteration latency).
    pub fn record_value(&mut self, name: &str, value: f64, unit: &str) {
        println!("{name:<44} {value:>12.3} {unit}");
        println!(
            "BENCHJSON {{\"suite\":\"{}\",\"name\":\"{}\",\"value\":{:.6},\"unit\":\"{}\"}}",
            self.title, name, value, unit
        );
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut suite = BenchSuite::new("test");
        let mut n = 0u64;
        let r = suite.bench("noop-ish", 2, 10, || {
            n = n.wrapping_add(1);
        });
        assert_eq!(r.iters, 10);
        assert_eq!(n, 12); // warmup + iters
        assert!(r.mean_ms >= 0.0);
        assert!(r.p95_ms >= r.p50_ms || r.p50_ms - r.p95_ms < 1e-9);
        assert_eq!(suite.results().len(), 1);
    }

    #[test]
    fn timed_sleep_is_measured() {
        let mut suite = BenchSuite::new("sleep");
        let r = suite.bench("1ms-sleep", 0, 3, || {
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert!(r.mean_ms >= 1.0);
    }
}
