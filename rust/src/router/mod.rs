//! The inference-service abstraction the serving ingress dispatches
//! into.
//!
//! This module used to own the whole request path — a raw
//! `SyncSender<Request>` channel, the batching loop (`serve`), and the
//! cache/padding plumbing. All of that moved into the unified
//! request-level ingress ([`crate::serving`]): requests now enter
//! through a `ServiceHandle` with per-request priority and deadline,
//! and the ingress dispatcher is the only place batches are formed.
//! What remains here is the boundary the dispatcher talks to:
//!
//! * [`InferenceService`] — anything that can run a stacked batch
//!   (distributed pipeline, monolithic baseline, mocks in tests).
//! * [`Submission`] — how a service accepted a batch: an asynchronous
//!   streaming waiter ([`Submission::Pending`]) or a handed-back tensor
//!   for synchronous execution ([`Submission::Inline`]).
//! * [`BatchMeta`] — the request-level context (priority class,
//!   batch deadline) the ingress threads through to the engine's
//!   admission and the scheduler's per-class charging.

use anyhow::Result;

use crate::runtime::Tensor;

/// Request-level context for one dispatched batch: the strictest
/// priority class among its requests, and — when every request carries
/// a deadline — the most lenient of them (so an engine-side shed is
/// correct for every member).
#[derive(Debug, Clone, Copy, Default)]
pub struct BatchMeta {
    /// Priority class (0 = most urgent).
    pub class: usize,
    /// Absolute deadline for the whole batch, if every member has one.
    pub deadline: Option<std::time::Instant>,
}

/// How a service accepted a stacked batch (see
/// [`InferenceService::submit_batch`]).
pub enum Submission {
    /// The batch was fed into a streaming engine; the closure blocks
    /// until that batch's rows are delivered and returns the usual
    /// `(output, compute_ms, comm_ms)` triple.
    Pending(Box<dyn FnOnce() -> Result<(Tensor, f64, f64)> + Send>),
    /// No streaming path: the ingress worker should run
    /// [`InferenceService::infer_batch_meta`] on the returned batch
    /// itself.
    Inline(Tensor),
}

/// Anything that can run a batched inference (distributed pipeline,
/// monolithic baseline, mocks in tests).
pub trait InferenceService: Send + Sync {
    /// Run one stacked batch. Returns output batch plus a timing split
    /// (compute ms, comm ms).
    fn infer_batch(&self, batch: &Tensor) -> Result<(Tensor, f64, f64)>;

    /// Like [`InferenceService::infer_batch`] but with the batch's
    /// request-level context, so synchronous services can still charge
    /// per class. Defaults to ignoring the meta.
    fn infer_batch_meta(
        &self,
        batch: &Tensor,
        meta: BatchMeta,
    ) -> Result<(Tensor, f64, f64)> {
        let _ = meta;
        self.infer_batch(batch)
    }

    /// Submit a stacked batch, preferring an asynchronous streaming
    /// path. Streaming services override this to enqueue the batch into
    /// their persistent engine (so successive batches overlap) and
    /// return [`Submission::Pending`]; the default hands the batch back
    /// for a synchronous `infer_batch_meta`.
    fn submit_batch(&self, batch: Tensor) -> Submission {
        Submission::Inline(batch)
    }

    /// [`InferenceService::submit_batch`] with request-level context:
    /// streaming services thread `meta.class` into their engine's
    /// admission ordering and `meta.deadline` into its pre-admission
    /// shed check. Defaults to the meta-less path.
    fn submit_batch_meta(&self, batch: Tensor, meta: BatchMeta) -> Submission {
        let _ = meta;
        self.submit_batch(batch)
    }

    /// The fixed batch the service's artifacts were compiled for.
    fn batch_size(&self) -> usize;

    /// Rows a miss set of `n` requests should be zero-padded to before
    /// [`InferenceService::infer_batch`]. Defaults to the full admission
    /// batch; streaming services override to round up to a multiple of
    /// their micro-batch instead, so light traffic does not pay compute
    /// for whole padding micro-batches.
    fn padded_rows(&self, n: usize) -> usize {
        let _ = n;
        self.batch_size()
    }

    /// A stable id namespacing cache keys.
    fn model_id(&self) -> u64;

    /// How many times the ingress should *resubmit* a batch whose
    /// submission failed with a non-deadline error before failing its
    /// requests. Zero (the default) preserves fail-fast semantics;
    /// self-healing services return a small budget so a batch that
    /// raced a node death and the subsequent heal swap gets served by
    /// the rebuilt stage chain instead of surfacing the transient.
    fn failure_retries(&self) -> usize {
        0
    }
}
