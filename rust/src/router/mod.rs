//! Request router + dynamic batcher.
//!
//! Requests enter a bounded queue; the batcher groups up to
//! `service.batch_size()` of them within `max_wait` (the paper's ~10 ms
//! scheduling overhead is exactly this admission delay plus node
//! selection), checks the result cache, and dispatches misses to an
//! [`InferenceService`] on a worker pool so multiple batches are in
//! flight at once.
//!
//! Streaming services (the `DistributedService` with `pipeline_depth >
//! 1`, adaptive depth, per-stage windows, or coalescing) override
//! [`InferenceService::submit_batch`] to feed their **persistent**
//! `pipeline::engine` directly: the worker's submission enqueues the
//! super-batch's micro-batches behind whatever is already flowing —
//! successive router batches stream back-to-back through the same
//! long-lived stage drivers with no inter-batch drain — and the worker
//! then blocks only on that batch's own completion. With coalescing the
//! engine's feeder may merge adjacent small miss-sets (each still its
//! own `submit_batch` call, padded to exact rows via
//! [`InferenceService::padded_rows`]) into shared micro-batches; every
//! worker still gets exactly its own batch's rows back, so the router
//! needs no awareness of the merge. Services without a streaming path
//! fall back to a synchronous [`InferenceService::infer_batch`] on the
//! worker.

use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::metrics::{MetricsCollector, RunMetrics};
use crate::pipeline::stack_batch;
use crate::runtime::Tensor;
use crate::scheduler::cache::{input_key, ResultCache};
use crate::util::pool::{ThreadPool, WaitGroup};

/// How a service accepted a stacked batch (see
/// [`InferenceService::submit_batch`]).
pub enum Submission {
    /// The batch was fed into a streaming engine; the closure blocks
    /// until that batch's rows are delivered and returns the usual
    /// `(output, compute_ms, comm_ms)` triple.
    Pending(Box<dyn FnOnce() -> Result<(Tensor, f64, f64)> + Send>),
    /// No streaming path: the router worker should run
    /// [`InferenceService::infer_batch`] on the returned batch itself.
    Inline(Tensor),
}

/// Anything that can run a batched inference (distributed pipeline,
/// monolithic baseline, mocks in tests).
pub trait InferenceService: Send + Sync {
    /// Run one stacked batch. Returns output batch plus a timing split
    /// (compute ms, comm ms).
    fn infer_batch(&self, batch: &Tensor) -> Result<(Tensor, f64, f64)>;

    /// Submit a stacked batch, preferring an asynchronous streaming
    /// path. Streaming services override this to enqueue the batch into
    /// their persistent engine (so successive batches overlap) and
    /// return [`Submission::Pending`]; the default hands the batch back
    /// for a synchronous `infer_batch`.
    fn submit_batch(&self, batch: Tensor) -> Submission {
        Submission::Inline(batch)
    }

    /// The fixed batch the service's artifacts were compiled for.
    fn batch_size(&self) -> usize;

    /// Rows a miss set of `n` requests should be zero-padded to before
    /// [`InferenceService::infer_batch`]. Defaults to the full admission
    /// batch; streaming services override to round up to a multiple of
    /// their micro-batch instead, so light traffic does not pay compute
    /// for whole padding micro-batches.
    fn padded_rows(&self, n: usize) -> usize {
        let _ = n;
        self.batch_size()
    }

    /// A stable id namespacing cache keys.
    fn model_id(&self) -> u64;
}

/// One inference request.
pub struct Request {
    pub id: u64,
    pub input: Tensor,
    pub enqueued: Instant,
}

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Batch admission window.
    pub max_wait: Duration,
    /// Concurrent batches in flight.
    pub workers: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            max_wait: Duration::from_millis(10),
            workers: 4,
        }
    }
}

/// Drive `service` with requests from `rx` until the channel closes,
/// optionally consulting a caller-owned result cache (the cache outlives
/// individual runs — AMP4EC+Cache's warm-cache behaviour). Returns
/// aggregate run metrics.
pub fn serve(
    service: Arc<dyn InferenceService>,
    rx: Receiver<Request>,
    config: RouterConfig,
    cache: Option<Arc<ResultCache>>,
) -> RunMetrics {
    let metrics = Arc::new(MetricsCollector::new());
    metrics.start_run();
    let pool = ThreadPool::new(config.workers, "router");
    let batch_size = service.batch_size();

    // One shared counter tracks outstanding batches; we wait for it to
    // drain once at the end. (This used to be a Vec with one WaitGroup
    // pushed per batch for the whole run — unbounded growth under
    // sustained traffic.)
    let drain = WaitGroup::new(0);

    loop {
        // ---- collect a batch ----
        let mut batch: Vec<Request> = Vec::with_capacity(batch_size);
        match rx.recv() {
            Ok(first) => batch.push(first),
            Err(_) => break, // channel closed and drained
        }
        let deadline = Instant::now() + config.max_wait;
        while batch.len() < batch_size {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        // ---- dispatch ----
        drain.add(1);
        let wg = drain.clone_handle();
        let service = Arc::clone(&service);
        let metrics = Arc::clone(&metrics);
        let cache = cache.clone();
        let dispatched = Instant::now();
        pool.execute(move || {
            process_batch(&*service, batch, cache.as_deref(), &metrics, dispatched);
            wg.done();
        });
    }

    drain.wait();
    metrics.finish()
}

fn process_batch(
    service: &dyn InferenceService,
    batch: Vec<Request>,
    cache: Option<&ResultCache>,
    metrics: &MetricsCollector,
    dispatched: Instant,
) {
    // Split into cache hits and misses (misses keep their batch index so
    // cache inserts are O(1) lookups, not per-row scans). Without a
    // cache there is nothing to key: skip hashing every input tensor.
    let mut misses: Vec<(usize, &Request)> = Vec::new();
    let mut hits: Vec<usize> = Vec::new();
    let mut keys: Vec<u64> = Vec::new();
    match cache {
        Some(c) => {
            keys.reserve(batch.len());
            for (i, r) in batch.iter().enumerate() {
                let key = input_key(service.model_id(), &r.input.data);
                keys.push(key);
                match c.get(key) {
                    Some(_row) => hits.push(i), // Arc clone; bytes untouched
                    None => misses.push((i, r)),
                }
            }
        }
        None => misses.extend(batch.iter().enumerate()),
    }

    // Serve hits immediately (zero compute / comm).
    for i in &hits {
        let r = &batch[*i];
        let latency = r.enqueued.elapsed().as_secs_f64() * 1e3;
        let sched = (dispatched - r.enqueued).as_secs_f64() * 1e3;
        metrics.record_request(latency, 0.0, 0.0, sched, true);
    }
    if misses.is_empty() {
        return;
    }

    // Run the miss set as one stacked batch. `submit_batch` lets a
    // streaming service enqueue it into its persistent engine right
    // behind the previous batch (no inter-batch drain); this worker then
    // waits only for its own batch's completion.
    let inputs: Vec<&Tensor> = misses.iter().map(|(_, r)| &r.input).collect();
    let stacked = match stack_batch(&inputs, service.padded_rows(misses.len())) {
        Ok(t) => t,
        Err(_) => {
            for _ in &misses {
                metrics.record_failure();
            }
            return;
        }
    };
    let stacked_bytes = stacked.byte_len();
    let result = match service.submit_batch(stacked) {
        Submission::Pending(wait) => wait(),
        Submission::Inline(t) => service.infer_batch(&t),
    };
    match result {
        Ok((output, compute_ms, comm_ms)) => {
            let row_len: usize = output.shape.iter().skip(1).product();
            if output.shape.is_empty()
                || output.shape[0] < misses.len()
                || row_len == 0
            {
                for _ in &misses {
                    metrics.record_failure();
                }
                return;
            }
            metrics.add_activation_bytes(stacked_bytes + output.byte_len());
            for (slot, (idx, r)) in misses.iter().enumerate() {
                let latency = r.enqueued.elapsed().as_secs_f64() * 1e3;
                let sched = (dispatched - r.enqueued).as_secs_f64() * 1e3;
                metrics.record_request(latency, compute_ms, comm_ms, sched, false);
                if let Some(c) = cache {
                    // One copy out of the batched output into a shared
                    // row; the cache keeps an Arc clone of the same
                    // allocation the response path hands out.
                    let row: std::sync::Arc<[f32]> = output.data
                        [slot * row_len..(slot + 1) * row_len]
                        .into();
                    c.put(keys[*idx], row);
                }
            }
        }
        Err(_) => {
            for _ in &misses {
                metrics.record_failure();
            }
        }
    }
}

/// Convenience: a bounded request channel pair.
pub fn request_channel(capacity: usize) -> (SyncSender<Request>, Receiver<Request>) {
    std::sync::mpsc::sync_channel(capacity)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fake service: output = input * 2, sleeps 2 ms per batch.
    struct Doubler {
        batch: usize,
    }

    impl InferenceService for Doubler {
        fn infer_batch(&self, batch: &Tensor) -> Result<(Tensor, f64, f64)> {
            std::thread::sleep(Duration::from_millis(2));
            let data = batch.data.iter().map(|v| v * 2.0).collect();
            Ok((Tensor::new(batch.shape.clone(), data)?, 2.0, 0.1))
        }
        fn batch_size(&self) -> usize {
            self.batch
        }
        fn model_id(&self) -> u64 {
            7
        }
    }

    fn send_n(tx: &SyncSender<Request>, n: usize, distinct: usize) {
        for i in 0..n {
            let v = (i % distinct) as f32;
            tx.send(Request {
                id: i as u64,
                input: Tensor::new(vec![1, 4], vec![v; 4]).unwrap(),
                enqueued: Instant::now(),
            })
            .unwrap();
        }
    }

    #[test]
    fn serves_all_requests() {
        let (tx, rx) = request_channel(64);
        send_n(&tx, 20, 20);
        drop(tx);
        let m = serve(Arc::new(Doubler { batch: 4 }), rx,
                      RouterConfig::default(), None);
        assert_eq!(m.completed, 20);
        assert_eq!(m.failed, 0);
        assert_eq!(m.cache_hits, 0);
        assert!(m.mean_latency_ms() > 0.0);
    }

    #[test]
    fn cache_hits_on_repeated_inputs() {
        let (tx, rx) = request_channel(64);
        send_n(&tx, 30, 3); // only 3 distinct inputs
        drop(tx);
        let m = serve(
            Arc::new(Doubler { batch: 1 }),
            rx,
            RouterConfig::default(),
            Some(Arc::new(ResultCache::new(16))),
        );
        assert_eq!(m.completed, 30);
        assert!(m.cache_hits >= 20, "hits {}", m.cache_hits);
    }

    #[test]
    fn batching_reduces_service_calls() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct Counting {
            calls: AtomicUsize,
        }
        impl InferenceService for Counting {
            fn infer_batch(&self, batch: &Tensor) -> Result<(Tensor, f64, f64)> {
                self.calls.fetch_add(1, Ordering::SeqCst);
                Ok((batch.clone(), 0.0, 0.0))
            }
            fn batch_size(&self) -> usize {
                8
            }
            fn model_id(&self) -> u64 {
                1
            }
        }
        let svc = Arc::new(Counting { calls: AtomicUsize::new(0) });
        let (tx, rx) = request_channel(64);
        send_n(&tx, 16, 16);
        drop(tx);
        let m = serve(Arc::clone(&svc) as Arc<dyn InferenceService>, rx,
                      RouterConfig::default(), None);
        assert_eq!(m.completed, 16);
        // 16 requests at batch 8 in <= ~4 calls (timing-dependent but far
        // fewer than 16).
        assert!(svc.calls.load(Ordering::SeqCst) <= 8);
    }

    #[test]
    fn padded_rows_override_controls_stacking() {
        // A streaming-style service pads misses to its micro-batch
        // multiple, not the full admission batch.
        struct MicroPad;
        impl InferenceService for MicroPad {
            fn infer_batch(&self, batch: &Tensor) -> Result<(Tensor, f64, f64)> {
                anyhow::ensure!(
                    batch.shape[0] % 2 == 0 && batch.shape[0] < 8,
                    "expected micro-batch-multiple padding, got {:?}",
                    batch.shape
                );
                Ok((batch.clone(), 0.0, 0.0))
            }
            fn batch_size(&self) -> usize {
                8
            }
            fn padded_rows(&self, n: usize) -> usize {
                (n + 1) / 2 * 2 // micro-batch of 2
            }
            fn model_id(&self) -> u64 {
                3
            }
        }
        let (tx, rx) = request_channel(16);
        send_n(&tx, 3, 3); // one admission of 3 misses -> padded to 4
        drop(tx);
        let m = serve(Arc::new(MicroPad), rx, RouterConfig::default(), None);
        assert_eq!(m.completed, 3);
        assert_eq!(m.failed, 0);
    }

    #[test]
    fn long_run_drain_bookkeeping_stays_bounded() {
        // Sustained traffic: many batches through one serve() call. With
        // the shared-counter drain the bookkeeping is O(1); the run must
        // complete everything and end fully drained.
        struct Instant0 {
            batch: usize,
        }
        impl InferenceService for Instant0 {
            fn infer_batch(&self, batch: &Tensor) -> Result<(Tensor, f64, f64)> {
                Ok((batch.clone(), 0.1, 0.0))
            }
            fn batch_size(&self) -> usize {
                self.batch
            }
            fn model_id(&self) -> u64 {
                9
            }
        }
        let (tx, rx) = request_channel(512);
        send_n(&tx, 400, 400);
        drop(tx);
        let m = serve(
            Arc::new(Instant0 { batch: 2 }),
            rx,
            RouterConfig { max_wait: Duration::from_millis(1), workers: 4 },
            None,
        );
        assert_eq!(m.completed, 400);
        assert_eq!(m.failed, 0);
    }

    #[test]
    fn pending_submissions_drive_the_streaming_path() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        // A streaming-style service: submit_batch returns a Pending
        // waiter and infer_batch must never be called by the router.
        struct Streaming {
            submissions: AtomicUsize,
            inline_calls: AtomicUsize,
        }
        impl InferenceService for Streaming {
            fn infer_batch(&self, batch: &Tensor) -> Result<(Tensor, f64, f64)> {
                self.inline_calls.fetch_add(1, Ordering::SeqCst);
                Ok((batch.clone(), 0.0, 0.0))
            }
            fn submit_batch(&self, batch: Tensor) -> Submission {
                self.submissions.fetch_add(1, Ordering::SeqCst);
                Submission::Pending(Box::new(move || {
                    let data = batch.data.iter().map(|v| v + 1.0).collect();
                    Ok((Tensor::new(batch.shape.clone(), data)?, 1.0, 0.5))
                }))
            }
            fn batch_size(&self) -> usize {
                4
            }
            fn model_id(&self) -> u64 {
                11
            }
        }
        let svc = Arc::new(Streaming {
            submissions: AtomicUsize::new(0),
            inline_calls: AtomicUsize::new(0),
        });
        let (tx, rx) = request_channel(32);
        send_n(&tx, 8, 8);
        drop(tx);
        let m = serve(
            Arc::clone(&svc) as Arc<dyn InferenceService>,
            rx,
            RouterConfig::default(),
            None,
        );
        assert_eq!(m.completed, 8);
        assert_eq!(m.failed, 0);
        assert!(svc.submissions.load(Ordering::SeqCst) >= 1);
        assert_eq!(svc.inline_calls.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn cache_rows_are_shared_not_copied() {
        // After a miss populates the cache, a repeat of the same input
        // must hit; the stored row is the Arc the router built.
        let cache = Arc::new(ResultCache::new(8));
        let (tx, rx) = request_channel(16);
        send_n(&tx, 6, 2); // 2 distinct inputs, repeated
        drop(tx);
        let m = serve(
            Arc::new(Doubler { batch: 1 }),
            rx,
            RouterConfig::default(),
            Some(Arc::clone(&cache)),
        );
        assert_eq!(m.completed, 6);
        assert!(m.cache_hits >= 2, "hits {}", m.cache_hits);
        let stats = cache.stats();
        assert_eq!(stats.entries, 2);
    }

    #[test]
    fn failures_are_counted() {
        struct Failing;
        impl InferenceService for Failing {
            fn infer_batch(&self, _batch: &Tensor) -> Result<(Tensor, f64, f64)> {
                anyhow::bail!("boom")
            }
            fn batch_size(&self) -> usize {
                2
            }
            fn model_id(&self) -> u64 {
                2
            }
        }
        let (tx, rx) = request_channel(16);
        send_n(&tx, 4, 4);
        drop(tx);
        let m = serve(Arc::new(Failing), rx, RouterConfig::default(), None);
        assert_eq!(m.completed, 0);
        assert_eq!(m.failed, 4);
    }
}
