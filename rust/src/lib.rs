//! # AMP4EC — Adaptive Model Partitioning for Edge Computing
//!
//! Rust + JAX + Pallas reproduction of *AMP4EC: Adaptive Model
//! Partitioning Framework for Efficient Deep Learning Inference in Edge
//! Computing Environments* (Zhang et al., 2025).
//!
//! Three-layer architecture, Python never on the request path:
//!
//! * **L3 (this crate)** — the paper's coordination contribution:
//!   [`monitor`] (Resource Monitor, §III-A), [`partitioner`] (Model
//!   Partitioner, §III-B, Eq. 1–3/9–10), [`scheduler`] (Task Scheduler +
//!   NSA, §III-C, Eq. 4–8), [`deployer`] (Model Deployer, §III-D), plus
//!   the [`cluster`] virtual-edge substrate, the [`serving`] unified
//!   request-level ingress (priority/deadline-aware admission over the
//!   [`router`] service boundary), the [`pipeline`] distributed
//!   executor (serial `run` plus the [`pipeline::engine`] streaming
//!   micro-batch engine), the [`baseline`] monolithic comparator, and
//!   the [`runtime`] PJRT bridge.
//! * **L2 (python/compile/model.py)** — MobileNetV2 in JAX, AOT-lowered
//!   per block to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas matmul and depthwise-conv
//!   kernels the model's FLOPs route through.
//!
//! Quickstart:
//!
//! ```no_run
//! use amp4ec::config::AmpConfig;
//! use amp4ec::server::EdgeServer;
//! use amp4ec::workload::Arrival;
//!
//! let cfg = AmpConfig::paper_cluster(std::path::Path::new("artifacts"));
//! let server = EdgeServer::start(cfg).unwrap();
//! let report = server.serve_workload(32, 32, Arrival::Closed, 0).unwrap();
//! println!("p50 latency: {:.1} ms", report.metrics.latency_summary().p50());
//! ```

pub mod baseline;
pub mod cluster;
pub mod config;
pub mod deployer;
pub mod manifest;
pub mod metrics;
pub mod monitor;
pub mod partitioner;
pub mod pipeline;
pub mod router;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod serving;
pub mod tenancy;
pub mod transport;
pub mod util;
pub mod workload;

/// Default artifacts directory, overridable with `AMP4EC_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("AMP4EC_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
