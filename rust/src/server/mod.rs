//! EdgeServer: the leader process tying all components together.
//!
//! Build from an [`AmpConfig`]: create the virtual cluster, spawn the
//! resource monitor, compute a partition plan, deploy it, then serve
//! workloads through the router. This is the end-to-end composition the
//! examples and the table benches drive.

use std::sync::{Arc, RwLock};

use anyhow::{Context, Result};

use crate::cluster::Cluster;
use crate::config::AmpConfig;
use crate::deployer::{Deployment, ModelDeployer};
use crate::manifest::Manifest;
use crate::metrics::RunMetrics;
use crate::monitor::{self, MonitorHandle};
use crate::partitioner::{self, Plan};
use crate::pipeline;
use crate::router::{self, InferenceService};
use crate::runtime::{Executor, Tensor};
use crate::scheduler::{ResultCache, Scheduler};
use crate::workload::{feed, Arrival, InputPool};

/// The distributed pipeline as an [`InferenceService`].
///
/// With `pipeline_depth == 1` every batch runs through the serial
/// [`pipeline::run`]. With `pipeline_depth > 1` the service admits
/// super-batches of `deployment.batch * pipeline_depth` rows and streams
/// them through the [`pipeline::engine`] as `pipeline_depth`
/// micro-batches of exactly the compiled artifact batch each — stage
/// *k* computes one micro-batch while stage *k+1* receives the previous
/// one.
pub struct DistributedService {
    deployment: RwLock<Deployment>,
    scheduler: Arc<Scheduler>,
    /// Micro-batches kept in flight per admitted batch (1 = serial).
    pipeline_depth: usize,
    /// Accumulated per-stage occupancy/bubble counters (streamed and
    /// serial runs alike).
    stage_counters: crate::metrics::StageCounterSet,
}

impl DistributedService {
    pub fn deployment_nodes(&self) -> Vec<usize> {
        self.deployment.read().unwrap().node_ids()
    }

    /// Swap in a new deployment (after a topology change).
    pub fn replace_deployment(&self, d: Deployment) -> Deployment {
        std::mem::replace(&mut *self.deployment.write().unwrap(), d)
    }

    /// Accumulated per-stage engine counters since startup.
    pub fn stage_counters(&self) -> Vec<crate::metrics::StageCounter> {
        self.stage_counters.snapshot()
    }

    pub fn pipeline_depth(&self) -> usize {
        self.pipeline_depth
    }
}

impl InferenceService for DistributedService {
    fn infer_batch(&self, batch: &Tensor) -> Result<(Tensor, f64, f64)> {
        let dep = self.deployment.read().unwrap();
        // Eq. 8 balance bookkeeping: every stage node carries this batch,
        // not just the first — charging only stage 0 made stages 2..N
        // look permanently idle to the scheduler.
        let node_ids: Vec<usize> =
            dep.stages.iter().map(|s| s.node.id()).collect();
        for id in &node_ids {
            self.scheduler.task_started(*id);
        }
        let dep_stages = pipeline::engine::DeploymentStages::new(&dep);
        let result = if self.pipeline_depth > 1 {
            let cfg = pipeline::engine::EngineConfig {
                micro_batch_rows: dep.batch,
                max_in_flight: self.pipeline_depth,
            };
            pipeline::engine::run_streamed(&dep_stages, batch, &cfg)
        } else {
            // Serial schedule (pipeline::run semantics) through the same
            // engine accounting, so stage counters are reported either
            // way.
            let rows = batch.shape.first().copied().unwrap_or(1).max(1);
            pipeline::engine::run_serial(&dep_stages, batch, rows)
        }
        .map(|run| {
            self.stage_counters.merge(&run.stage_counters);
            (run.output, run.timing)
        });
        match result {
            Ok((out, timing)) => {
                for st in &timing.stages {
                    self.scheduler
                        .task_completed(st.node, st.compute_ms + st.comm_ms);
                }
                Ok((out, timing.compute_ms, timing.comm_ms))
            }
            Err(e) => {
                // A failure has no meaningful execution time; count it in
                // the dedicated failure counter instead of feeding a 1e9
                // ms sentinel into the performance history (which
                // permanently cratered Eq. 7's S_P for the node).
                for id in &node_ids {
                    self.scheduler.task_failed(*id);
                }
                Err(e)
            }
        }
    }

    fn batch_size(&self) -> usize {
        self.deployment.read().unwrap().batch * self.pipeline_depth
    }

    fn padded_rows(&self, n: usize) -> usize {
        // Round up to whole micro-batches, not the full super-batch: a
        // light-traffic miss set of 1 request at depth 4 runs 1
        // micro-batch, not 4 (3 of which would be pure padding).
        let micro = self.deployment.read().unwrap().batch.max(1);
        let admission = micro * self.pipeline_depth;
        let chunks = n.div_euclid(micro) + usize::from(n % micro != 0);
        (chunks.max(1) * micro).min(admission)
    }

    fn model_id(&self) -> u64 {
        0xD157
    }
}

/// Everything a serving run produces, for the table harnesses.
pub struct ServeReport {
    pub metrics: RunMetrics,
    pub monitor_overhead_pct: f64,
    pub mean_stability: f64,
    pub deploy_transfer_bytes: u64,
    pub deploy_ms: f64,
    pub partition_layer_sizes: Vec<usize>,
    pub node_names: Vec<String>,
    pub cache_stats: Option<crate::scheduler::CacheStats>,
    /// Per-node accumulated energy (name, total J, compute J) — §V
    /// energy-aware extension.
    pub node_energy: Vec<(String, f64, f64)>,
    /// Per-pipeline-stage occupancy/bubble counters accumulated by the
    /// execution engine (simulated ms).
    pub stage_counters: Vec<crate::metrics::StageCounter>,
}

/// The leader.
pub struct EdgeServer {
    pub config: AmpConfig,
    pub manifest: Arc<Manifest>,
    pub cluster: Arc<Cluster>,
    pub scheduler: Arc<Scheduler>,
    pub deployer: Arc<ModelDeployer>,
    pub monitor: MonitorHandle,
    /// Persistent result cache (AMP4EC+Cache); survives across workloads.
    pub cache: Option<Arc<ResultCache>>,
    service: Arc<DistributedService>,
    plan: std::sync::Mutex<Plan>,
}

impl EdgeServer {
    /// Build the full stack from a config. Loads the manifest, spins up
    /// the cluster + monitor, plans partitions, and deploys.
    pub fn start(config: AmpConfig) -> Result<EdgeServer> {
        Self::start_with_plan(config, None)
    }

    /// Like [`EdgeServer::start`] but with a caller-supplied partition
    /// plan (e.g. profile-guided via `partitioner::plan_measured`).
    pub fn start_with_plan(
        config: AmpConfig,
        plan_override: Option<Plan>,
    ) -> Result<EdgeServer> {
        config.validate()?;
        let manifest = Arc::new(
            Manifest::load(&config.artifacts_dir).context("loading manifest")?,
        );
        anyhow::ensure!(
            manifest.batch_sizes.contains(&config.batch),
            "batch {} not in manifest batch sizes {:?}",
            config.batch,
            manifest.batch_sizes
        );

        let cluster = Arc::new(Cluster::new(config.sim_params()));
        for n in &config.nodes {
            cluster.add_node(n.to_spec());
        }
        let monitor = monitor::spawn(Arc::clone(&cluster), config.monitor_config());

        let scheduler = Arc::new(
            Scheduler::new(config.weights)
                .with_thresholds(config.overload_threshold, config.latency_threshold_ms),
        );

        let n_parts = config
            .num_partitions
            .unwrap_or_else(|| cluster.online_count())
            .min(manifest.blocks.len())
            .max(1);
        let plan = match plan_override {
            Some(p) => p,
            None if config.profiled_partitioning => {
                let block_ms = calibrate_block_costs(&manifest, config.batch)?;
                let weights: Vec<f64> =
                    config.nodes.iter().map(|n| n.cpu).collect();
                let weights = if weights.len() == n_parts {
                    weights
                } else {
                    vec![1.0; n_parts]
                };
                partitioner::plan_measured_weighted(
                    &manifest, &block_ms, &weights,
                )?
            }
            None if config.weighted_partitioning => {
                let weights: Vec<f64> =
                    config.nodes.iter().map(|n| n.cpu).collect();
                let weights = if weights.len() == n_parts {
                    weights
                } else {
                    vec![1.0; n_parts]
                };
                partitioner::plan_weighted(&manifest, &weights)?
            }
            None => partitioner::plan(&manifest, n_parts)?,
        };

        let mut deployer = ModelDeployer::new(Arc::clone(&manifest));
        deployer.use_model_cache = config.model_cache;
        let deployer = Arc::new(deployer);
        if config.model_cache {
            // Warm deployment: ship once so the measured run reuses the
            // node-local model cache (the +Cache configuration).
            let warm = deployer.deploy(&plan, &cluster, &scheduler, config.batch)?;
            deployer.undeploy(&warm);
        }
        let deployment = deployer.deploy(&plan, &cluster, &scheduler, config.batch)?;

        let service = Arc::new(DistributedService {
            deployment: RwLock::new(deployment),
            scheduler: Arc::clone(&scheduler),
            pipeline_depth: config.pipeline_depth.max(1),
            stage_counters: crate::metrics::StageCounterSet::new(),
        });

        let cache = config.cache_entries.map(|n| Arc::new(ResultCache::new(n)));
        Ok(EdgeServer {
            config,
            manifest,
            cluster,
            scheduler,
            deployer,
            monitor,
            cache,
            service,
            plan: std::sync::Mutex::new(plan),
        })
    }

    /// Current partition plan (clone; plans are small).
    pub fn plan(&self) -> Plan {
        self.plan.lock().unwrap().clone()
    }

    pub fn service(&self) -> Arc<DistributedService> {
        Arc::clone(&self.service)
    }

    /// Input tensor shape for a single request (batch dim = 1).
    pub fn request_shape(&self) -> Vec<usize> {
        vec![1, self.manifest.input_hw, self.manifest.input_hw,
             self.manifest.input_channels]
    }

    /// Run a closed- or open-loop workload of `n` requests drawn from
    /// `distinct` inputs; returns the full report.
    pub fn serve_workload(
        &self,
        n: usize,
        distinct: usize,
        arrival: Arrival,
        seed: u64,
    ) -> Result<ServeReport> {
        let pool = InputPool::new(&self.request_shape(), distinct, seed);
        let (tx, rx) = router::request_channel(256);
        let service: Arc<dyn InferenceService> = self.service();
        let router_cfg = self.config.router_config();
        let cache = self.cache.clone();
        let handle =
            std::thread::spawn(move || router::serve(service, rx, router_cfg, cache));
        feed(&tx, &pool, n, arrival, seed ^ 0xF00D);
        drop(tx);
        let metrics = handle.join().expect("router thread");

        let dep = self.service.deployment.read().unwrap();
        let snapshot = self.monitor.latest();
        Ok(ServeReport {
            metrics,
            monitor_overhead_pct: self.monitor.overhead_cpu_pct(),
            mean_stability: snapshot
                .as_ref()
                .map(|s| s.mean_stability())
                .unwrap_or(1.0),
            deploy_transfer_bytes: dep.transfer_bytes,
            deploy_ms: dep.deploy_ms,
            partition_layer_sizes: self.plan.lock().unwrap().layer_sizes(),
            node_names: self
                .cluster
                .online_nodes()
                .iter()
                .map(|n| n.name().to_string())
                .collect(),
            cache_stats: self.cache.as_ref().map(|c| c.stats()),
            node_energy: self
                .cluster
                .online_nodes()
                .iter()
                .map(|n| {
                    let e = n.energy();
                    (n.name().to_string(), e.total_j, e.compute_j)
                })
                .collect(),
            stage_counters: self.service.stage_counters(),
        })
    }

    /// Handle a topology change: re-plan and redeploy over the current
    /// online nodes. Returns the new partition layer sizes.
    pub fn rebalance(&self) -> Result<Vec<usize>> {
        let n = self
            .cluster
            .online_count()
            .min(self.manifest.blocks.len())
            .max(1);
        let plan = partitioner::plan(&self.manifest, n)?;
        let new_dep =
            self.deployer
                .deploy(&plan, &self.cluster, &self.scheduler, self.config.batch)?;
        let old = self.service.replace_deployment(new_dep);
        self.deployer.undeploy(&old);
        let sizes = plan.layer_sizes();
        *self.plan.lock().unwrap() = plan;
        Ok(sizes)
    }

    /// §V extension "dynamic partitioning ... adapt to runtime changes":
    /// spawn a watchdog that rebalances automatically whenever the online
    /// node count changes. Dropping the handle stops it.
    pub fn start_auto_rebalance(
        self: &Arc<Self>,
        interval: std::time::Duration,
    ) -> AutoRebalanceHandle {
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let server = Arc::clone(self);
        let stop_t = Arc::clone(&stop);
        // Baseline captured *before* the thread spawns: a topology change
        // racing thread startup must still be detected.
        let baseline = self.cluster.online_count();
        let thread = std::thread::Builder::new()
            .name("amp4ec-rebalance".into())
            .spawn(move || {
                let mut last = baseline;
                while !stop_t.load(std::sync::atomic::Ordering::SeqCst) {
                    std::thread::sleep(interval);
                    let now = server.cluster.online_count();
                    if now != last && now > 0 {
                        match server.rebalance() {
                            Ok(sizes) => crate::log_info!(
                                "rebalance",
                                "topology {last} -> {now} nodes; new plan {sizes:?}"
                            ),
                            Err(e) => crate::log_warn!(
                                "rebalance",
                                "failed after topology change: {e:#}"
                            ),
                        }
                        last = now;
                    }
                }
            })
            .expect("spawn rebalance watchdog");
        AutoRebalanceHandle { stop, thread: Some(thread) }
    }

    /// Golden parity: run the manifest's recorded input through the
    /// deployed pipeline and compare against the AOT-recorded output.
    pub fn golden_check(&self) -> Result<f32> {
        let golden = self
            .manifest
            .golden
            .as_ref()
            .context("manifest has no golden pair")?;
        anyhow::ensure!(
            golden.batch == 1,
            "golden parity assumes batch-1 recording"
        );
        let input = Tensor::from_f32_file(
            &self.manifest.dir.join(&golden.input_file),
            golden.in_shape.clone(),
        )?;
        let want = Tensor::from_f32_file(
            &self.manifest.dir.join(&golden.output_file),
            golden.out_shape.clone(),
        )?;
        // Pad the single input to the deployment batch.
        let dep = self.service.deployment.read().unwrap();
        let stacked = pipeline::stack_batch(&[&input], dep.batch)?;
        let (out, _) = pipeline::run(&dep, &stacked)?;
        let rows = pipeline::split_batch(&out, 1)?;
        let diff = rows[0].max_abs_diff(&want);
        anyhow::ensure!(
            (diff as f64) <= golden.tolerance * 10.0,
            "golden mismatch: max abs diff {diff}"
        );
        Ok(diff)
    }
}

/// Handle to the auto-rebalance watchdog; dropping stops the thread.
pub struct AutoRebalanceHandle {
    stop: Arc<std::sync::atomic::AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Drop for AutoRebalanceHandle {
    fn drop(&mut self) {
        self.stop.store(true, std::sync::atomic::Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// One-shot calibration: measured per-block execution time at `batch`
/// (thread-CPU ms on a scratch executor). Used by profile-guided
/// partitioning and the scalability bench.
pub fn calibrate_block_costs(
    manifest: &Manifest,
    batch: usize,
) -> Result<Vec<f64>> {
    let exec = Executor::spawn("calibrate")?;
    let mut out = Vec::with_capacity(manifest.blocks.len());
    let mut act = Tensor::zeros(vec![
        batch,
        manifest.input_hw,
        manifest.input_hw,
        manifest.input_channels,
    ]);
    for b in &manifest.blocks {
        let out_shape =
            vec![batch, b.out_shape[0], b.out_shape[1], b.out_shape[2]];
        let h = exec.load_block(
            manifest.artifact_path(b, batch)?,
            manifest.weights_path(b),
            b.param_count as usize,
            out_shape,
        )?;
        // Warm once, then one timed run (relative weights are all the
        // planner needs).
        let (_, _) = exec.run_chain(vec![h], act.clone())?;
        let (next, ms) = exec.run_chain(vec![h], act)?;
        act = next;
        out.push(ms);
    }
    Ok(out)
}

/// Convenience used by benches: a one-request-at-a-time helper.
pub fn single_request(
    server: &EdgeServer,
    input: &Tensor,
) -> Result<(Tensor, f64)> {
    let dep = server.service.deployment.read().unwrap();
    let stacked = pipeline::stack_batch(&[input], dep.batch)?;
    let t0 = std::time::Instant::now();
    let (out, _) = pipeline::run(&dep, &stacked)?;
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    let rows = pipeline::split_batch(&out, 1)?;
    Ok((rows[0].clone(), ms))
}

pub use crate::router::Request as ServerRequest;
