//! EdgeServer: the leader process tying all components together.
//!
//! Build from an [`AmpConfig`]: create the virtual cluster, spawn the
//! resource monitor, compute a partition plan, deploy it, then serve
//! requests through the unified serving ingress
//! ([`EdgeServer::serve_handle`] — every entry point, from the CLI
//! serve loop to [`single_request`] and [`EdgeServer::golden_check`],
//! rides the same request-level path). This is the end-to-end
//! composition the examples and the table benches drive.
//!
//! Multi-model co-deployment (ISSUE 9): [`EdgeServer::deploy_model`]
//! packs additional models onto the *same* cluster — each entry gets
//! its own manifest, partition plan, deployer, and service, but node
//! selection goes through the shared scheduler, whose scoring reads
//! each node's **remaining** memory, so a second model packs around
//! whatever co-resident deployments already reserved. Healing is
//! deployment-scoped: a deployment is healed only when it actually
//! lost a node, so one model's churn never redeploys another.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{Context, Result};

use crate::cluster::Cluster;
use crate::config::AmpConfig;
use crate::deployer::{Deployment, ModelDeployer};
use crate::manifest::Manifest;
use crate::metrics::{ChurnStats, RunMetrics, StageCounter};
use crate::monitor::{self, ClusterSnapshot, MonitorHandle, NodeEvent};
use crate::partitioner::{self, Plan};
use crate::pipeline::engine;
use crate::router::{BatchMeta, InferenceService, Submission};
use crate::runtime::{Executor, Tensor};
use crate::scheduler::{ResultCache, Scheduler};
use crate::serving::ServiceHandle;
use crate::transport::{self, TransportKind};
use crate::workload::{feed, Arrival, InputPool};

/// Boxed completion waiter produced by the streaming submission path:
/// blocks until the batch's rows are delivered.
type InferWait = Box<dyn FnOnce() -> Result<(Tensor, f64, f64)> + Send>;

/// The distributed pipeline as an [`InferenceService`].
///
/// With `pipeline_depth == 1` (and no adaptive depth) every batch runs
/// through the serial [`pipeline::run`] schedule. Otherwise the service
/// owns a **persistent** [`engine::PersistentEngine`]: super-batches of
/// `deployment.batch * pipeline_depth` rows are *submitted* (not run)
/// into long-lived per-stage driver threads, so successive router
/// batches stream back-to-back across the stage nodes with no
/// inter-batch drain, and — when `adaptive_depth` is on — the in-flight
/// window resizes itself online from observed per-stage bubble time.
pub struct DistributedService {
    deployment: RwLock<Arc<Deployment>>,
    scheduler: Arc<Scheduler>,
    /// Configured micro-batches in flight per admitted batch (1 =
    /// serial); the adaptive controller may move the live window.
    pipeline_depth: usize,
    adaptive: Option<engine::AdaptiveDepthConfig>,
    /// Per-stage credit windows: the adaptive controller resizes each
    /// stage's budget independently, and rebalance carries learned
    /// budgets into the rebuilt engine.
    per_stage_windows: bool,
    /// Feeder-side batch coalescing (also relaxes miss padding to exact
    /// rows — short tails merge in the engine instead of being padded).
    coalesce: bool,
    /// Wire-transport configuration: when set, stage chains are built
    /// over node-agent connections instead of in-process deployment
    /// stages. Wire mode always runs the engine — the serial fallback
    /// would execute stages locally, silently ignoring the agents.
    wire: Option<transport::WireConfig>,
    /// The long-lived streaming engine (None = serial schedule). Rebuilt
    /// on deployment swaps; the old engine drains before teardown.
    engine: Mutex<Option<Arc<engine::PersistentEngine>>>,
    /// Accumulated per-stage occupancy/bubble counters (streamed and
    /// serial runs alike). Arc so completion closures can merge into it.
    stage_counters: Arc<crate::metrics::StageCounterSet>,
    /// Self-healing serving (`AmpConfig::heal`): the engine replays
    /// failed micro-batches on surviving replicas, and the ingress gets
    /// a failure-retry budget to ride out a heal swap.
    heal: bool,
    /// Straggler hedging (`AmpConfig::hedge`): a replicated stage's
    /// micro-batch that runs past its armed latency threshold is
    /// re-issued on a surviving sibling replica, first completion wins.
    hedge: bool,
    /// Replay counters carried over from engines already torn down by
    /// deployment swaps; the live engine's counters ride on top (see
    /// [`DistributedService::replay_stats`]).
    replay_base: ReplayBase,
}

/// Replay counts folded in from drained engines (a heal rebuilds the
/// engine, which would otherwise reset the run's replay accounting).
#[derive(Default)]
struct ReplayBase {
    attempted: AtomicU64,
    succeeded: AtomicU64,
}

/// What a previous engine learned, for an engine-aware rebalance: the
/// live delivery depth plus the per-stage budget shape.
struct LearnedWindows {
    depth: usize,
    stage_budgets: Vec<usize>,
}

impl DistributedService {
    pub fn deployment_nodes(&self) -> Vec<usize> {
        self.deployment.read().unwrap().node_ids()
    }

    /// Every node hosting *any* replica of the live deployment — the
    /// set the deployment-scoped heal intersects with the dead set.
    pub fn all_deployment_nodes(&self) -> HashSet<usize> {
        self.deployment
            .read()
            .unwrap()
            .replica_node_ids()
            .into_iter()
            .flatten()
            .collect()
    }

    fn wants_engine(
        pipeline_depth: usize,
        adaptive: Option<&engine::AdaptiveDepthConfig>,
        per_stage_windows: bool,
        coalesce: bool,
        wire: Option<&transport::WireConfig>,
        replicated: bool,
    ) -> bool {
        // Replication forces the engine: replicas only exist in the
        // streaming data plane (the serial schedule runs primaries only,
        // which would silently waste every placed replica).
        pipeline_depth > 1
            || adaptive.is_some()
            || per_stage_windows
            || coalesce
            || wire.is_some()
            || replicated
    }

    /// Build the persistent engine for a deployment (None when the
    /// config asks for the serial schedule). `carried` is the previous
    /// engine's learned window state: an engine-aware rebalance seeds
    /// the rebuilt engine from it instead of restarting the controller
    /// cold.
    fn build_engine(
        dep: &Arc<Deployment>,
        pipeline_depth: usize,
        adaptive: Option<engine::AdaptiveDepthConfig>,
        per_stage_windows: bool,
        coalesce: bool,
        wire: Option<&transport::WireConfig>,
        replay: bool,
        hedge: bool,
        carried: Option<LearnedWindows>,
    ) -> Result<Option<Arc<engine::PersistentEngine>>> {
        let replicated = dep.stages.iter().any(|s| s.replica_count() > 1);
        if !Self::wants_engine(
            pipeline_depth,
            adaptive.as_ref(),
            per_stage_windows,
            coalesce,
            wire,
            replicated,
        ) {
            return Ok(None);
        }
        let n_stages = dep.stages.len().max(1);
        let clamp = |d: usize| match &adaptive {
            Some(a) => d.clamp(a.min_depth, a.max_depth),
            None => d.max(1),
        };
        let (initial_depth, stage_budgets) = match carried {
            Some(learned) => {
                let budgets: Vec<usize> =
                    engine::carry_stage_budgets(&learned.stage_budgets, n_stages)
                        .into_iter()
                        .map(clamp)
                        .collect();
                (clamp(learned.depth), Some(budgets))
            }
            None => (clamp(pipeline_depth.max(1)), None),
        };
        let cfg = engine::PersistentEngineConfig {
            micro_batch_rows: dep.batch.max(1),
            initial_depth,
            stage_budgets,
            per_stage: per_stage_windows,
            coalesce,
            adaptive,
            replay,
            hedge: hedge.then(engine::HedgeConfig::default),
        };
        let built = match wire {
            // Wire mode: the stage chain is the remote twin of `dep` —
            // each agent replays the same block loads (or sim spec) and
            // the coordinator keeps link-model mirrors, so scheduling
            // and sim accounting match the in-process chain.
            Some(w) => {
                // One deploy-spec group per stage (one spec per replica,
                // one agent connection per spec) — singleton groups are
                // byte-identical to the old per-stage connect.
                let groups = transport::block_spec_groups_for(
                    dep,
                    &w.params,
                    &w.artifacts_dir,
                );
                let stages =
                    Arc::new(
                        transport::WireStages::connect_replicated(
                            &w.addrs,
                            groups,
                            w.connect_timeout,
                        )?
                        .with_execute_timeout(w.execute_timeout),
                    );
                engine::PersistentEngine::new(stages, cfg)?
            }
            None => {
                let stages =
                    Arc::new(engine::DeploymentStages::new(Arc::clone(dep)));
                engine::PersistentEngine::new(stages, cfg)?
            }
        };
        Ok(Some(Arc::new(built)))
    }

    /// Swap in a new deployment (after a topology change): the streaming
    /// engine is rebuilt over the new stage chain, seeded with the old
    /// engine's *learned* per-stage budgets and live depth (engine-aware
    /// rebalance — the controller does not restart cold); the old engine
    /// drains its in-flight batches against the old deployment before
    /// teardown. Returns the old deployment for undeploy. On error (e.g.
    /// the new engine failed to spawn) nothing was swapped — the caller
    /// still owns `d` and must undeploy it.
    pub fn replace_deployment(&self, d: Arc<Deployment>) -> Result<Arc<Deployment>> {
        let carried = self.engine.lock().unwrap().as_ref().map(|e| {
            LearnedWindows {
                depth: e.current_depth(),
                stage_budgets: e.stage_budgets(),
            }
        });
        let new_engine = Self::build_engine(
            &d,
            self.pipeline_depth,
            self.adaptive,
            self.per_stage_windows,
            self.coalesce,
            self.wire.as_ref(),
            self.heal,
            self.hedge,
            carried,
        )?;
        // Swap both under the deployment write lock. Acquiring it waits
        // for every submit_streaming/serial_infer read guard, and the
        // engine is swapped before the write guard releases, so no
        // submission can reach the old engine afterwards: once we hold
        // `old_engine` its refcount is ours alone.
        let (old_dep, old_engine) = {
            let mut dep_guard = self.deployment.write().unwrap();
            let old_dep = std::mem::replace(&mut *dep_guard, Arc::clone(&d));
            let old_engine = std::mem::replace(
                &mut *self.engine.lock().unwrap(),
                new_engine,
            );
            (old_dep, old_engine)
        };
        // Last reference: dropping joins the old engine's threads after
        // its queues drain, so in-flight batches complete against the old
        // deployment before the caller undeploys it. The probe outlives
        // the engine, so replays performed *during* that final drain
        // still land in the accumulated base.
        let probe = old_engine.as_ref().map(|e| e.replay_probe());
        drop(old_engine);
        if let Some(p) = probe {
            let s = p.stats();
            self.replay_base.attempted.fetch_add(s.attempted, Ordering::Relaxed);
            self.replay_base.succeeded.fetch_add(s.succeeded, Ordering::Relaxed);
        }
        Ok(old_dep)
    }

    /// In-flight replay counters since startup, accumulated across
    /// deployment swaps (a heal rebuilds the engine; the drained
    /// engine's counts fold into the base — see `replay_base`).
    pub fn replay_stats(&self) -> engine::ReplayStats {
        let live = self
            .engine
            .lock()
            .unwrap()
            .as_ref()
            .map(|e| e.replay_stats())
            .unwrap_or_default();
        engine::ReplayStats {
            attempted: self.replay_base.attempted.load(Ordering::Relaxed)
                + live.attempted,
            succeeded: self.replay_base.succeeded.load(Ordering::Relaxed)
                + live.succeeded,
        }
    }

    /// Accumulated per-stage engine counters since startup.
    pub fn stage_counters(&self) -> Vec<crate::metrics::StageCounter> {
        self.stage_counters.snapshot()
    }

    pub fn pipeline_depth(&self) -> usize {
        self.pipeline_depth
    }

    /// Live in-flight window plus the adaptive controller's trajectory
    /// (None when running the serial schedule or a fixed window).
    pub fn depth_status(&self) -> (usize, Option<engine::DepthReport>) {
        match &*self.engine.lock().unwrap() {
            Some(e) => {
                let report =
                    self.adaptive.is_some().then(|| e.depth_report());
                (e.current_depth(), report)
            }
            None => (self.pipeline_depth, None),
        }
    }

    /// Live per-stage credit budgets (empty when running the serial
    /// schedule) and the feeder's coalescing counters (None when no
    /// engine is configured or coalescing is off).
    pub fn window_status(
        &self,
    ) -> (Vec<usize>, Option<crate::metrics::CoalesceStats>) {
        match &*self.engine.lock().unwrap() {
            Some(e) => (
                e.stage_budgets(),
                self.coalesce.then(|| e.coalesce_stats()),
            ),
            None => (Vec::new(), None),
        }
    }

    /// Reshape the engine's per-stage credit windows from the monitor's
    /// *live* profile (the ROADMAP follow-on to probe-batch shaping,
    /// behind the same `--stage-windows` flag): the engine's measured
    /// per-micro-batch stage latencies are scaled by each stage node's
    /// current load from `snapshot`
    /// ([`live_stage_latencies`]), re-shaped into budgets with
    /// `budgets_from_profile` at the *current* credit total, and applied
    /// in place with `PersistentEngine::reshape_budgets` — no drain, no
    /// engine rebuild. Returns the resulting live budgets, or None when
    /// per-stage windows are off, no engine is running, or no stage has
    /// served traffic yet (a cold engine has no profile to shape from).
    pub fn retune_windows(&self, snapshot: &ClusterSnapshot) -> Option<Vec<usize>> {
        if !self.per_stage_windows {
            return None;
        }
        let engine = self.engine.lock().unwrap().clone()?;
        let latencies =
            live_stage_latencies(&engine.total_counters(), snapshot)?;
        let total: usize = engine.stage_budgets().iter().sum();
        let target = engine::budgets_from_profile(&latencies, total);
        engine.reshape_budgets(&target);
        Some(engine.stage_budgets())
    }

    /// Feed the persistent engine (by value — the batch's rows go
    /// straight into the feeder with no defensive copy), returning a
    /// completion waiter; hands the batch back untouched when no engine
    /// is configured (serial schedule). The batch's request-level
    /// context threads through: `meta.class` orders pending submissions
    /// in the engine feeder and `meta.deadline` arms its pre-admission
    /// shed check. Node charging uses the *engine's* stage nodes —
    /// during a deployment swap a batch submitted to the old engine
    /// still executes on the old stages, so reading `self.deployment`
    /// here could charge the wrong nodes.
    fn submit_streaming(
        &self,
        batch: Tensor,
        meta: BatchMeta,
    ) -> std::result::Result<InferWait, Tensor> {
        // Hold the deployment read guard across the engine lookup *and*
        // the submission: replace_deployment's write lock then waits for
        // every mid-flight submission before swapping, and since `engine`
        // (declared after the guard) drops first, the moment the write
        // lock is granted the old engine's only reference is the
        // service's — its drop truly drains before the caller undeploys.
        let _dep_guard = self.deployment.read().unwrap();
        let engine = match self.engine.lock().unwrap().clone() {
            Some(e) => e,
            None => return Err(batch),
        };
        // Shared Arc<[usize]> — no per-batch copy of the stage→node map.
        let node_ids = engine.shared_node_ids();
        self.scheduler.tasks_started(&node_ids);
        let scheduler = Arc::clone(&self.scheduler);
        let stage_counters = Arc::clone(&self.stage_counters);
        match engine.submit_owned_with(batch, meta.class, meta.deadline) {
            Ok(handle) => Ok(Box::new(move || match handle.wait() {
                Ok(run) => {
                    stage_counters.merge(&run.stage_counters);
                    for st in &run.timing.stages {
                        scheduler
                            .task_completed(st.node, st.compute_ms + st.comm_ms);
                    }
                    Ok((run.output, run.timing.compute_ms, run.timing.comm_ms))
                }
                Err(e) => {
                    // A deadline shed never reached the stage nodes:
                    // reverse the started charge instead of booking a
                    // failure against healthy hardware.
                    if e.downcast_ref::<engine::DeadlineShed>().is_some() {
                        scheduler.tasks_cancelled(&node_ids);
                    } else {
                        scheduler.tasks_failed(&node_ids);
                    }
                    Err(e)
                }
            })),
            Err(e) => {
                self.scheduler.tasks_failed(&node_ids);
                Ok(Box::new(move || Err(e)))
            }
        }
    }

    /// Serial schedule (pipeline::run semantics) through the engine
    /// accounting, with full scheduler charging.
    fn serial_infer(&self, batch: &Tensor) -> Result<(Tensor, f64, f64)> {
        // Hold the read guard across the whole run: a concurrent
        // rebalance's write + undeploy must wait for in-flight serial
        // inferences instead of unloading executor blocks under them.
        let dep = self.deployment.read().unwrap();
        // Eq. 8 balance bookkeeping: every stage node carries this batch,
        // not just the first — charging only stage 0 made stages 2..N
        // look permanently idle to the scheduler.
        let node_ids: Vec<usize> =
            dep.stages.iter().map(|s| s.node.id()).collect();
        self.scheduler.tasks_started(&node_ids);
        let dep_stages = engine::DeploymentStages::new(&**dep);
        let rows = batch.shape.first().copied().unwrap_or(1).max(1);
        match engine::run_serial(&dep_stages, batch, rows) {
            Ok(run) => {
                self.stage_counters.merge(&run.stage_counters);
                for st in &run.timing.stages {
                    self.scheduler
                        .task_completed(st.node, st.compute_ms + st.comm_ms);
                }
                Ok((run.output, run.timing.compute_ms, run.timing.comm_ms))
            }
            Err(e) => {
                // A failure has no meaningful execution time; count it in
                // the dedicated failure counter instead of feeding a 1e9
                // ms sentinel into the performance history (which
                // permanently cratered Eq. 7's S_P for the node).
                self.scheduler.tasks_failed(&node_ids);
                Err(e)
            }
        }
    }
}

impl InferenceService for DistributedService {
    fn infer_batch(&self, batch: &Tensor) -> Result<(Tensor, f64, f64)> {
        self.infer_batch_meta(batch, BatchMeta::default())
    }

    fn infer_batch_meta(
        &self,
        batch: &Tensor,
        meta: BatchMeta,
    ) -> Result<(Tensor, f64, f64)> {
        // Cheap presence check first so the serial-only configuration
        // never clones; the owned submission handles the (rare)
        // engine-swap race by handing the batch back.
        if self.engine.lock().unwrap().is_some() {
            if let Ok(wait) = self.submit_streaming(batch.clone(), meta) {
                return wait();
            }
        }
        self.serial_infer(batch)
    }

    fn submit_batch(&self, batch: Tensor) -> Submission {
        self.submit_batch_meta(batch, BatchMeta::default())
    }

    /// Feed the persistent engine directly: the batch's micro-batches
    /// are enqueued behind whatever is already streaming (submission
    /// blocks only on queue back-pressure) ordered by `meta.class`, and
    /// the returned waiter resolves when this batch's rows are
    /// delivered — or with a `DeadlineShed` if `meta.deadline` expired
    /// before the feeder admitted it. Falls back to the serial schedule
    /// when no engine is configured.
    fn submit_batch_meta(&self, batch: Tensor, meta: BatchMeta) -> Submission {
        match self.submit_streaming(batch, meta) {
            Ok(wait) => Submission::Pending(wait),
            Err(batch) => Submission::Inline(batch),
        }
    }

    fn batch_size(&self) -> usize {
        self.deployment.read().unwrap().batch * self.pipeline_depth
    }

    fn padded_rows(&self, n: usize) -> usize {
        // With coalescing the engine feeder merges short tails across
        // adjacent miss-sets, so padding to a micro-batch multiple here
        // would only manufacture rows for it to *not* save: submit the
        // exact miss rows instead. (coalesce implies an engine exists —
        // see wants_engine — so no lock is needed on this hot path.)
        if self.coalesce {
            return n.max(1);
        }
        // Round up to whole micro-batches, not the full super-batch: a
        // light-traffic miss set of 1 request at depth 4 runs 1
        // micro-batch, not 4 (3 of which would be pure padding).
        let micro = self.deployment.read().unwrap().batch.max(1);
        let admission = micro * self.pipeline_depth;
        let chunks = n.div_euclid(micro) + usize::from(n % micro != 0);
        (chunks.max(1) * micro).min(admission)
    }

    fn model_id(&self) -> u64 {
        0xD157
    }

    /// Ingress-side retry budget: with healing on, a batch that failed
    /// mid-churn (its stage chain lost a node between the death and the
    /// heal swap) is worth resubmitting — the healed engine serves it.
    /// Without healing a failure is terminal, so retrying would only
    /// double the latency of a lost cause; keep the fail-fast default.
    fn failure_retries(&self) -> usize {
        if self.heal { 2 } else { 0 }
    }
}

/// Everything a serving run produces, for the table harnesses.
pub struct ServeReport {
    /// Which co-deployed model this report covers: `"primary"` for the
    /// server's own deployment; registry entries report under their
    /// [`EdgeServer::deploy_model`] name. Together with the per-tenant
    /// breakdown inside `metrics`, results key by (model, tenant,
    /// class).
    pub model: String,
    pub metrics: RunMetrics,
    pub monitor_overhead_pct: f64,
    pub mean_stability: f64,
    pub deploy_transfer_bytes: u64,
    pub deploy_ms: f64,
    pub partition_layer_sizes: Vec<usize>,
    pub node_names: Vec<String>,
    pub cache_stats: Option<crate::scheduler::CacheStats>,
    /// Per-node accumulated energy (name, total J, compute J) — §V
    /// energy-aware extension.
    pub node_energy: Vec<(String, f64, f64)>,
    /// Per-pipeline-stage occupancy/bubble counters accumulated by the
    /// execution engine (simulated ms).
    pub stage_counters: Vec<crate::metrics::StageCounter>,
    /// Live in-flight window at the end of the run (== configured
    /// `pipeline_depth` unless the adaptive controller moved it).
    pub final_pipeline_depth: usize,
    /// Adaptive depth trajectory (None unless `adaptive_depth`).
    pub depth_report: Option<engine::DepthReport>,
    /// Live per-stage credit budgets at the end of the run (empty when
    /// running the serial schedule).
    pub stage_budgets: Vec<usize>,
    /// Feeder coalescing counters (None when no engine is configured).
    pub coalesce_stats: Option<crate::metrics::CoalesceStats>,
    /// Activation data-plane movement during this run: the copies the
    /// zero-copy plane could not avoid, vs. bytes moved as `Arc` views.
    pub data_plane: crate::metrics::data_plane::DataPlaneStats,
    /// Buffer-pool hit/miss/return movement during this run.
    pub pool_stats: crate::util::pool::PoolStats,
    /// Wire-transport frame/byte/codec counters during this run (None
    /// on the in-process transport).
    pub wire: Option<crate::metrics::wire::WireStats>,
    /// Replica map: `replica_map[k]` lists the nodes hosting stage `k`'s
    /// replicas, primary first (all singletons when replication is off).
    pub replica_map: Vec<Vec<usize>>,
    /// Per-(stage, replica) occupancy/bubble counters from the engine's
    /// critical path (empty when no engine ran).
    pub replica_counters: Vec<crate::metrics::ReplicaCounter>,
    /// Node-churn accounting: deaths/returns seen by the heal watchdog,
    /// heals performed, and engine micro-batch replays (accumulated
    /// across deployment swaps). All zero on a churn-free run.
    pub churn: ChurnStats,
}

/// What one [`EdgeServer::heal`] invocation did.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HealAction {
    /// Dead replicas were re-placed in place; the partition plan (and
    /// the learned engine windows) survived.
    Replaced,
    /// Full re-partition over the surviving topology — some stage had
    /// lost every replica. Carries the new partition layer sizes.
    Repartitioned(Vec<usize>),
}

/// Atomic churn counters accumulated by the heal watchdog; snapshotted
/// into [`ChurnStats`] for reports (replay counts merged in from the
/// service, which owns that accounting).
#[derive(Default)]
struct ChurnCounters {
    nodes_died: AtomicU64,
    nodes_returned: AtomicU64,
    heals_replaced: AtomicU64,
    heals_repartitioned: AtomicU64,
}

impl ChurnCounters {
    fn stats(&self) -> ChurnStats {
        ChurnStats {
            nodes_died: self.nodes_died.load(Ordering::Relaxed),
            nodes_returned: self.nodes_returned.load(Ordering::Relaxed),
            heals_replaced: self.heals_replaced.load(Ordering::Relaxed),
            heals_repartitioned: self
                .heals_repartitioned
                .load(Ordering::Relaxed),
            replays_attempted: 0,
            replays_succeeded: 0,
        }
    }
}

/// One co-deployed model: its own manifest, partition plan, deployer,
/// and distributed service, sharing the server's cluster, scheduler,
/// and monitor with every co-resident entry. Created by
/// [`EdgeServer::deploy_model`]; placement packs under each node's
/// memory budget as *already reserved* by earlier deployments, because
/// the shared scheduler scores nodes on remaining memory.
pub struct ModelEntry {
    pub name: String,
    pub config: AmpConfig,
    pub manifest: Arc<Manifest>,
    pub deployer: Arc<ModelDeployer>,
    service: Arc<DistributedService>,
    plan: Mutex<Plan>,
}

impl ModelEntry {
    pub fn service(&self) -> Arc<DistributedService> {
        Arc::clone(&self.service)
    }

    /// Current partition plan (clone; plans are small).
    pub fn plan(&self) -> Plan {
        self.plan.lock().unwrap().clone()
    }

    /// Every node hosting any replica of this model's live deployment.
    pub fn node_set(&self) -> HashSet<usize> {
        self.service.all_deployment_nodes()
    }

    /// A fresh request-level ingress over this model, with the per-
    /// tenant WFQ weights from its own config. Co-deployed models do
    /// not share the server's result cache — a cache hit for model A
    /// must never answer model B.
    pub fn handle(&self) -> ServiceHandle {
        ServiceHandle::new(self.service(), self.config.ingress_config(), None)
    }
}

/// The leader.
pub struct EdgeServer {
    pub config: AmpConfig,
    pub manifest: Arc<Manifest>,
    pub cluster: Arc<Cluster>,
    pub scheduler: Arc<Scheduler>,
    pub deployer: Arc<ModelDeployer>,
    pub monitor: MonitorHandle,
    /// Persistent result cache (AMP4EC+Cache); survives across workloads.
    pub cache: Option<Arc<ResultCache>>,
    service: Arc<DistributedService>,
    plan: std::sync::Mutex<Plan>,
    /// Named co-deployed models packed onto this server's cluster
    /// alongside the primary deployment (ISSUE 9).
    models: crate::tenancy::ModelRegistry<ModelEntry>,
    /// Churn counters shared with the heal watchdog thread.
    churn: Arc<ChurnCounters>,
    /// Lazily-built long-lived ingress for the one-request convenience
    /// paths ([`single_request`], [`EdgeServer::golden_check`]): one
    /// worker, no batch-fill wait, no cache, no default deadline —
    /// built once instead of spawning an ingress per call.
    one_shot: std::sync::OnceLock<ServiceHandle>,
}

impl EdgeServer {
    /// Build the full stack from a config. Loads the manifest, spins up
    /// the cluster + monitor, plans partitions, and deploys.
    pub fn start(config: AmpConfig) -> Result<EdgeServer> {
        Self::start_with_plan(config, None)
    }

    /// Like [`EdgeServer::start`] but with a caller-supplied partition
    /// plan (e.g. profile-guided via `partitioner::plan_measured`).
    pub fn start_with_plan(
        config: AmpConfig,
        plan_override: Option<Plan>,
    ) -> Result<EdgeServer> {
        config.validate()?;
        let manifest = Arc::new(
            Manifest::load(&config.artifacts_dir).context("loading manifest")?,
        );
        anyhow::ensure!(
            manifest.batch_sizes.contains(&config.batch),
            "batch {} not in manifest batch sizes {:?}",
            config.batch,
            manifest.batch_sizes
        );

        let cluster = Arc::new(Cluster::new(config.sim_params()));
        for n in &config.nodes {
            cluster.add_node(n.to_spec());
        }
        let monitor = monitor::spawn(Arc::clone(&cluster), config.monitor_config());

        let scheduler = Arc::new(
            Scheduler::new(config.weights)
                .with_thresholds(config.overload_threshold, config.latency_threshold_ms),
        );

        let n_parts = config
            .num_partitions
            .unwrap_or_else(|| cluster.online_count())
            .min(manifest.blocks.len())
            .max(1);
        let plan = match plan_override {
            Some(p) => p,
            None if config.profiled_partitioning => {
                let block_ms = calibrate_block_costs(&manifest, config.batch)?;
                let weights: Vec<f64> =
                    config.nodes.iter().map(|n| n.cpu).collect();
                let weights = if weights.len() == n_parts {
                    weights
                } else {
                    vec![1.0; n_parts]
                };
                partitioner::plan_measured_weighted(
                    &manifest, &block_ms, &weights,
                )?
            }
            None if config.weighted_partitioning => {
                let weights: Vec<f64> =
                    config.nodes.iter().map(|n| n.cpu).collect();
                let weights = if weights.len() == n_parts {
                    weights
                } else {
                    vec![1.0; n_parts]
                };
                partitioner::plan_weighted(&manifest, &weights)?
            }
            None => partitioner::plan(&manifest, n_parts)?,
        };

        // Scale-out: distribute the policy's extra-replica budget over
        // stages bottleneck-first on the plan's per-partition costs, so
        // a skewed profile concentrates copies on its hottest stage.
        let replica_counts = if config.replicas.is_off() {
            vec![1; plan.partitions.len()]
        } else {
            let spare = cluster
                .online_count()
                .saturating_sub(plan.partitions.len());
            let costs: Vec<f64> =
                plan.partitions.iter().map(|p| p.cost as f64).collect();
            partitioner::replica_counts(
                &costs,
                config.replicas.extra_budget(spare),
            )
        };

        let mut deployer = ModelDeployer::new(Arc::clone(&manifest));
        deployer.use_model_cache = config.model_cache;
        let deployer = Arc::new(deployer);
        if config.model_cache {
            // Warm deployment: ship once so the measured run reuses the
            // node-local model cache (the +Cache configuration). Warm
            // the replica placements too — their nodes cache as well.
            let warm = deployer.deploy_replicated(
                &plan,
                &cluster,
                &scheduler,
                config.batch,
                &replica_counts,
            )?;
            deployer.undeploy(&warm);
        }
        let deployment = Arc::new(deployer.deploy_replicated(
            &plan,
            &cluster,
            &scheduler,
            config.batch,
            &replica_counts,
        )?);

        let pipeline_depth = config.pipeline_depth.max(1);
        let adaptive = config.adaptive_depth.then(|| {
            engine::AdaptiveDepthConfig {
                max_depth: config.max_pipeline_depth.max(pipeline_depth),
                ..engine::AdaptiveDepthConfig::default()
            }
        });
        let wire = match config.transport {
            TransportKind::Inproc => None,
            kind => {
                let mut w = transport::WireConfig::new(
                    kind,
                    config.agent_addrs()?,
                    config.sim_params(),
                    config.artifacts_dir.clone(),
                );
                w.execute_timeout = config
                    .wire_execute_timeout_ms
                    .map(|t| std::time::Duration::from_secs_f64(t / 1e3));
                Some(w)
            }
        };
        let pipeline_engine = DistributedService::build_engine(
            &deployment,
            pipeline_depth,
            adaptive,
            config.per_stage_windows,
            config.coalesce,
            wire.as_ref(),
            config.heal,
            config.hedge,
            None,
        )?;
        let service = Arc::new(DistributedService {
            deployment: RwLock::new(deployment),
            scheduler: Arc::clone(&scheduler),
            pipeline_depth,
            adaptive,
            per_stage_windows: config.per_stage_windows,
            coalesce: config.coalesce,
            wire,
            engine: Mutex::new(pipeline_engine),
            stage_counters: Arc::new(crate::metrics::StageCounterSet::new()),
            heal: config.heal,
            hedge: config.hedge,
            replay_base: ReplayBase::default(),
        });

        let cache = config.cache_entries.map(|n| Arc::new(ResultCache::new(n)));
        Ok(EdgeServer {
            config,
            manifest,
            cluster,
            scheduler,
            deployer,
            monitor,
            cache,
            service,
            plan: std::sync::Mutex::new(plan),
            models: crate::tenancy::ModelRegistry::new(),
            churn: Arc::new(ChurnCounters::default()),
            one_shot: std::sync::OnceLock::new(),
        })
    }

    /// Current partition plan (clone; plans are small).
    pub fn plan(&self) -> Plan {
        self.plan.lock().unwrap().clone()
    }

    pub fn service(&self) -> Arc<DistributedService> {
        Arc::clone(&self.service)
    }

    /// Co-deploy another model onto this server's cluster under `name`
    /// (ISSUE 9). The entry gets its own manifest, plan, deployer, and
    /// engine, but placement runs through the **shared** scheduler —
    /// its scoring reads each node's remaining memory, so the new
    /// model's stages pack around whatever the primary deployment and
    /// earlier entries already reserved (the PR-7 `mem_reserve` guard).
    /// A duplicate name is an error; nothing is leaked on failure.
    pub fn deploy_model(
        &self,
        name: &str,
        config: AmpConfig,
    ) -> Result<Arc<ModelEntry>> {
        config.validate()?;
        let manifest = Arc::new(
            Manifest::load(&config.artifacts_dir)
                .with_context(|| format!("loading manifest for '{name}'"))?,
        );
        anyhow::ensure!(
            manifest.batch_sizes.contains(&config.batch),
            "model '{name}': batch {} not in manifest batch sizes {:?}",
            config.batch,
            manifest.batch_sizes
        );
        let online = self.cluster.online_count();
        let n_parts = config
            .num_partitions
            .unwrap_or(online)
            .min(manifest.blocks.len())
            .max(1);
        let plan = partitioner::plan(&manifest, n_parts)?;
        let replica_counts = if config.replicas.is_off() {
            vec![1; plan.partitions.len()]
        } else {
            let spare = online.saturating_sub(plan.partitions.len());
            let costs: Vec<f64> =
                plan.partitions.iter().map(|p| p.cost as f64).collect();
            partitioner::replica_counts(
                &costs,
                config.replicas.extra_budget(spare),
            )
        };
        let mut deployer = ModelDeployer::new(Arc::clone(&manifest));
        deployer.use_model_cache = config.model_cache;
        let deployer = Arc::new(deployer);
        let deployment = Arc::new(deployer.deploy_replicated(
            &plan,
            &self.cluster,
            &self.scheduler,
            config.batch,
            &replica_counts,
        )?);
        let pipeline_depth = config.pipeline_depth.max(1);
        let adaptive = config.adaptive_depth.then(|| {
            engine::AdaptiveDepthConfig {
                max_depth: config.max_pipeline_depth.max(pipeline_depth),
                ..engine::AdaptiveDepthConfig::default()
            }
        });
        // Co-deployed entries run in-process; the wire transport stays
        // the primary deployment's concern.
        let pipeline_engine = match DistributedService::build_engine(
            &deployment,
            pipeline_depth,
            adaptive,
            config.per_stage_windows,
            config.coalesce,
            None,
            config.heal,
            config.hedge,
            None,
        ) {
            Ok(e) => e,
            Err(e) => {
                deployer.undeploy(&deployment);
                return Err(e);
            }
        };
        let service = Arc::new(DistributedService {
            deployment: RwLock::new(deployment),
            scheduler: Arc::clone(&self.scheduler),
            pipeline_depth,
            adaptive,
            per_stage_windows: config.per_stage_windows,
            coalesce: config.coalesce,
            wire: None,
            engine: Mutex::new(pipeline_engine),
            stage_counters: Arc::new(crate::metrics::StageCounterSet::new()),
            heal: config.heal,
            hedge: config.hedge,
            replay_base: ReplayBase::default(),
        });
        let entry = Arc::new(ModelEntry {
            name: name.to_string(),
            config,
            manifest,
            deployer,
            service,
            plan: Mutex::new(plan),
        });
        if let Err(e) = self.models.insert(name, Arc::clone(&entry)) {
            // Duplicate name: release everything just deployed.
            let dep = Arc::clone(&*entry.service.deployment.read().unwrap());
            entry.deployer.undeploy(&dep);
            return Err(e);
        }
        Ok(entry)
    }

    /// Remove the model deployed under `name`, releasing its node
    /// memory and executor blocks. In-flight requests holding the
    /// entry's `Arc` drain against it first — the registry drops its
    /// reference, not the deployment.
    pub fn undeploy_model(&self, name: &str) -> Result<()> {
        let entry = self.models.remove(name).ok_or_else(|| {
            anyhow::anyhow!("no model deployed under '{name}'")
        })?;
        let dep = Arc::clone(&*entry.service.deployment.read().unwrap());
        entry.deployer.undeploy(&dep);
        Ok(())
    }

    /// Registry entry for `name`, if deployed.
    pub fn model(&self, name: &str) -> Option<Arc<ModelEntry>> {
        self.models.get(name)
    }

    /// A fresh serving ingress over the model deployed under `name`.
    pub fn model_handle(&self, name: &str) -> Option<ServiceHandle> {
        self.models.get(name).map(|e| e.handle())
    }

    /// Names of every co-deployed model (the primary deployment is not
    /// a registry entry).
    pub fn model_names(&self) -> Vec<String> {
        self.models.names()
    }

    /// Input tensor shape for a single request (batch dim = 1).
    pub fn request_shape(&self) -> Vec<usize> {
        vec![1, self.manifest.input_hw, self.manifest.input_hw,
             self.manifest.input_channels]
    }

    /// The unified request-level serving ingress over this server's
    /// distributed service: build requests with
    /// `handle.request(input).priority(..).deadline(..)`, submit, and
    /// wait on the returned `ResponseHandle`. Every call spawns a fresh
    /// ingress (bounded priority queue + dispatcher + worker pool, per
    /// [`AmpConfig::ingress_config`]) sharing the server's persistent
    /// result cache; `finish()` drains it and returns the run's
    /// [`RunMetrics`] including the per-class breakdown.
    pub fn serve_handle(&self) -> ServiceHandle {
        ServiceHandle::new(
            self.service(),
            self.config.ingress_config(),
            self.cache.clone(),
        )
    }

    /// Run a closed- or open-loop workload of `n` requests drawn from
    /// `distinct` inputs; returns the full report.
    pub fn serve_workload(
        &self,
        n: usize,
        distinct: usize,
        arrival: Arrival,
        seed: u64,
    ) -> Result<ServeReport> {
        let pool = InputPool::new(&self.request_shape(), distinct, seed);
        // Live-profile window retune (ROADMAP follow-on): with
        // per-stage windows on, reshape the engine's budgets from the
        // monitor's latest snapshot before the run — a no-op until the
        // engine has served traffic to profile.
        if self.config.per_stage_windows {
            if let Some(snapshot) = self.monitor.latest() {
                self.service.retune_windows(&snapshot);
            }
        }
        // Data-plane / pool / wire counters are process-global; snapshot
        // around the run so the report shows *this run's* movement.
        let dp0 = crate::metrics::data_plane::snapshot();
        let pool0 = crate::util::pool::BufferPool::global().stats();
        let wire0 = crate::metrics::wire::snapshot();
        let handle = self.serve_handle();
        feed(&handle, &pool, n, arrival, seed ^ 0xF00D);
        let metrics = handle.finish();
        let data_plane = crate::metrics::data_plane::snapshot().since(&dp0);
        let pool_stats =
            crate::util::pool::BufferPool::global().stats().since(&pool0);
        let wire = (self.config.transport != TransportKind::Inproc)
            .then(|| crate::metrics::wire::snapshot().since(&wire0));

        let dep = Arc::clone(&*self.service.deployment.read().unwrap());
        let (final_depth, depth_report) = self.service.depth_status();
        let (stage_budgets, coalesce_stats) = self.service.window_status();
        // The engine is authoritative for the replica map (wire chains
        // replicate at the connection layer); a serial run reports the
        // deployment's placement.
        let (replica_map, replica_counters) =
            match &*self.service.engine.lock().unwrap() {
                Some(e) => (e.replica_nodes().to_vec(), e.replica_counters()),
                None => (dep.replica_node_ids(), Vec::new()),
            };
        let snapshot = self.monitor.latest();
        Ok(ServeReport {
            model: "primary".to_string(),
            metrics,
            monitor_overhead_pct: self.monitor.overhead_cpu_pct(),
            mean_stability: snapshot
                .as_ref()
                .map(|s| s.mean_stability())
                .unwrap_or(1.0),
            deploy_transfer_bytes: dep.transfer_bytes,
            deploy_ms: dep.deploy_ms,
            partition_layer_sizes: self.plan.lock().unwrap().layer_sizes(),
            node_names: self
                .cluster
                .online_nodes()
                .iter()
                .map(|n| n.name().to_string())
                .collect(),
            cache_stats: self.cache.as_ref().map(|c| c.stats()),
            node_energy: self
                .cluster
                .online_nodes()
                .iter()
                .map(|n| {
                    let e = n.energy();
                    (n.name().to_string(), e.total_j, e.compute_j)
                })
                .collect(),
            stage_counters: self.service.stage_counters(),
            final_pipeline_depth: final_depth,
            depth_report,
            stage_budgets,
            coalesce_stats,
            data_plane,
            pool_stats,
            wire,
            replica_map,
            replica_counters,
            churn: self.churn_stats(),
        })
    }

    /// Node-churn + replay accounting since startup: watchdog-observed
    /// deaths/returns, heals performed, and engine micro-batch replays
    /// (accumulated across deployment swaps).
    pub fn churn_stats(&self) -> ChurnStats {
        let mut s = self.churn.stats();
        let replay = self.service.replay_stats();
        s.replays_attempted = replay.attempted;
        s.replays_succeeded = replay.succeeded;
        s
    }

    /// Handle a topology change: re-plan and redeploy over the current
    /// online nodes. Returns the new partition layer sizes.
    pub fn rebalance(&self) -> Result<Vec<usize>> {
        // Snapshot the topology *once*: reading online_count() again for
        // the replica budget let a node leave (or return) between the
        // two reads, sizing the plan for N nodes and the budget for a
        // different N — deploy then over- or under-places replicas.
        let online = self.cluster.online_count();
        let n = online.min(self.manifest.blocks.len()).max(1);
        let plan = partitioner::plan(&self.manifest, n)?;
        // Re-derive the replica budget for the *new* topology: the node
        // that just left may have hosted a replica.
        let replica_counts = if self.config.replicas.is_off() {
            vec![1; plan.partitions.len()]
        } else {
            let spare = online.saturating_sub(plan.partitions.len());
            let costs: Vec<f64> =
                plan.partitions.iter().map(|p| p.cost as f64).collect();
            partitioner::replica_counts(
                &costs,
                self.config.replicas.extra_budget(spare),
            )
        };
        let new_dep = Arc::new(self.deployer.deploy_replicated(
            &plan,
            &self.cluster,
            &self.scheduler,
            self.config.batch,
            &replica_counts,
        )?);
        let old = match self.service.replace_deployment(Arc::clone(&new_dep)) {
            Ok(old) => old,
            Err(e) => {
                // The swap never happened: release the freshly loaded
                // blocks instead of leaking them on the stage executors.
                self.deployer.undeploy(&new_dep);
                return Err(e);
            }
        };
        self.deployer.undeploy(&old);
        let sizes = plan.layer_sizes();
        *self.plan.lock().unwrap() = plan;
        Ok(sizes)
    }

    /// §V extension "dynamic partitioning ... adapt to runtime changes":
    /// spawn a watchdog that rebalances automatically whenever cluster
    /// *membership* changes. Dropping the handle stops it.
    pub fn start_auto_rebalance(
        self: &Arc<Self>,
        interval: std::time::Duration,
    ) -> AutoRebalanceHandle {
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let server = Arc::clone(self);
        let stop_t = Arc::clone(&stop);
        // Baseline captured *before* the thread spawns: a topology change
        // racing thread startup must still be detected.
        let baseline = self.cluster.membership_epoch();
        let thread = std::thread::Builder::new()
            .name("amp4ec-rebalance".into())
            .spawn(move || {
                let mut last = baseline;
                while !stop_t.load(std::sync::atomic::Ordering::SeqCst) {
                    std::thread::sleep(interval);
                    // Membership epoch, not online_count(): an
                    // equal-count leave+join (or a leave and a join
                    // landing inside one poll interval) keeps the count
                    // identical while the membership — and therefore the
                    // right placement — changed underneath it.
                    let now = server.cluster.membership_epoch();
                    if now != last && server.cluster.online_count() > 0 {
                        match server.rebalance() {
                            Ok(sizes) => crate::log_info!(
                                "rebalance",
                                "membership epoch {last} -> {now}; new plan {sizes:?}"
                            ),
                            Err(e) => crate::log_warn!(
                                "rebalance",
                                "failed after topology change: {e:#}"
                            ),
                        }
                        last = now;
                    }
                }
            })
            .expect("spawn rebalance watchdog");
        AutoRebalanceHandle { stop, thread: Some(thread) }
    }

    /// One rung of the heal ladder (self-healing serving): given the
    /// nodes the monitor declared dead, first try the cheap delta —
    /// keep the partition plan and re-place only the dead replicas'
    /// slots ([`ModelDeployer::heal_replace`]; the model cache makes the
    /// surviving re-ship near-free and the learned engine windows carry
    /// over) — and fall back to a full re-partition only when some
    /// stage lost every replica. Counters land in
    /// [`EdgeServer::churn_stats`].
    pub fn heal(&self, dead: &HashSet<usize>) -> Result<HealAction> {
        let old = Arc::clone(&*self.service.deployment.read().unwrap());
        match self
            .deployer
            .heal_replace(&old, dead, &self.cluster, &self.scheduler)
        {
            Ok(new_dep) => {
                let new_dep = Arc::new(new_dep);
                let old = match self
                    .service
                    .replace_deployment(Arc::clone(&new_dep))
                {
                    Ok(old) => old,
                    Err(e) => {
                        // The swap never happened: release the freshly
                        // loaded blocks instead of leaking them.
                        self.deployer.undeploy(&new_dep);
                        return Err(e);
                    }
                };
                self.deployer.undeploy(&old);
                self.churn.heals_replaced.fetch_add(1, Ordering::Relaxed);
                Ok(HealAction::Replaced)
            }
            Err(e) => {
                crate::log_info!(
                    "heal",
                    "replica re-placement not possible ({e:#}); \
                     falling back to re-partition"
                );
                let sizes = self.rebalance()?;
                self.churn
                    .heals_repartitioned
                    .fetch_add(1, Ordering::Relaxed);
                Ok(HealAction::Repartitioned(sizes))
            }
        }
    }

    /// Deployment-scoped heal across the co-deployment registry: walk
    /// the models and heal only those that actually lost a replica to
    /// `dead`, so one model's churn never redeploys a co-resident
    /// model. Counters land in the same [`EdgeServer::churn_stats`].
    pub fn heal_models(&self, dead: &HashSet<usize>) {
        for (name, entry) in self.models.entries() {
            if entry.node_set().is_disjoint(dead) {
                continue;
            }
            match self.heal_model(&entry, dead) {
                Ok(action) => crate::log_info!(
                    "heal",
                    "model '{name}': {action:?} after losing {dead:?}"
                ),
                Err(e) => crate::log_warn!(
                    "heal",
                    "model '{name}' heal failed: {e:#}"
                ),
            }
        }
    }

    /// The heal ladder for one registry entry: replica re-placement
    /// first, full re-partition over the surviving topology as the
    /// fallback — the per-model twin of [`EdgeServer::heal`].
    fn heal_model(
        &self,
        entry: &ModelEntry,
        dead: &HashSet<usize>,
    ) -> Result<HealAction> {
        let old = Arc::clone(&*entry.service.deployment.read().unwrap());
        match entry.deployer.heal_replace(
            &old,
            dead,
            &self.cluster,
            &self.scheduler,
        ) {
            Ok(new_dep) => {
                let new_dep = Arc::new(new_dep);
                let old = match entry
                    .service
                    .replace_deployment(Arc::clone(&new_dep))
                {
                    Ok(old) => old,
                    Err(e) => {
                        entry.deployer.undeploy(&new_dep);
                        return Err(e);
                    }
                };
                entry.deployer.undeploy(&old);
                self.churn.heals_replaced.fetch_add(1, Ordering::Relaxed);
                Ok(HealAction::Replaced)
            }
            Err(e) => {
                crate::log_info!(
                    "heal",
                    "model '{}': replica re-placement not possible \
                     ({e:#}); falling back to re-partition",
                    entry.name
                );
                let online = self.cluster.online_count();
                let n = online.min(entry.manifest.blocks.len()).max(1);
                let plan = partitioner::plan(&entry.manifest, n)?;
                let replica_counts = if entry.config.replicas.is_off() {
                    vec![1; plan.partitions.len()]
                } else {
                    let spare =
                        online.saturating_sub(plan.partitions.len());
                    let costs: Vec<f64> = plan
                        .partitions
                        .iter()
                        .map(|p| p.cost as f64)
                        .collect();
                    partitioner::replica_counts(
                        &costs,
                        entry.config.replicas.extra_budget(spare),
                    )
                };
                let new_dep = Arc::new(entry.deployer.deploy_replicated(
                    &plan,
                    &self.cluster,
                    &self.scheduler,
                    entry.config.batch,
                    &replica_counts,
                )?);
                let old = match entry
                    .service
                    .replace_deployment(Arc::clone(&new_dep))
                {
                    Ok(old) => old,
                    Err(e) => {
                        entry.deployer.undeploy(&new_dep);
                        return Err(e);
                    }
                };
                entry.deployer.undeploy(&old);
                let sizes = plan.layer_sizes();
                *entry.plan.lock().unwrap() = plan;
                self.churn
                    .heals_repartitioned
                    .fetch_add(1, Ordering::Relaxed);
                Ok(HealAction::Repartitioned(sizes))
            }
        }
    }

    /// Spawn the self-healing watchdog: drains the monitor's liveness
    /// transitions every `interval` and walks the heal ladder for each
    /// batch of deaths ([`EdgeServer::heal`]); a `Returned` node is
    /// re-admitted to the spare pool (warm re-admission — its model
    /// cache still holds whatever was shipped before it left). Dropping
    /// the handle stops the thread. Liveness detection latency is
    /// `miss_threshold * monitor_interval_ms` plus up to one `interval`.
    pub fn start_heal_watchdog(
        self: &Arc<Self>,
        interval: std::time::Duration,
    ) -> AutoRebalanceHandle {
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let server = Arc::clone(self);
        let stop_t = Arc::clone(&stop);
        let thread = std::thread::Builder::new()
            .name("amp4ec-heal".into())
            .spawn(move || {
                while !stop_t.load(std::sync::atomic::Ordering::SeqCst) {
                    std::thread::sleep(interval);
                    let events = server.monitor.drain_events();
                    if events.is_empty() {
                        continue;
                    }
                    let mut died: HashSet<usize> = HashSet::new();
                    for ev in events {
                        match ev {
                            NodeEvent::Died { node, .. } => {
                                died.insert(node);
                                server
                                    .churn
                                    .nodes_died
                                    .fetch_add(1, Ordering::Relaxed);
                            }
                            NodeEvent::Returned { node, .. } => {
                                // Warm re-admission: make sure the
                                // cluster sees the node as spare
                                // capacity again (idempotent when
                                // whoever revived it already did).
                                server.cluster.readmit_node(node);
                                server
                                    .churn
                                    .nodes_returned
                                    .fetch_add(1, Ordering::Relaxed);
                                died.remove(&node);
                            }
                        }
                    }
                    if died.is_empty() {
                        continue;
                    }
                    // Fold in anything still dead from earlier rounds —
                    // a heal that failed last tick retries here with the
                    // full dead set.
                    died.extend(server.monitor.dead_nodes());
                    // Deployment-scoped (ISSUE 9): the primary heals
                    // only when it actually lost a replica — a death
                    // that hit only a co-deployed model (or a spare)
                    // must not redeploy it.
                    let primary_hit = !server
                        .service
                        .all_deployment_nodes()
                        .is_disjoint(&died);
                    if primary_hit {
                        match server.heal(&died) {
                            Ok(HealAction::Replaced) => crate::log_info!(
                                "heal",
                                "replaced dead replicas of {died:?} \
                                 in place"
                            ),
                            Ok(HealAction::Repartitioned(sizes)) => {
                                crate::log_info!(
                                    "heal",
                                    "re-partitioned around {died:?}; \
                                     new plan {sizes:?}"
                                )
                            }
                            Err(e) => crate::log_warn!(
                                "heal",
                                "failed after losing {died:?}: {e:#}"
                            ),
                        }
                    }
                    server.heal_models(&died);
                }
            })
            .expect("spawn heal watchdog");
        AutoRebalanceHandle { stop, thread: Some(thread) }
    }

    /// Golden parity: run the manifest's recorded input through the
    /// deployed pipeline — via the same unified serving ingress every
    /// other entry point uses — and compare against the AOT-recorded
    /// output.
    pub fn golden_check(&self) -> Result<f32> {
        let golden = self
            .manifest
            .golden
            .as_ref()
            .context("manifest has no golden pair")?;
        anyhow::ensure!(
            golden.batch == 1,
            "golden parity assumes batch-1 recording"
        );
        let input = Tensor::from_f32_file(
            &self.manifest.dir.join(&golden.input_file),
            golden.in_shape.clone(),
        )?;
        let want = Tensor::from_f32_file(
            &self.manifest.dir.join(&golden.output_file),
            golden.out_shape.clone(),
        )?;
        // One request through a one-shot ingress: no batch-fill wait for
        // a lone request, no result cache (parity must hit the
        // pipeline), and no default deadline (parity must never shed).
        let out = one_shot_handle(self).submit(input)?.wait_output()?;
        let diff = out.max_abs_diff(&want);
        anyhow::ensure!(
            (diff as f64) <= golden.tolerance * 10.0,
            "golden mismatch: max abs diff {diff}"
        );
        Ok(diff)
    }
}

/// The server's shared single-request ingress (see
/// [`EdgeServer::one_shot`]'s field docs), built on first use.
/// [`single_request`] and [`EdgeServer::golden_check`] ride this so
/// even the one-off convenience paths go through the unified serving
/// API without paying an ingress spawn per call.
fn one_shot_handle(server: &EdgeServer) -> &ServiceHandle {
    server.one_shot.get_or_init(|| {
        let mut cfg = server.config.ingress_config();
        cfg.workers = 1;
        cfg.max_wait = std::time::Duration::ZERO;
        cfg.default_deadline = None;
        ServiceHandle::new(server.service(), cfg, None)
    })
}

/// Handle to the auto-rebalance watchdog; dropping stops the thread.
pub struct AutoRebalanceHandle {
    stop: Arc<std::sync::atomic::AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Drop for AutoRebalanceHandle {
    fn drop(&mut self) {
        self.stop.store(true, std::sync::atomic::Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// One-shot calibration: measured per-block execution time at `batch`
/// (thread-CPU ms on a scratch executor). Used by profile-guided
/// partitioning and the scalability bench.
pub fn calibrate_block_costs(
    manifest: &Manifest,
    batch: usize,
) -> Result<Vec<f64>> {
    let exec = Executor::spawn("calibrate")?;
    let mut out = Vec::with_capacity(manifest.blocks.len());
    let mut act = Tensor::zeros(vec![
        batch,
        manifest.input_hw,
        manifest.input_hw,
        manifest.input_channels,
    ]);
    for b in &manifest.blocks {
        let out_shape =
            vec![batch, b.out_shape[0], b.out_shape[1], b.out_shape[2]];
        let h = exec.load_block(
            manifest.artifact_path(b, batch)?,
            manifest.weights_path(b),
            b.param_count as usize,
            out_shape,
        )?;
        // Warm once, then one timed run (relative weights are all the
        // planner needs).
        let (_, _) = exec.run_chain(vec![h], act.clone())?;
        let (next, ms) = exec.run_chain(vec![h], act)?;
        act = next;
        out.push(ms);
    }
    Ok(out)
}

/// Convenience used by benches: a one-request-at-a-time helper, riding
/// the unified serving ingress (one-shot handle, no batching wait).
/// Returns the request's output row and its end-to-end wall latency.
pub fn single_request(
    server: &EdgeServer,
    input: &Tensor,
) -> Result<(Tensor, f64)> {
    let handle = one_shot_handle(server);
    let t0 = std::time::Instant::now();
    let out = handle.submit(input.clone())?.wait_output()?;
    Ok((out, t0.elapsed().as_secs_f64() * 1e3))
}

/// Effective per-stage latency profile from the engine's cumulative
/// counters and the monitor's live snapshot: each stage's measured
/// per-micro-batch service time (compute + ingress comm) scaled by its
/// node's current load — a node half-busy with other work serves at
/// roughly double the empty-node latency, so its stage weighs heavier
/// when `budgets_from_profile` re-shapes the credit windows. Returns
/// None until every stage has served at least one micro-batch (a cold
/// profile would shape windows from noise).
pub fn live_stage_latencies(
    counters: &[StageCounter],
    snapshot: &ClusterSnapshot,
) -> Option<Vec<f64>> {
    if counters.is_empty() || counters.iter().any(|c| c.micro_batches == 0) {
        return None;
    }
    Some(
        counters
            .iter()
            .map(|c| {
                let per_micro =
                    (c.busy_ms + c.comm_ms) / c.micro_batches as f64;
                let load = snapshot
                    .nodes
                    .iter()
                    .find(|n| n.id == c.node)
                    .map(|n| n.current_load.clamp(0.0, 1.0))
                    .unwrap_or(0.0);
                per_micro * (1.0 + load)
            })
            .collect(),
    )
}
