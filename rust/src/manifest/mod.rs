//! Model manifest: the contract between the python AOT path and the rust
//! coordinator.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing the
//! 20 AOT blocks of MobileNetV2, each with its HLO artifact paths, weight
//! sidecar, tensor shapes, and — crucially for the paper — the flat
//! 141-entry *module list* (52 Conv2d + 52 BatchNorm2d + 35 ReLU6 +
//! Dropout + Linear) whose per-layer costs drive AMP4EC's partitioner.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// The kind of a model layer, as the paper's Eq. 9 distinguishes them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    Conv2d,
    Linear,
    BatchNorm2d,
    ReLU6,
    Dropout,
    Other,
}

impl LayerKind {
    fn from_str(s: &str) -> LayerKind {
        match s {
            "Conv2d" => LayerKind::Conv2d,
            "Linear" => LayerKind::Linear,
            "BatchNorm2d" => LayerKind::BatchNorm2d,
            "ReLU6" => LayerKind::ReLU6,
            "Dropout" => LayerKind::Dropout,
            _ => LayerKind::Other,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            LayerKind::Conv2d => "Conv2d",
            LayerKind::Linear => "Linear",
            LayerKind::BatchNorm2d => "BatchNorm2d",
            LayerKind::ReLU6 => "ReLU6",
            LayerKind::Dropout => "Dropout",
            LayerKind::Other => "Other",
        }
    }
}

/// One flat module entry (paper §III-B "Layer Analysis").
#[derive(Debug, Clone)]
pub struct LayerMeta {
    pub name: String,
    pub kind: LayerKind,
    pub params: u64,
    // Conv2d attributes (0 when not applicable).
    pub k_h: u32,
    pub k_w: u32,
    pub c_in: u32,
    pub c_out: u32,
    pub groups: u32,
    pub stride: u32,
    // Linear attributes.
    pub n_in: u32,
    pub n_out: u32,
}

/// One AOT block: the smallest unit the deployer can place on a node.
#[derive(Debug, Clone)]
pub struct BlockMeta {
    pub index: usize,
    pub name: String,
    /// (H, W, C) activation shapes; batch dim is added at runtime.
    pub in_shape: [usize; 3],
    pub out_shape: [usize; 3],
    pub param_count: u64,
    pub weights_file: String,
    pub weights_bytes: u64,
    /// batch size -> HLO text artifact file name.
    pub artifacts: BTreeMap<usize, String>,
    pub layers: Vec<LayerMeta>,
}

impl BlockMeta {
    /// Bytes of the activation tensor leaving this block at `batch`.
    pub fn output_bytes(&self, batch: usize) -> u64 {
        (batch * self.out_shape.iter().product::<usize>() * 4) as u64
    }

    pub fn input_bytes(&self, batch: usize) -> u64 {
        (batch * self.in_shape.iter().product::<usize>() * 4) as u64
    }
}

/// Golden parity pair recorded by the AOT export.
#[derive(Debug, Clone)]
pub struct GoldenMeta {
    pub input_file: String,
    pub output_file: String,
    pub batch: usize,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub tolerance: f64,
}

/// The monolithic whole-model artifact (the paper's baseline comparator).
#[derive(Debug, Clone)]
pub struct MonolithicMeta {
    pub weights_file: String,
    pub weights_bytes: u64,
    pub artifacts: BTreeMap<usize, String>,
}

/// Parsed manifest + the directory its files live in.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: String,
    pub input_hw: usize,
    pub input_channels: usize,
    pub num_classes: usize,
    pub batch_sizes: Vec<usize>,
    pub total_params: u64,
    pub blocks: Vec<BlockMeta>,
    pub monolithic: Option<MonolithicMeta>,
    pub golden: Option<GoldenMeta>,
}

fn parse_shape3(j: &Json, key: &str) -> Result<[usize; 3]> {
    let arr = j.req_arr(key)?;
    anyhow::ensure!(arr.len() == 3, "shape `{key}` must have 3 dims");
    Ok([
        arr[0].as_usize().context("shape dim")?,
        arr[1].as_usize().context("shape dim")?,
        arr[2].as_usize().context("shape dim")?,
    ])
}

fn parse_artifacts(j: &Json) -> Result<BTreeMap<usize, String>> {
    let obj = j
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("`artifacts` is not an object"))?;
    let mut out = BTreeMap::new();
    for (k, v) in obj {
        let batch: usize = k.parse().context("artifact batch key")?;
        let file = v
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("artifact path not a string"))?;
        out.insert(batch, file.to_string());
    }
    Ok(out)
}

fn parse_layer(j: &Json) -> Result<LayerMeta> {
    let num = |key: &str| -> u32 {
        j.get(key).and_then(Json::as_u64).unwrap_or(0) as u32
    };
    Ok(LayerMeta {
        name: j.req_str("name")?.to_string(),
        kind: LayerKind::from_str(j.req_str("type")?),
        params: j.get("params").and_then(Json::as_u64).unwrap_or(0),
        k_h: num("k_h"),
        k_w: num("k_w"),
        c_in: num("c_in"),
        c_out: num("c_out"),
        groups: num("groups").max(1),
        stride: num("stride").max(1),
        n_in: num("n_in"),
        n_out: num("n_out"),
    })
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let mut blocks = Vec::new();
        for bj in j.req_arr("blocks")? {
            let layers = bj
                .req_arr("layers")?
                .iter()
                .map(parse_layer)
                .collect::<Result<Vec<_>>>()?;
            blocks.push(BlockMeta {
                index: bj.req_usize("index")?,
                name: bj.req_str("name")?.to_string(),
                in_shape: parse_shape3(bj, "in_shape")?,
                out_shape: parse_shape3(bj, "out_shape")?,
                param_count: bj.req_f64("param_count")? as u64,
                weights_file: bj.req_str("weights_file")?.to_string(),
                weights_bytes: bj.req_f64("weights_bytes")? as u64,
                artifacts: parse_artifacts(bj.req("artifacts")?)?,
                layers,
            });
        }
        anyhow::ensure!(!blocks.is_empty(), "manifest has no blocks");
        for (i, b) in blocks.iter().enumerate() {
            anyhow::ensure!(b.index == i, "block indices must be dense");
        }
        // Shapes must chain between consecutive feature blocks.
        for pair in blocks.windows(2) {
            if pair[1].name != "classifier" {
                anyhow::ensure!(
                    pair[0].out_shape == pair[1].in_shape,
                    "shape mismatch {} -> {}",
                    pair[0].name,
                    pair[1].name
                );
            }
        }

        let monolithic = match j.get("monolithic") {
            Some(m) => Some(MonolithicMeta {
                weights_file: m.req_str("weights_file")?.to_string(),
                weights_bytes: m.req_f64("weights_bytes")? as u64,
                artifacts: parse_artifacts(m.req("artifacts")?)?,
            }),
            None => None,
        };
        let golden = match j.get("golden") {
            Some(g) => Some(GoldenMeta {
                input_file: g.req_str("input")?.to_string(),
                output_file: g.req_str("output")?.to_string(),
                batch: g.req_usize("batch")?,
                in_shape: g
                    .req_arr("in_shape")?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect(),
                out_shape: g
                    .req_arr("out_shape")?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect(),
                tolerance: g.req_f64("tolerance")?,
            }),
            None => None,
        };

        Ok(Manifest {
            dir: dir.to_path_buf(),
            model: j.req_str("model")?.to_string(),
            input_hw: j.req_usize("input_hw")?,
            input_channels: j.req_usize("input_channels")?,
            num_classes: j.req_usize("num_classes")?,
            batch_sizes: j
                .req_arr("batch_sizes")?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            total_params: j.req_f64("total_params")? as u64,
            blocks,
            monolithic,
            golden,
        })
    }

    /// The flat module list across all blocks, in execution order.
    pub fn flat_layers(&self) -> Vec<&LayerMeta> {
        self.blocks.iter().flat_map(|b| b.layers.iter()).collect()
    }

    /// Global layer index at which each block starts, plus the total count.
    /// Used to snap layer-granular partition boundaries to feasible
    /// (block-aligned) cut points.
    pub fn block_layer_offsets(&self) -> Vec<usize> {
        let mut offsets = Vec::with_capacity(self.blocks.len() + 1);
        let mut acc = 0;
        for b in &self.blocks {
            offsets.push(acc);
            acc += b.layers.len();
        }
        offsets.push(acc);
        offsets
    }

    pub fn artifact_path(&self, block: &BlockMeta, batch: usize) -> Result<PathBuf> {
        let file = block.artifacts.get(&batch).ok_or_else(|| {
            anyhow::anyhow!(
                "block {} has no artifact for batch {batch}",
                block.name
            )
        })?;
        Ok(self.dir.join(file))
    }

    pub fn weights_path(&self, block: &BlockMeta) -> PathBuf {
        self.dir.join(&block.weights_file)
    }

    /// Total model-transfer payload for a set of blocks (deployment cost).
    pub fn weights_bytes_for(&self, range: std::ops::Range<usize>) -> u64 {
        self.blocks[range].iter().map(|b| b.weights_bytes).sum()
    }
}

#[cfg(test)]
pub mod testutil {
    use super::*;

    /// A small synthetic manifest (not MobileNetV2) for unit tests that
    /// don't want to depend on the artifacts directory.
    pub fn tiny_manifest() -> Manifest {
        let mk_layer = |name: &str, kind: LayerKind, cin: u32, cout: u32| LayerMeta {
            name: name.into(),
            kind,
            params: (cin * cout) as u64,
            k_h: if kind == LayerKind::Conv2d { 3 } else { 0 },
            k_w: if kind == LayerKind::Conv2d { 3 } else { 0 },
            c_in: cin,
            c_out: cout,
            groups: 1,
            stride: 1,
            n_in: if kind == LayerKind::Linear { cin } else { 0 },
            n_out: if kind == LayerKind::Linear { cout } else { 0 },
        };
        let block = |index: usize, name: &str, cin, cout, layers| BlockMeta {
            index,
            name: name.into(),
            in_shape: [8, 8, cin],
            out_shape: [8, 8, cout],
            param_count: 100,
            weights_file: format!("b{index}.bin"),
            weights_bytes: 400,
            artifacts: BTreeMap::from([(1, format!("b{index}.hlo.txt"))]),
            layers,
        };
        Manifest {
            dir: PathBuf::from("/nonexistent"),
            model: "tiny".into(),
            input_hw: 8,
            input_channels: 4,
            num_classes: 10,
            batch_sizes: vec![1],
            total_params: 300,
            blocks: vec![
                block(0, "a", 4, 8, vec![
                    mk_layer("a.conv", LayerKind::Conv2d, 4, 8),
                    mk_layer("a.bn", LayerKind::BatchNorm2d, 0, 0),
                ]),
                block(1, "b", 8, 8, vec![
                    mk_layer("b.conv", LayerKind::Conv2d, 8, 8),
                ]),
                block(2, "c", 8, 10, vec![
                    mk_layer("c.fc", LayerKind::Linear, 8, 10),
                ]),
            ],
            monolithic: None,
            golden: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "model": "mobilenet_v2", "version": 1, "input_hw": 96,
        "input_channels": 3, "num_classes": 1000, "batch_sizes": [1, 8],
        "seed": 0, "total_params": 500,
        "blocks": [
            {"index": 0, "name": "stem", "in_shape": [96,96,3],
             "out_shape": [48,48,32], "param_count": 300,
             "weights_file": "block_00.weights.bin", "weights_bytes": 1200,
             "weights_sha256": "x",
             "artifacts": {"1": "block_00_b1.hlo.txt", "8": "block_00_b8.hlo.txt"},
             "layers": [
                {"name":"features.0.0","type":"Conv2d","params":864,
                 "k_h":3,"k_w":3,"c_in":3,"c_out":32,"groups":1,"stride":2,
                 "n_in":0,"n_out":0},
                {"name":"features.0.1","type":"BatchNorm2d","params":64,
                 "k_h":0,"k_w":0,"c_in":0,"c_out":0,"groups":1,"stride":1,
                 "n_in":0,"n_out":0}
             ]},
            {"index": 1, "name": "classifier", "in_shape": [48,48,32],
             "out_shape": [1,1,10], "param_count": 200,
             "weights_file": "block_01.weights.bin", "weights_bytes": 800,
             "artifacts": {"1": "block_01_b1.hlo.txt"},
             "layers": [
                {"name":"classifier.1","type":"Linear","params":330,
                 "k_h":0,"k_w":0,"c_in":0,"c_out":0,"groups":1,"stride":1,
                 "n_in":32,"n_out":10}
             ]}
        ],
        "monolithic": {"weights_file": "model.weights.bin",
                       "weights_bytes": 2000,
                       "artifacts": {"1": "model_b1.hlo.txt"}},
        "golden": {"input": "golden_input_b1.bin",
                   "output": "golden_output_b1.bin", "batch": 1,
                   "in_shape": [1,96,96,3], "out_shape": [1,1000],
                   "tolerance": 0.001}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.model, "mobilenet_v2");
        assert_eq!(m.blocks.len(), 2);
        assert_eq!(m.blocks[0].layers[0].kind, LayerKind::Conv2d);
        assert_eq!(m.blocks[0].layers[0].c_out, 32);
        assert_eq!(m.blocks[0].artifacts[&8], "block_00_b8.hlo.txt");
        assert_eq!(m.batch_sizes, vec![1, 8]);
        let g = m.golden.as_ref().unwrap();
        assert_eq!(g.tolerance, 0.001);
        assert_eq!(m.monolithic.as_ref().unwrap().weights_bytes, 2000);
    }

    #[test]
    fn output_bytes() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.blocks[0].output_bytes(1), 48 * 48 * 32 * 4);
        assert_eq!(m.blocks[0].output_bytes(8), 8 * 48 * 48 * 32 * 4);
    }

    #[test]
    fn flat_layers_and_offsets() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.flat_layers().len(), 3);
        assert_eq!(m.block_layer_offsets(), vec![0, 2, 3]);
    }

    #[test]
    fn rejects_shape_mismatch() {
        let bad = SAMPLE.replace("\"in_shape\": [48,48,32]", "\"in_shape\": [24,24,32]");
        // classifier block is exempt from chaining (pool changes shape),
        // so corrupt the first block's out_shape instead
        let bad2 = bad.replace("\"out_shape\": [48,48,32]", "\"out_shape\": [24,24,3]");
        let _ = bad2; // classifier exemption means this still parses
        // A dense-index violation is always rejected:
        let bad3 = SAMPLE.replace("\"index\": 1", "\"index\": 5");
        assert!(Manifest::parse(&bad3, Path::new("/tmp/a")).is_err());
    }

    #[test]
    fn tiny_manifest_is_consistent() {
        let m = testutil::tiny_manifest();
        assert_eq!(m.flat_layers().len(), 4);
        assert_eq!(m.block_layer_offsets(), vec![0, 2, 3, 4]);
        assert_eq!(m.weights_bytes_for(0..2), 800);
    }
}
