//! `amp4ec` CLI — the leader entrypoint.
//!
//! ```text
//! amp4ec info        [--artifacts DIR]
//! amp4ec partition   [--artifacts DIR] [--parts N]
//! amp4ec serve       [--artifacts DIR] [--requests N] [--distinct N]
//!                    [--batch B] [--partitions N] [--cache] [--workers N]
//!                    [--depth D]   # streaming pipeline depth (1 = serial)
//!                    [--adaptive-depth] [--max-depth M]  # online window sizing
//!                    [--stage-windows]  # per-stage credit windows
//!                    [--coalesce]       # merge adjacent small miss-sets
//!                    [--replicas auto|K]  # data-parallel copies of hot stages
//!                    [--deadline-ms MS] # default per-request deadline (shed past it)
//!                    [--heal] [--miss-threshold N]  # self-heal under node churn
//!                    [--priority-classes N]  # strict-priority ingress lanes
//!                    [--tenants name=w,...]  # per-tenant WFQ weights
//!                    [--transport inproc|uds|tcp] [--agents a,b,...]  # wire transport
//!                    [--wire-timeout-ms MS]  # per-execute agent deadline
//!                    [--hedge]          # re-issue straggler micro-batches
//! amp4ec node        --listen ADDR      # node agent (socket path or host:port)
//!                    [--transport uds|tcp] [--stay]  # --stay: don't exit when idle
//!                    [--idle-timeout-ms MS]  # stalled-coordinator give-up
//! amp4ec golden      [--artifacts DIR]
//! amp4ec config      [--out FILE]       # write a default config file
//! amp4ec serve-cfg   --config FILE [--requests N]
//! amp4ec calibrate   [--artifacts DIR] [--batch B]  # per-block costs
//! ```

use std::path::PathBuf;

use amp4ec::config::AmpConfig;
use amp4ec::manifest::Manifest;
use amp4ec::partitioner;
use amp4ec::server::EdgeServer;
use amp4ec::util::cli::Args;
use amp4ec::workload::Arrival;

fn artifacts(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(amp4ec::artifacts_dir)
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let m = Manifest::load(&artifacts(args))?;
    println!("model          : {}", m.model);
    println!("input          : {0}x{0}x{1}", m.input_hw, m.input_channels);
    println!("classes        : {}", m.num_classes);
    println!("batch sizes    : {:?}", m.batch_sizes);
    println!("blocks         : {}", m.blocks.len());
    println!("flat layers    : {}", m.flat_layers().len());
    println!("total params   : {}", m.total_params);
    println!(
        "weights payload: {:.1} MB",
        m.blocks.iter().map(|b| b.weights_bytes).sum::<u64>() as f64 / 1e6
    );
    Ok(())
}

fn cmd_partition(args: &Args) -> anyhow::Result<()> {
    let m = Manifest::load(&artifacts(args))?;
    let parts = args.get_usize("parts", 2)?;
    let plan = partitioner::plan(&m, parts)?;
    println!("partitions (layer sizes): {:?}", plan.layer_sizes());
    println!("block ranges            : {:?}", plan.block_ranges());
    println!("per-partition cost      : {:?}",
             plan.partitions.iter().map(|p| p.cost).collect::<Vec<_>>());
    println!("imbalance (max/min)     : {:.3}", plan.imbalance());
    println!("comm bytes at batch 1   : {:?}", plan.comm_bytes(&m, 1));
    println!("weights bytes           : {:?}", plan.weights_bytes(&m));
    Ok(())
}

fn build_config(args: &Args) -> anyhow::Result<AmpConfig> {
    let mut cfg = AmpConfig::paper_cluster(&artifacts(args));
    cfg.batch = args.get_usize("batch", 1)?;
    if let Some(p) = args.get("partitions") {
        cfg.num_partitions = Some(p.parse()?);
    }
    if args.flag("cache") {
        cfg.cache_entries = Some(256);
        cfg.model_cache = true;
    }
    cfg.workers = args.get_usize("workers", cfg.workers)?;
    cfg.time_scale = args.get_f64("time-scale", cfg.time_scale)?;
    cfg.pipeline_depth = args.get_usize("depth", cfg.pipeline_depth)?;
    cfg.adaptive_depth = args.flag("adaptive-depth");
    cfg.max_pipeline_depth =
        args.get_usize("max-depth", cfg.max_pipeline_depth)?;
    cfg.per_stage_windows = args.flag("stage-windows");
    cfg.coalesce = args.flag("coalesce");
    if let Some(r) = args.get("replicas") {
        cfg.replicas = amp4ec::config::ReplicaPolicy::parse(r)?;
    }
    cfg.heal = args.flag("heal");
    cfg.miss_threshold =
        args.get_usize("miss-threshold", cfg.miss_threshold as usize)? as u32;
    cfg.priority_classes =
        args.get_usize("priority-classes", cfg.priority_classes)?;
    if let Some(t) = args.get("tenants") {
        cfg.tenants = amp4ec::config::TenantConfig::parse_list(t)?;
    }
    if let Some(ms) = args.get("deadline-ms") {
        cfg.default_deadline_ms = Some(
            ms.parse()
                .map_err(|_| anyhow::anyhow!("--deadline-ms expects a number, got `{ms}`"))?,
        );
    }
    if let Some(ms) = args.get("wire-timeout-ms") {
        cfg.wire_execute_timeout_ms = Some(ms.parse().map_err(|_| {
            anyhow::anyhow!("--wire-timeout-ms expects a number, got `{ms}`")
        })?);
    }
    cfg.hedge = args.flag("hedge");
    if let Some(t) = args.get("transport") {
        cfg.transport = amp4ec::transport::TransportKind::parse(t)?;
    }
    if let Some(a) = args.get("agents") {
        cfg.agents = a
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect();
    }
    Ok(cfg)
}

fn print_report(report: &amp4ec::server::ServeReport) {
    let m = &report.metrics;
    let lat = m.latency_summary();
    println!("requests completed : {}", m.completed);
    println!("requests failed    : {}", m.failed);
    println!("requests shed      : {}", m.total_shed());
    println!("cache hits         : {}", m.cache_hits);
    println!("latency mean/p50/p95/p99: {:.2} / {:.2} / {:.2} / {:.2} ms",
             lat.mean(), lat.p50(), lat.p95(), lat.p99());
    println!("throughput         : {:.2} req/s", m.throughput_rps());
    println!("comm overhead      : {:.2} ms/req", m.mean_comm_ms());
    println!("sched overhead     : {:.2} ms/req", m.mean_sched_ms());
    println!("stability score    : {:.3}", m.stability_score());
    // Per-priority-class breakdown (only classes that saw traffic).
    for c in &m.classes {
        if c.completed + c.failed + c.shed() == 0 {
            continue;
        }
        let lat = c.latency_summary();
        let deadline = if c.deadline_total > 0 {
            format!(", deadlines met {}/{}", c.deadline_met, c.deadline_total)
        } else {
            String::new()
        };
        println!(
            "class {:<12}: {} ok / {} failed / {} shed ({} expired, {} \
             predicted), p50/p99 {:.2}/{:.2} ms{}",
            amp4ec::serving::class_name(c.class),
            c.completed,
            c.failed,
            c.shed(),
            c.shed_expired,
            c.shed_predicted,
            lat.p50(),
            lat.p99(),
            deadline
        );
    }
    // Per-tenant breakdown (only when a weight table routed traffic to
    // more than the implicit tenant 0).
    if m.tenants.iter().any(|t| t.tenant != 0) {
        for t in &m.tenants {
            if t.completed + t.failed + t.shed() == 0 {
                continue;
            }
            let lat = t.latency_summary();
            println!(
                "tenant {} class {:<12}: {} ok / {} failed / {} shed, \
                 p50/p99 {:.2}/{:.2} ms",
                t.tenant,
                amp4ec::serving::class_name(t.class),
                t.completed,
                t.failed,
                t.shed(),
                lat.p50(),
                lat.p99()
            );
        }
    }
    println!("deploy transfer    : {:.2} MB", report.deploy_transfer_bytes as f64 / 1e6);
    println!("monitor overhead   : {:.3}% CPU", report.monitor_overhead_pct);
    println!("partition sizes    : {:?}", report.partition_layer_sizes);
    println!("nodes              : {:?}", report.node_names);
    for c in &report.stage_counters {
        println!(
            "stage {} (node {})  : busy {:.1} ms, bubble {:.1} ms ({:.0}%), {} micro-batches",
            c.stage,
            c.node,
            c.busy_ms,
            c.bubble_ms,
            100.0 * c.bubble_fraction(),
            c.micro_batches
        );
    }
    // Scale-out: show where each stage's replicas landed and how busy
    // each copy was (only when some stage actually runs more than one).
    if report.replica_map.iter().any(|r| r.len() > 1) {
        let map = report
            .replica_map
            .iter()
            .enumerate()
            .map(|(k, nodes)| format!("{k}->{nodes:?}"))
            .collect::<Vec<_>>()
            .join(" ");
        println!("replica map        : {map}");
        for c in &report.replica_counters {
            println!(
                "  stage {}.{} (node {}): busy {:.1} ms, bubble {:.1} ms \
                 ({:.0}%), {} micro-batches",
                c.stage,
                c.replica,
                c.node,
                c.busy_ms,
                c.bubble_ms,
                100.0 * c.bubble_fraction(),
                c.micro_batches
            );
        }
    }
    println!("pipeline depth     : {}", report.final_pipeline_depth);
    if !report.stage_budgets.is_empty() {
        println!("stage windows      : {:?}", report.stage_budgets);
    }
    if let Some(c) = &report.coalesce_stats {
        println!(
            "coalescing         : {} transports ({} coalesced), {} member \
             batches, {} micro-batches saved",
            c.transports,
            c.coalesced_transports,
            c.member_batches,
            c.saved_micro_batches
        );
    }
    if let Some(d) = &report.depth_report {
        println!(
            "adaptive depth     : {} -> {} (range {}..{}, +{} / -{})",
            d.initial_depth,
            d.final_depth,
            d.min_depth,
            d.max_depth,
            d.widenings,
            d.narrowings
        );
    }
    let dp = &report.data_plane;
    println!(
        "data plane         : {:.2} MB copied ({} copies), {:.2} MB as views",
        dp.copied_bytes as f64 / 1e6,
        dp.copies,
        dp.viewed_bytes as f64 / 1e6
    );
    let p = &report.pool_stats;
    println!(
        "buffer pool        : {} hits / {} misses / {} returns",
        p.hits, p.misses, p.returns
    );
    if let Some(w) = &report.wire {
        println!(
            "wire transport     : {} frames / {:.2} MB tx, {} frames / {:.2} MB rx, \
             encode {:.2} ms, decode {:.2} ms",
            w.frames_tx,
            w.bytes_tx as f64 / 1e6,
            w.frames_rx,
            w.bytes_rx as f64 / 1e6,
            w.encode_ns as f64 / 1e6,
            w.decode_ns as f64 / 1e6
        );
        if w.hedges > 0 {
            println!(
                "straggler hedging  : {} issued, {} won, {} wasted",
                w.hedges, w.hedge_wins, w.hedge_wasted
            );
        }
    }
    // Self-healing: only on a run that actually saw churn.
    let ch = &report.churn;
    if ch.any() {
        println!(
            "node churn         : {} died / {} returned; heals: {} replica \
             re-placements, {} re-partitions",
            ch.nodes_died,
            ch.nodes_returned,
            ch.heals_replaced,
            ch.heals_repartitioned
        );
        println!(
            "micro-batch replays: {} succeeded / {} attempted",
            ch.replays_succeeded, ch.replays_attempted
        );
    }
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let cfg = build_config(args)?;
    let heal = cfg.heal;
    let interval =
        std::time::Duration::from_millis(cfg.monitor_interval_ms.max(1));
    let requests = args.get_usize("requests", 32)?;
    let distinct = args.get_usize("distinct", requests)?;
    let server = std::sync::Arc::new(EdgeServer::start(cfg)?);
    println!("deployed over nodes: {:?}", server.service().deployment_nodes());
    // Self-healing serving: watch the monitor's liveness feed and walk
    // the heal ladder on node death. Held for the duration of the run.
    let _watchdog = heal.then(|| server.start_heal_watchdog(interval));
    let report = server.serve_workload(requests, distinct, Arrival::Closed, 0)?;
    print_report(&report);
    Ok(())
}

fn cmd_serve_cfg(args: &Args) -> anyhow::Result<()> {
    let path = args
        .get("config")
        .ok_or_else(|| anyhow::anyhow!("--config FILE required"))?;
    let cfg = AmpConfig::load(std::path::Path::new(path))?;
    let requests = args.get_usize("requests", 32)?;
    let distinct = args.get_usize("distinct", requests)?;
    let server = EdgeServer::start(cfg)?;
    let report = server.serve_workload(requests, distinct, Arrival::Closed, 0)?;
    print_report(&report);
    Ok(())
}

fn cmd_golden(args: &Args) -> anyhow::Result<()> {
    let cfg = build_config(args)?;
    let server = EdgeServer::start(cfg)?;
    let diff = server.golden_check()?;
    println!("golden parity OK (max abs diff {diff:.2e})");
    Ok(())
}

fn cmd_calibrate(args: &Args) -> anyhow::Result<()> {
    let m = Manifest::load(&artifacts(args))?;
    let batch = args.get_usize("batch", 1)?;
    let costs = amp4ec::server::calibrate_block_costs(&m, batch)?;
    let total: f64 = costs.iter().sum();
    println!("{:<4} {:<22} {:>10} {:>8}", "idx", "block", "ms", "share");
    for (b, ms) in m.blocks.iter().zip(&costs) {
        println!(
            "{:<4} {:<22} {:>10.3} {:>7.1}%",
            b.index, b.name, ms, 100.0 * ms / total
        );
    }
    println!("total: {total:.1} ms at batch {batch}");
    Ok(())
}

/// Run a node agent: host stage deployments shipped by a coordinator
/// over the wire transport. By default the agent exits once it has
/// served a coordinator and that coordinator disconnects (`--stay`
/// keeps it listening forever).
fn cmd_node(args: &Args) -> anyhow::Result<()> {
    use amp4ec::transport::{agent::NodeAgent, TransportKind};
    let listen = args
        .get("listen")
        .ok_or_else(|| anyhow::anyhow!("--listen ADDR required (socket path or host:port)"))?;
    // Infer the flavor from the address shape unless told explicitly:
    // host:port is TCP, anything else is a socket path.
    let kind = match args.get("transport") {
        Some(t) => match TransportKind::parse(t)? {
            TransportKind::Inproc => anyhow::bail!(
                "a node agent serves uds or tcp, not inproc"
            ),
            k => k,
        },
        None if listen.contains(':') => TransportKind::Tcp,
        None => TransportKind::Uds,
    };
    let handle = match kind {
        TransportKind::Tcp => NodeAgent::serve_tcp(listen)?,
        _ => NodeAgent::serve_uds(listen)?,
    };
    handle.exit_when_idle(!args.flag("stay"));
    // How long a non-`--stay` agent tolerates a silent (stalled, not
    // disconnected) coordinator before giving up the connection.
    if let Some(ms) = args.get("idle-timeout-ms") {
        let ms: u64 = ms.parse().map_err(|_| {
            anyhow::anyhow!("--idle-timeout-ms expects a number, got `{ms}`")
        })?;
        handle.set_idle_timeout(std::time::Duration::from_millis(ms.max(1)));
    }
    println!("node agent listening on {}", handle.addr());
    handle.join();
    Ok(())
}

fn cmd_config(args: &Args) -> anyhow::Result<()> {
    let out = args.get_or("out", "amp4ec.json");
    AmpConfig::default().save(std::path::Path::new(out))?;
    println!("wrote default config to {out}");
    Ok(())
}

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("partition") => cmd_partition(&args),
        Some("serve") => cmd_serve(&args),
        Some("serve-cfg") => cmd_serve_cfg(&args),
        Some("node") => cmd_node(&args),
        Some("golden") => cmd_golden(&args),
        Some("config") => cmd_config(&args),
        Some("calibrate") => cmd_calibrate(&args),
        other => {
            eprintln!(
                "usage: amp4ec <info|partition|serve|serve-cfg|node|golden|config|calibrate> [--options]\n\
                 unknown subcommand: {other:?}"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
