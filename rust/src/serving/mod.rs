//! Unified request-level serving API: the one ingress every entry point
//! rides.
//!
//! Historically each entry point reached the engine differently — the
//! CLI serve loop through `router::serve` + a raw sync channel,
//! `single_request`/`golden_check` through ad-hoc `pipeline::run` calls,
//! examples through hand-rolled channel plumbing — and none of them
//! could express what the paper's serving story needs: per-request
//! **priorities** and **deadlines** (DEFER / edge-cloud-continuum style
//! request-level SLOs). This module replaces all of that with one
//! request-level path:
//!
//! * [`ServiceHandle`] — obtained from `EdgeServer` (or built directly
//!   over any [`InferenceService`]); owns the ingress queue and its
//!   dispatcher.
//! * [`RequestBuilder`] — one request: input tensor, [`Priority`]
//!   class, optional deadline, optional tag.
//! * [`ResponseHandle`] — non-blocking completion handle:
//!   [`ResponseHandle::wait`] / [`ResponseHandle::try_wait`] resolve to
//!   an [`Outcome`] (completed, shed, or failed) — **never hangs**: a
//!   shed or dropped request still resolves its handle.
//! * [`IngressQueue`] — bounded priority queue doing admission:
//!   priority-ordered dequeue into the dispatcher, deadline-aware
//!   shedding (a request that cannot meet its SLO given the current
//!   service-time estimate is rejected instead of wasting engine
//!   credits), and bounded-queue backpressure (submission blocks while
//!   the queue is full).
//!
//! The dispatcher preserves the old router's batching semantics exactly
//! — collect up to `InferenceService::batch_size` requests within
//! `max_wait`, check the result cache, stack misses padded via
//! `padded_rows`, submit through `submit_batch_meta` — so default-class
//! no-deadline traffic produces **bit-identical outputs** to the
//! pre-redesign path (pinned by equivalence tests). Priority changes
//! only *order*: lanes are strict-priority, and a worker slot is
//! acquired *before* the next batch is popped so the priority decision
//! happens as late as possible.
//!
//! **Multi-tenant WFQ** (ISSUE 9): when [`IngressConfig`] carries two
//! or more tenant weights, each priority lane holds one FIFO per
//! tenant and dequeues across them deficit-weighted round-robin
//! ([`crate::tenancy::DrrScheduler`]) — a flooding tenant is capped
//! near its weight share of the lane instead of starving everyone
//! queued behind it. With zero or one tenants configured each lane is a
//! single plain FIFO and the dequeue path never consults the DRR state:
//! within-class order is bit-identical to the single-tenant ingress.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::metrics::{MetricsCollector, RunMetrics};
use crate::pipeline::stack_batch;
use crate::router::{BatchMeta, InferenceService, Submission};
use crate::runtime::Tensor;
use crate::scheduler::cache::{input_key, ResultCache};
use crate::util::pool::{ThreadPool, WaitGroup};

// ---------------------------------------------------------------------------
// Request-side types
// ---------------------------------------------------------------------------

/// A request's priority class. Lower is more urgent: class 0 is
/// dispatched before class 1, and so on. [`Priority::NORMAL`] is the
/// default — plain traffic that neither jumps nor yields.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Priority(pub u8);

impl Priority {
    /// Latency-critical traffic: dispatched ahead of everything else.
    pub const HIGH: Priority = Priority(0);
    /// The default class.
    pub const NORMAL: Priority = Priority(1);
    /// Background traffic: dispatched only when nothing above it waits.
    pub const BEST_EFFORT: Priority = Priority(2);

    pub fn class(self) -> usize {
        self.0 as usize
    }
}

impl Default for Priority {
    fn default() -> Self {
        Priority::NORMAL
    }
}

/// Human-readable name for a priority class (reports/CLI).
pub fn class_name(class: usize) -> String {
    match class {
        0 => "high".into(),
        1 => "normal".into(),
        2 => "best-effort".into(),
        n => format!("class-{n}"),
    }
}

/// Why a request was shed instead of served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The deadline had already passed when the dispatcher reached the
    /// request (or when the engine feeder was about to admit it).
    DeadlineExpired,
    /// The deadline was still ahead, but the current service-time
    /// estimate says it cannot be met — shedding now saves the engine
    /// work that would be wasted anyway.
    PredictedMiss,
}

/// A completed request.
#[derive(Debug, Clone)]
pub struct Response {
    /// This request's output row(s) — shape `[1, ...]`.
    pub output: Tensor,
    /// End-to-end latency (enqueue to completion), wall-clock ms.
    pub latency_ms: f64,
    /// Batch-shared simulated compute / communication ms.
    pub compute_ms: f64,
    pub comm_ms: f64,
    pub cache_hit: bool,
    /// Whether the request carried a deadline and completed within it
    /// (`None` when no deadline was set).
    pub deadline_met: Option<bool>,
}

/// Terminal state of one request. Every submitted request resolves to
/// exactly one `Outcome` — shed and failed requests included.
#[derive(Debug)]
pub enum Outcome {
    Done(Response),
    Shed(ShedReason),
    Failed(anyhow::Error),
}

impl Outcome {
    /// Completed output, or an error describing the shed/failure.
    pub fn into_output(self) -> Result<Tensor> {
        match self {
            Outcome::Done(r) => Ok(r.output),
            Outcome::Shed(reason) => {
                Err(anyhow::anyhow!("request shed: {reason:?}"))
            }
            Outcome::Failed(e) => Err(e),
        }
    }

    pub fn is_shed(&self) -> bool {
        matches!(self, Outcome::Shed(_))
    }
}

/// Non-blocking completion handle for one submitted request.
pub struct ResponseHandle {
    rx: Receiver<Outcome>,
}

impl ResponseHandle {
    /// Block until the request resolves. Never hangs: shedding, batch
    /// failure, ingress shutdown, and even a panicking service all
    /// resolve the handle.
    pub fn wait(self) -> Outcome {
        match self.rx.recv() {
            Ok(o) => o,
            Err(_) => Self::dropped(),
        }
    }

    /// Non-blocking poll: `None` only while the request is genuinely
    /// still in flight. A dropped request (ingress shutdown, worker
    /// panic) yields `Some(Outcome::Failed)` — pollers never spin
    /// forever.
    pub fn try_wait(&self) -> Option<Outcome> {
        match self.rx.try_recv() {
            Ok(o) => Some(o),
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                Some(Self::dropped())
            }
        }
    }

    /// Block up to `timeout`; `None` only if the request is still in
    /// flight. Like [`ResponseHandle::try_wait`], a dropped request
    /// resolves as `Some(Outcome::Failed)` instead of timing out
    /// forever.
    pub fn wait_timeout(&self, timeout: Duration) -> Option<Outcome> {
        match self.rx.recv_timeout(timeout) {
            Ok(o) => Some(o),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                Some(Self::dropped())
            }
        }
    }

    fn dropped() -> Outcome {
        Outcome::Failed(anyhow::anyhow!(
            "request dropped before resolving (ingress shut down or its \
             batch worker panicked)"
        ))
    }

    /// Convenience: wait and unwrap the completed output.
    pub fn wait_output(self) -> Result<Tensor> {
        self.wait().into_output()
    }
}

/// One request being assembled. Submit with [`RequestBuilder::submit`]
/// (blocks on queue backpressure).
pub struct RequestBuilder<'a> {
    handle: &'a ServiceHandle,
    input: Tensor,
    priority: Priority,
    deadline: Option<Duration>,
    tag: Option<String>,
    tenant: usize,
}

impl RequestBuilder<'_> {
    pub fn priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }

    /// The submitting tenant (index into the configured weight table;
    /// clamps to the last tenant). Default 0 — the only tenant that
    /// exists when no weight table is configured.
    pub fn tenant(mut self, tenant: usize) -> Self {
        self.tenant = tenant;
        self
    }

    /// Relative deadline: the request must complete within `d` of
    /// submission or it is shed/reported as missed.
    pub fn deadline(mut self, d: Duration) -> Self {
        self.deadline = Some(d);
        self
    }

    pub fn deadline_ms(self, ms: f64) -> Self {
        self.deadline(Duration::from_secs_f64(ms.max(0.0) / 1e3))
    }

    /// Free-form label carried through for debugging/tracing.
    pub fn tag(mut self, tag: &str) -> Self {
        self.tag = Some(tag.to_string());
        self
    }

    /// Enqueue the request (blocking while the bounded ingress queue is
    /// full — backpressure). Errors only if the ingress is shut down.
    ///
    /// Queue-depth-aware predictive shedding happens *here*, before the
    /// request ever occupies a queue slot: the EWMA service-time
    /// estimate is scaled by the batch waves of same-or-higher-class
    /// traffic already queued ahead, so a deadline that is already
    /// doomed behind a deep backlog resolves as
    /// [`ShedReason::PredictedMiss`] immediately instead of at dispatch.
    /// A cold estimate never sheds, and neither does a request whose
    /// answer is already cached (it costs ~0 ms regardless of the
    /// queue).
    pub fn submit(self) -> Result<ResponseHandle> {
        let cfg = &self.handle.cfg;
        let class = (self.priority.class()).min(cfg.classes.max(1) - 1);
        let tenant = self.tenant.min(cfg.tenant_weights.len().max(1) - 1);
        let deadline = self
            .deadline
            .or(cfg.default_deadline)
            .map(|d| Instant::now() + d);
        let (reply, rx) = channel();
        if let Some(d) = deadline {
            if self.handle.shed_doomed(&self.input, class, tenant, d) {
                let _ = reply.send(Outcome::Shed(ShedReason::PredictedMiss));
                return Ok(ResponseHandle { rx });
            }
        }
        let req = QueuedRequest {
            input: self.input,
            class,
            tenant,
            deadline,
            tag: self.tag,
            enqueued: Instant::now(),
            reply,
        };
        if self.handle.queue.push(req) {
            Ok(ResponseHandle { rx })
        } else {
            anyhow::bail!("ingress is shut down")
        }
    }
}

// ---------------------------------------------------------------------------
// Ingress queue
// ---------------------------------------------------------------------------

/// Ingress configuration (the old `RouterConfig`, extended with the
/// request-level knobs).
#[derive(Debug, Clone)]
pub struct IngressConfig {
    /// Bounded queue size: submission blocks past this (backpressure).
    pub capacity: usize,
    /// Batch admission window (how long the dispatcher waits to fill a
    /// batch).
    pub max_wait: Duration,
    /// Concurrent batches in flight.
    pub workers: usize,
    /// Number of priority classes (lanes). Priorities clamp to
    /// `classes - 1`.
    pub classes: usize,
    /// Deadline applied to requests that don't set their own (CLI
    /// `--deadline-ms`).
    pub default_deadline: Option<Duration>,
    /// Tenant WFQ weights (tenant id = index). Empty or a single entry
    /// means one implicit tenant and plain FIFO within each class — the
    /// single-tenant fast path.
    pub tenant_weights: Vec<f64>,
}

impl Default for IngressConfig {
    fn default() -> Self {
        IngressConfig {
            capacity: 256,
            max_wait: Duration::from_millis(10),
            workers: 4,
            classes: 3,
            default_deadline: None,
            tenant_weights: Vec::new(),
        }
    }
}

struct QueuedRequest {
    input: Tensor,
    class: usize,
    tenant: usize,
    deadline: Option<Instant>,
    #[allow(dead_code)]
    tag: Option<String>,
    enqueued: Instant,
    reply: Sender<Outcome>,
}

/// One priority class's queue: a FIFO per tenant plus the DRR state
/// that arbitrates across them. With a single tenant the DRR is never
/// consulted — the lane *is* a plain FIFO, structurally identical to
/// the pre-multitenant ingress.
struct Lane {
    queues: Vec<std::collections::VecDeque<QueuedRequest>>,
    drr: crate::tenancy::DrrScheduler,
}

impl Lane {
    fn new(tenant_weights: &[f64]) -> Lane {
        let weights: &[f64] = if tenant_weights.len() <= 1 {
            &[1.0]
        } else {
            tenant_weights
        };
        Lane {
            queues: weights
                .iter()
                .map(|_| std::collections::VecDeque::new())
                .collect(),
            drr: crate::tenancy::DrrScheduler::new(weights),
        }
    }

    fn len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    fn push(&mut self, req: QueuedRequest) {
        let t = req.tenant.min(self.queues.len() - 1);
        self.queues[t].push_back(req);
    }

    fn pop(&mut self) -> Option<QueuedRequest> {
        let Lane { queues, drr } = self;
        if queues.len() == 1 {
            // Single tenant: plain FIFO, no DRR state touched.
            return queues[0].pop_front();
        }
        let t = drr.pick(|t| queues[t].len())?;
        queues[t].pop_front()
    }
}

struct QueueState {
    /// One lane per priority class; dequeue scans lanes in order
    /// (strict priority) and WFQs across tenants within a lane.
    lanes: Vec<Lane>,
    len: usize,
    closed: bool,
}

enum Popped {
    Item(QueuedRequest),
    Timeout,
    Closed,
}

/// Bounded multi-lane priority queue with condvar-based blocking on both
/// sides: producers block while full (backpressure), the dispatcher
/// blocks while empty. Also owns the service-time estimate the
/// deadline shedder consults, and the shed counters.
pub struct IngressQueue {
    state: Mutex<QueueState>,
    arrived: Condvar,
    space: Condvar,
    capacity: usize,
    /// EWMA of observed dispatch-to-completion service time, ms. `None`
    /// until the first batch completes (no shedding on a cold estimate).
    estimate: Mutex<Option<f64>>,
    shed_expired: AtomicU64,
    shed_predicted: AtomicU64,
}

impl IngressQueue {
    fn new(
        capacity: usize,
        classes: usize,
        tenant_weights: &[f64],
    ) -> IngressQueue {
        IngressQueue {
            state: Mutex::new(QueueState {
                lanes: (0..classes.max(1))
                    .map(|_| Lane::new(tenant_weights))
                    .collect(),
                len: 0,
                closed: false,
            }),
            arrived: Condvar::new(),
            space: Condvar::new(),
            capacity: capacity.max(1),
            estimate: Mutex::new(None),
            shed_expired: AtomicU64::new(0),
            shed_predicted: AtomicU64::new(0),
        }
    }

    /// Enqueue; blocks while full. Returns false (req dropped, handle
    /// resolves as Failed via the dropped sender) when closed.
    fn push(&self, req: QueuedRequest) -> bool {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return false;
            }
            if st.len < self.capacity {
                let lane = req.class.min(st.lanes.len() - 1);
                st.lanes[lane].push(req);
                st.len += 1;
                self.arrived.notify_one();
                return true;
            }
            st = self.space.wait(st).unwrap();
        }
    }

    fn take(st: &mut QueueState) -> Option<QueuedRequest> {
        for lane in st.lanes.iter_mut() {
            if let Some(r) = lane.pop() {
                st.len -= 1;
                return Some(r);
            }
        }
        None
    }

    /// Block until a request is available (highest-priority lane first)
    /// or the queue is closed *and* empty.
    fn pop_one(&self) -> Option<QueuedRequest> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(r) = Self::take(&mut st) {
                self.space.notify_one();
                return Some(r);
            }
            if st.closed {
                return None;
            }
            st = self.arrived.wait(st).unwrap();
        }
    }

    /// Like [`IngressQueue::pop_one`] but give up after `timeout` (the
    /// batch-fill wait).
    fn pop_one_timeout(&self, timeout: Duration) -> Popped {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(r) = Self::take(&mut st) {
                self.space.notify_one();
                return Popped::Item(r);
            }
            if st.closed {
                return Popped::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Popped::Timeout;
            }
            let (guard, _) =
                self.arrived.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.arrived.notify_all();
        self.space.notify_all();
    }

    /// Requests currently queued (diagnostics).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Current service-time estimate (EWMA of dispatch-to-completion
    /// ms), the critical-path figure the deadline shedder compares
    /// against remaining slack.
    pub fn estimate_ms(&self) -> Option<f64> {
        *self.estimate.lock().unwrap()
    }

    fn observe_service_ms(&self, ms: f64) {
        let mut est = self.estimate.lock().unwrap();
        *est = Some(match *est {
            Some(e) => 0.7 * e + 0.3 * ms,
            None => ms,
        });
    }

    /// (expired, predicted-miss) shed counts since startup.
    pub fn shed_counts(&self) -> (u64, u64) {
        (
            self.shed_expired.load(Ordering::Relaxed),
            self.shed_predicted.load(Ordering::Relaxed),
        )
    }

    /// Requests queued in this class's lane and every more-urgent lane —
    /// the traffic that will be dispatched before a new arrival of
    /// `class`. The queue-depth-aware shedder scales the service-time
    /// estimate by the batch *waves* this backlog represents, so a
    /// doomed deadline is shed at submission instead of after it has
    /// waited through the whole queue.
    pub fn queued_ahead(&self, class: usize) -> usize {
        let st = self.state.lock().unwrap();
        st.lanes
            .iter()
            .take(class.min(st.lanes.len().saturating_sub(1)) + 1)
            .map(|l| l.len())
            .sum()
    }
}

// ---------------------------------------------------------------------------
// Worker-slot gate
// ---------------------------------------------------------------------------

/// Counting semaphore bounding in-flight batches: the dispatcher
/// acquires a slot *before* popping the next batch, so priority
/// decisions happen at the last possible moment instead of queueing
/// already-ordered batches inside the thread pool.
struct Slots {
    free: Mutex<usize>,
    cv: Condvar,
}

impl Slots {
    fn new(n: usize) -> Arc<Slots> {
        Arc::new(Slots { free: Mutex::new(n.max(1)), cv: Condvar::new() })
    }

    fn acquire(&self) {
        let mut n = self.free.lock().unwrap();
        while *n == 0 {
            n = self.cv.wait(n).unwrap();
        }
        *n -= 1;
    }

    fn release(&self) {
        *self.free.lock().unwrap() += 1;
        self.cv.notify_one();
    }
}

// ---------------------------------------------------------------------------
// Service handle + dispatcher
// ---------------------------------------------------------------------------

/// The unified serving ingress over one [`InferenceService`]. Create
/// via `EdgeServer::serve_handle()` (or directly for tests/benches),
/// build requests with [`ServiceHandle::request`], and finish with
/// [`ServiceHandle::finish`] to collect the run's metrics.
pub struct ServiceHandle {
    queue: Arc<IngressQueue>,
    metrics: Arc<MetricsCollector>,
    cfg: IngressConfig,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    /// Admission-batch size and cache probe context for the
    /// submission-time queue-depth-aware shedder (mirrors what the
    /// dispatcher sees, without reaching through the service Arc).
    batch_size: usize,
    model_id: u64,
    cache: Option<Arc<ResultCache>>,
}

impl ServiceHandle {
    /// Spawn an ingress (queue + dispatcher + worker pool) over
    /// `service`. The optional result cache is consulted per request
    /// before dispatch, exactly like the old router.
    pub fn new(
        service: Arc<dyn InferenceService>,
        cfg: IngressConfig,
        cache: Option<Arc<ResultCache>>,
    ) -> ServiceHandle {
        let queue = Arc::new(IngressQueue::new(
            cfg.capacity,
            cfg.classes.max(1),
            &cfg.tenant_weights,
        ));
        let metrics = Arc::new(MetricsCollector::new());
        metrics.start_run();
        let batch_size = service.batch_size().max(1);
        let model_id = service.model_id();
        let dispatcher = {
            let queue = Arc::clone(&queue);
            let metrics = Arc::clone(&metrics);
            let cfg = cfg.clone();
            let cache = cache.clone();
            std::thread::Builder::new()
                .name("amp4ec-ingress".into())
                .spawn(move || {
                    dispatch_loop(service, queue, cfg, cache, metrics)
                })
                .expect("spawn ingress dispatcher")
        };
        ServiceHandle {
            queue,
            metrics,
            cfg,
            dispatcher: Some(dispatcher),
            batch_size,
            model_id,
            cache,
        }
    }

    /// Submission-time predictive shed decision (see
    /// [`RequestBuilder::submit`]): true when the deadline `d` cannot be
    /// met given the warm service-time estimate scaled by the batch
    /// waves of same-or-higher-class traffic already queued, and the
    /// answer is not already cached. Records the shed when it fires.
    fn shed_doomed(
        &self,
        input: &Tensor,
        class: usize,
        tenant: usize,
        d: Instant,
    ) -> bool {
        let Some(est) = self.queue.estimate_ms() else {
            return false; // cold estimate never sheds
        };
        let now = Instant::now();
        if now >= d {
            return false; // already expired: dispatch-time shed accounts it
        }
        // Requests ahead dispatch in batches of `batch_size`; this
        // request rides the wave after them.
        let ahead = self.queue.queued_ahead(class);
        let waves = 1.0 + (ahead / self.batch_size) as f64;
        let slack_ms = (d - now).as_secs_f64() * 1e3;
        if slack_ms >= est * waves {
            return false;
        }
        let cached = self.cache.as_ref().is_some_and(|c| {
            c.contains(input_key(self.model_id, input.data()))
        });
        if cached {
            return false;
        }
        self.queue.shed_predicted.fetch_add(1, Ordering::Relaxed);
        self.metrics.record_shed_tenant(tenant, class, false);
        true
    }

    /// Start building one request.
    pub fn request(&self, input: Tensor) -> RequestBuilder<'_> {
        RequestBuilder {
            handle: self,
            input,
            priority: Priority::default(),
            deadline: None,
            tag: None,
            tenant: crate::tenancy::DEFAULT_TENANT,
        }
    }

    /// Sugar: submit with default priority and no explicit deadline.
    pub fn submit(&self, input: Tensor) -> Result<ResponseHandle> {
        self.request(input).submit()
    }

    /// The ingress queue (shed counts, service estimate, depth).
    pub fn queue(&self) -> &IngressQueue {
        &self.queue
    }

    /// Close the ingress, drain in-flight work, and return the run's
    /// aggregate metrics (including per-class latency and shed counts).
    pub fn finish(mut self) -> RunMetrics {
        self.queue.close();
        if let Some(t) = self.dispatcher.take() {
            let _ = t.join();
        }
        self.metrics.finish()
    }
}

impl Drop for ServiceHandle {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(t) = self.dispatcher.take() {
            let _ = t.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Engine adapter
// ---------------------------------------------------------------------------

/// An [`InferenceService`] over a bare
/// [`PersistentEngine`](crate::pipeline::engine::PersistentEngine) —
/// the artifact-free adapter benches and tests use to drive the full
/// request-level ingress against `SimStages` chains. Threads the
/// batch's priority class and deadline straight into the engine's
/// feeder (`PersistentEngine::submit_owned_with`), so engine-side
/// admission ordering and pre-admission deadline shedding are
/// exercised end-to-end.
pub struct EngineService {
    engine: Arc<crate::pipeline::engine::PersistentEngine>,
    micro_rows: usize,
    depth: usize,
    id: u64,
}

impl EngineService {
    /// `micro_rows` must equal the engine's configured micro-batch;
    /// `depth` sizes the admission super-batch (`micro_rows * depth`
    /// rows per dispatched batch).
    pub fn new(
        engine: Arc<crate::pipeline::engine::PersistentEngine>,
        micro_rows: usize,
        depth: usize,
    ) -> EngineService {
        EngineService {
            engine,
            micro_rows: micro_rows.max(1),
            depth: depth.max(1),
            id: 0xE5E5,
        }
    }

    pub fn engine(&self) -> &Arc<crate::pipeline::engine::PersistentEngine> {
        &self.engine
    }
}

impl InferenceService for EngineService {
    fn infer_batch(&self, batch: &Tensor) -> Result<(Tensor, f64, f64)> {
        let run = self.engine.run(batch)?;
        Ok((run.output, run.timing.compute_ms, run.timing.comm_ms))
    }

    fn submit_batch_meta(&self, batch: Tensor, meta: BatchMeta) -> Submission {
        match self.engine.submit_owned_with(batch, meta.class, meta.deadline) {
            Ok(h) => Submission::Pending(Box::new(move || {
                let run = h.wait()?;
                Ok((run.output, run.timing.compute_ms, run.timing.comm_ms))
            })),
            Err(e) => Submission::Pending(Box::new(move || Err(e))),
        }
    }

    fn batch_size(&self) -> usize {
        self.micro_rows * self.depth
    }

    fn padded_rows(&self, n: usize) -> usize {
        // Whole micro-batches, never more than the admission batch.
        let chunks = n.div_euclid(self.micro_rows)
            + usize::from(n % self.micro_rows != 0);
        (chunks.max(1) * self.micro_rows).min(self.batch_size())
    }

    fn model_id(&self) -> u64 {
        self.id
    }
}

/// Dispatcher: pop priority-ordered batches, shed what cannot make its
/// deadline, and hand each batch to a pool worker. The loop exits when
/// the queue closes and drains; the pool drains before return.
fn dispatch_loop(
    service: Arc<dyn InferenceService>,
    queue: Arc<IngressQueue>,
    cfg: IngressConfig,
    cache: Option<Arc<ResultCache>>,
    metrics: Arc<MetricsCollector>,
) {
    let pool = ThreadPool::new(cfg.workers.max(1), "ingress");
    let drain = WaitGroup::new(0);
    let slots = Slots::new(cfg.workers.max(1));
    let batch_size = service.batch_size().max(1);
    let model_id = service.model_id();

    'outer: loop {
        // A worker slot first: the next batch is chosen only when it can
        // actually start, so late-arriving high-priority requests still
        // jump everything not yet dispatched.
        slots.acquire();
        let mut batch: Vec<QueuedRequest> = Vec::with_capacity(batch_size);
        // ---- collect a batch (priority lanes, shed-aware) ----
        loop {
            match queue.pop_one() {
                Some(r) => {
                    admit_or_shed(
                        r,
                        &mut batch,
                        &queue,
                        &metrics,
                        cache.as_deref(),
                        model_id,
                    );
                    if !batch.is_empty() {
                        break;
                    }
                }
                None => {
                    slots.release();
                    break 'outer;
                }
            }
        }
        let fill_deadline = Instant::now() + cfg.max_wait;
        while batch.len() < batch_size {
            let now = Instant::now();
            if now >= fill_deadline {
                break;
            }
            match queue.pop_one_timeout(fill_deadline - now) {
                Popped::Item(r) => admit_or_shed(
                    r,
                    &mut batch,
                    &queue,
                    &metrics,
                    cache.as_deref(),
                    model_id,
                ),
                Popped::Timeout | Popped::Closed => break,
            }
        }

        // ---- dispatch ----
        drain.add(1);
        let wg = drain.clone_handle();
        let service = Arc::clone(&service);
        let metrics = Arc::clone(&metrics);
        let queue = Arc::clone(&queue);
        let cache = cache.clone();
        let slots_t = Arc::clone(&slots);
        let dispatched = Instant::now();
        pool.execute(move || {
            // A panicking InferenceService must not wedge the ingress:
            // catching the unwind keeps this pool worker alive and lets
            // the slot/drain bookkeeping below run, and dropping the
            // batch during the unwind drops its reply senders, so every
            // ResponseHandle still resolves (as Failed) — the module's
            // never-hangs contract survives a buggy service.
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || {
                    process_batch(
                        &*service,
                        batch,
                        cache.as_deref(),
                        &metrics,
                        &queue,
                        dispatched,
                    );
                },
            ));
            slots_t.release();
            wg.done();
        });
    }

    drain.wait();
}

/// Admission check at dequeue: expired deadlines and predicted misses
/// are shed (handle resolved immediately, metrics recorded); everything
/// else joins the batch. The predicted-miss check first probes the
/// result cache (stats-neutral): a request whose answer is already
/// cached is served in ~0 ms regardless of the batch service-time
/// estimate, so shedding it would throw away a free hit.
fn admit_or_shed(
    req: QueuedRequest,
    batch: &mut Vec<QueuedRequest>,
    queue: &IngressQueue,
    metrics: &MetricsCollector,
    cache: Option<&ResultCache>,
    model_id: u64,
) {
    if let Some(d) = req.deadline {
        let now = Instant::now();
        if now >= d {
            queue.shed_expired.fetch_add(1, Ordering::Relaxed);
            metrics.record_shed_tenant(req.tenant, req.class, true);
            let _ = req.reply.send(Outcome::Shed(ShedReason::DeadlineExpired));
            return;
        }
        if let Some(est) = queue.estimate_ms() {
            let slack_ms = (d - now).as_secs_f64() * 1e3;
            let cached = || {
                cache.is_some_and(|c| {
                    c.contains(input_key(model_id, req.input.data()))
                })
            };
            if slack_ms < est && !cached() {
                queue.shed_predicted.fetch_add(1, Ordering::Relaxed);
                metrics.record_shed_tenant(req.tenant, req.class, false);
                let _ =
                    req.reply.send(Outcome::Shed(ShedReason::PredictedMiss));
                return;
            }
        }
    }
    batch.push(req);
}

/// Serve one dispatched batch: cache hits answered inline, misses
/// stacked (padded via `padded_rows`) and submitted through the
/// service's streaming path, per-request rows sliced back out and every
/// handle resolved. This is the old `router::process_batch`, extended
/// with per-request replies, per-class metrics, and deadline
/// bookkeeping.
fn process_batch(
    service: &dyn InferenceService,
    batch: Vec<QueuedRequest>,
    cache: Option<&ResultCache>,
    metrics: &MetricsCollector,
    queue: &IngressQueue,
    dispatched: Instant,
) {
    // Split into cache hits and misses (misses keep their batch index so
    // cache inserts are O(1) lookups). Without a cache there is nothing
    // to key: skip hashing every input tensor.
    let mut misses: Vec<usize> = Vec::new();
    let mut keys: Vec<u64> = Vec::new();
    match cache {
        Some(c) => {
            keys.reserve(batch.len());
            for (i, r) in batch.iter().enumerate() {
                let key = input_key(service.model_id(), r.input.data());
                keys.push(key);
                match c.get(key) {
                    Some(row) => {
                        // Serve the hit immediately: zero compute/comm.
                        let latency =
                            r.enqueued.elapsed().as_secs_f64() * 1e3;
                        let sched =
                            (dispatched - r.enqueued).as_secs_f64() * 1e3;
                        let met = deadline_met(r.deadline);
                        metrics.record_request_tenant(
                            r.tenant, r.class, latency, 0.0, 0.0, sched,
                            true, met,
                        );
                        // Zero-copy: the response wraps the cached row's
                        // shared buffer directly.
                        crate::metrics::data_plane::count_view(
                            (row.len() * 4) as u64,
                        );
                        let shape = vec![1, row.len()];
                        let output = Tensor::from_buf(shape, row, 0)
                            .expect("cached row tensor");
                        let _ = r.reply.send(Outcome::Done(Response {
                            output,
                            latency_ms: latency,
                            compute_ms: 0.0,
                            comm_ms: 0.0,
                            cache_hit: true,
                            deadline_met: met,
                        }));
                    }
                    None => misses.push(i),
                }
            }
        }
        None => misses.extend(0..batch.len()),
    }
    if misses.is_empty() {
        return;
    }

    // Run the miss set as one stacked batch through the streaming path.
    let inputs: Vec<&Tensor> =
        misses.iter().map(|&i| &batch[i].input).collect();
    let stacked =
        match stack_batch(&inputs, service.padded_rows(misses.len())) {
            Ok(t) => t,
            Err(e) => {
                fail_requests(&batch, &misses, metrics, &e);
                return;
            }
        };
    let stacked_bytes = stacked.byte_len();
    // The batch's meta: the strictest class present, and — when every
    // miss carries a deadline — the most lenient one, so an engine-side
    // shed (deadline already blown in the submission queue) is correct
    // for every member.
    let meta = BatchMeta {
        class: misses
            .iter()
            .map(|&i| batch[i].class)
            .min()
            .unwrap_or(0),
        deadline: {
            let ds: Vec<Instant> = misses
                .iter()
                .filter_map(|&i| batch[i].deadline)
                .collect();
            if ds.len() == misses.len() {
                ds.into_iter().max()
            } else {
                None
            }
        },
    };
    // Self-healing ingress: with a failure-retry budget
    // ([`InferenceService::failure_retries`]) a submission that fails
    // with a transient — e.g. the stage chain lost a node and the heal
    // swap landed between this batch's submission and its completion —
    // is resubmitted against the healed service instead of failing its
    // requests. A deadline shed is never retried (the deadline stays
    // blown either way). The retry input is a zero-copy clone of the
    // stacked batch (`Tensor` rows are Arc views), so a non-zero budget
    // costs nothing on the happy path.
    let retries = service.failure_retries();
    let mut spare = (retries > 0).then(|| stacked.clone());
    let submit = |input: Tensor| match service.submit_batch_meta(input, meta)
    {
        Submission::Pending(wait) => wait(),
        Submission::Inline(t) => service.infer_batch_meta(&t, meta),
    };
    let mut result = submit(stacked);
    let mut attempt = 0;
    while attempt < retries
        && result.as_ref().err().is_some_and(|e| {
            e.downcast_ref::<crate::pipeline::engine::DeadlineShed>()
                .is_none()
        })
    {
        attempt += 1;
        // Brief linear backoff: the heal needs a moment to rebuild the
        // stage chain; resubmitting instantly would race the swap.
        std::thread::sleep(Duration::from_millis(10 * attempt as u64));
        let input = if attempt < retries {
            spare.clone().expect("retry batch clone")
        } else {
            spare.take().expect("retry batch clone")
        };
        result = submit(input);
    }
    match result {
        Ok((output, compute_ms, comm_ms)) => {
            let row_len: usize = output.shape.iter().skip(1).product();
            if output.shape.is_empty()
                || output.shape[0] < misses.len()
                || row_len == 0
            {
                let e = anyhow::anyhow!(
                    "service returned a malformed batch output {:?}",
                    output.shape
                );
                fail_requests(&batch, &misses, metrics, &e);
                return;
            }
            metrics.add_activation_bytes(stacked_bytes + output.byte_len());
            queue.observe_service_ms(
                dispatched.elapsed().as_secs_f64() * 1e3,
            );
            for (slot, &idx) in misses.iter().enumerate() {
                let r = &batch[idx];
                let latency = r.enqueued.elapsed().as_secs_f64() * 1e3;
                let sched = (dispatched - r.enqueued).as_secs_f64() * 1e3;
                let met = deadline_met(r.deadline);
                metrics.record_request_tenant(
                    r.tenant, r.class, latency, compute_ms, comm_ms, sched,
                    false, met,
                );
                if let Some(c) = cache {
                    // The cache's one deliberate copy: a cached row owns
                    // its storage outright so it can never alias (and be
                    // corrupted through) a live activation buffer.
                    let row_data =
                        &output.data()[slot * row_len..(slot + 1) * row_len];
                    crate::metrics::data_plane::count_copy(
                        (row_data.len() * 4) as u64,
                    );
                    c.put(keys[idx], std::sync::Arc::new(row_data.to_vec()));
                }
                // The response row is a zero-copy view into the batch
                // output (the batch buffer lives as long as any of its
                // row views).
                let out = output.view_rows(slot..slot + 1);
                let outcome = match out {
                    Ok(output) => Outcome::Done(Response {
                        output,
                        latency_ms: latency,
                        compute_ms,
                        comm_ms,
                        cache_hit: false,
                        deadline_met: met,
                    }),
                    Err(e) => Outcome::Failed(e),
                };
                let _ = r.reply.send(outcome);
            }
        }
        Err(e) => {
            if e.downcast_ref::<crate::pipeline::engine::DeadlineShed>()
                .is_some()
            {
                // The engine shed the whole transport pre-admission: the
                // batch deadline was the most lenient member's, so every
                // member's own deadline is blown too.
                for &i in &misses {
                    let r = &batch[i];
                    queue.shed_expired.fetch_add(1, Ordering::Relaxed);
                    metrics.record_shed_tenant(r.tenant, r.class, true);
                    let _ = r
                        .reply
                        .send(Outcome::Shed(ShedReason::DeadlineExpired));
                }
            } else {
                fail_requests(&batch, &misses, metrics, &e);
            }
        }
    }
}

fn deadline_met(deadline: Option<Instant>) -> Option<bool> {
    deadline.map(|d| Instant::now() <= d)
}

fn fail_requests(
    batch: &[QueuedRequest],
    misses: &[usize],
    metrics: &MetricsCollector,
    error: &anyhow::Error,
) {
    let msg = format!("{error:#}");
    for &i in misses {
        let r = &batch[i];
        metrics.record_failure_tenant(r.tenant, r.class);
        let _ = r
            .reply
            .send(Outcome::Failed(anyhow::anyhow!("{msg}")));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A fake service: output = input * 2, sleeps 2 ms per batch.
    struct Doubler {
        batch: usize,
    }

    impl InferenceService for Doubler {
        fn infer_batch(&self, batch: &Tensor) -> Result<(Tensor, f64, f64)> {
            std::thread::sleep(Duration::from_millis(2));
            let data = batch.data().iter().map(|v| v * 2.0).collect();
            Ok((Tensor::new(batch.shape.clone(), data)?, 2.0, 0.1))
        }
        fn batch_size(&self) -> usize {
            self.batch
        }
        fn model_id(&self) -> u64 {
            7
        }
    }

    fn handle_over(batch: usize, cache: Option<Arc<ResultCache>>) -> ServiceHandle {
        ServiceHandle::new(
            Arc::new(Doubler { batch }),
            IngressConfig::default(),
            cache,
        )
    }

    fn req(v: f32) -> Tensor {
        Tensor::new(vec![1, 4], vec![v; 4]).unwrap()
    }

    #[test]
    fn serves_all_requests_with_outputs() {
        let h = handle_over(4, None);
        let responses: Vec<_> = (0..20)
            .map(|i| h.submit(req(i as f32)).unwrap())
            .collect();
        for (i, r) in responses.into_iter().enumerate() {
            let out = r.wait_output().unwrap();
            assert_eq!(out.shape, vec![1, 4]);
            assert_eq!(out.data(), &vec![i as f32 * 2.0; 4][..]);
        }
        let m = h.finish();
        assert_eq!(m.completed, 20);
        assert_eq!(m.failed, 0);
        assert_eq!(m.cache_hits, 0);
        assert!(m.mean_latency_ms() > 0.0);
    }

    #[test]
    fn cache_hits_on_repeated_inputs() {
        let cache = Arc::new(ResultCache::new(16));
        let h = handle_over(1, Some(Arc::clone(&cache)));
        let responses: Vec<_> = (0..30)
            .map(|i| h.submit(req((i % 3) as f32)).unwrap())
            .collect();
        let mut hits = 0;
        for r in responses {
            match r.wait() {
                Outcome::Done(resp) => {
                    if resp.cache_hit {
                        hits += 1;
                    }
                }
                other => panic!("unexpected outcome {other:?}"),
            }
        }
        let m = h.finish();
        assert_eq!(m.completed, 30);
        assert!(m.cache_hits >= 20, "hits {}", m.cache_hits);
        assert_eq!(m.cache_hits, hits);
        assert_eq!(cache.stats().entries, 3);
    }

    #[test]
    fn batching_reduces_service_calls() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct Counting {
            calls: AtomicUsize,
        }
        impl InferenceService for Counting {
            fn infer_batch(&self, batch: &Tensor) -> Result<(Tensor, f64, f64)> {
                self.calls.fetch_add(1, Ordering::SeqCst);
                Ok((batch.clone(), 0.0, 0.0))
            }
            fn batch_size(&self) -> usize {
                8
            }
            fn model_id(&self) -> u64 {
                1
            }
        }
        let svc = Arc::new(Counting { calls: AtomicUsize::new(0) });
        let h = ServiceHandle::new(
            Arc::clone(&svc) as Arc<dyn InferenceService>,
            IngressConfig::default(),
            None,
        );
        let responses: Vec<_> =
            (0..16).map(|i| h.submit(req(i as f32)).unwrap()).collect();
        drop(responses);
        let m = h.finish();
        assert_eq!(m.completed, 16);
        assert!(svc.calls.load(Ordering::SeqCst) <= 8);
    }

    #[test]
    fn padded_rows_override_controls_stacking() {
        struct MicroPad;
        impl InferenceService for MicroPad {
            fn infer_batch(&self, batch: &Tensor) -> Result<(Tensor, f64, f64)> {
                anyhow::ensure!(
                    batch.shape[0] % 2 == 0 && batch.shape[0] < 8,
                    "expected micro-batch-multiple padding, got {:?}",
                    batch.shape
                );
                Ok((batch.clone(), 0.0, 0.0))
            }
            fn batch_size(&self) -> usize {
                8
            }
            fn padded_rows(&self, n: usize) -> usize {
                (n + 1) / 2 * 2
            }
            fn model_id(&self) -> u64 {
                3
            }
        }
        let h = ServiceHandle::new(
            Arc::new(MicroPad),
            IngressConfig::default(),
            None,
        );
        let rs: Vec<_> =
            (0..3).map(|i| h.submit(req(i as f32)).unwrap()).collect();
        drop(rs);
        let m = h.finish();
        assert_eq!(m.completed, 3);
        assert_eq!(m.failed, 0);
    }

    #[test]
    fn failures_are_counted_and_resolve_handles() {
        struct Failing;
        impl InferenceService for Failing {
            fn infer_batch(&self, _batch: &Tensor) -> Result<(Tensor, f64, f64)> {
                anyhow::bail!("boom")
            }
            fn batch_size(&self) -> usize {
                2
            }
            fn model_id(&self) -> u64 {
                2
            }
        }
        let h = ServiceHandle::new(
            Arc::new(Failing),
            IngressConfig::default(),
            None,
        );
        let rs: Vec<_> =
            (0..4).map(|i| h.submit(req(i as f32)).unwrap()).collect();
        for r in rs {
            match r.wait() {
                Outcome::Failed(e) => {
                    assert!(format!("{e:#}").contains("boom"))
                }
                other => panic!("expected failure, got {other:?}"),
            }
        }
        let m = h.finish();
        assert_eq!(m.completed, 0);
        assert_eq!(m.failed, 4);
    }

    /// A service that fails its first `flaky` batch calls then recovers
    /// — the shape of a node death healed a moment later.
    struct FlakyThenHealed {
        flaky: std::sync::atomic::AtomicUsize,
        retries: usize,
    }

    impl InferenceService for FlakyThenHealed {
        fn infer_batch(&self, batch: &Tensor) -> Result<(Tensor, f64, f64)> {
            use std::sync::atomic::Ordering;
            if self
                .flaky
                .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                    n.checked_sub(1)
                })
                .is_ok()
            {
                anyhow::bail!("stage chain lost a node");
            }
            let data = batch.data().iter().map(|v| v * 2.0).collect();
            Ok((Tensor::new(batch.shape.clone(), data)?, 1.0, 0.1))
        }
        fn batch_size(&self) -> usize {
            4
        }
        fn model_id(&self) -> u64 {
            8
        }
        fn failure_retries(&self) -> usize {
            self.retries
        }
    }

    #[test]
    fn failure_retries_ride_out_a_transient() {
        let h = ServiceHandle::new(
            Arc::new(FlakyThenHealed {
                flaky: std::sync::atomic::AtomicUsize::new(1),
                retries: 2,
            }),
            IngressConfig::default(),
            None,
        );
        let rs: Vec<_> =
            (0..4).map(|i| h.submit(req(i as f32)).unwrap()).collect();
        for (i, r) in rs.into_iter().enumerate() {
            let out = r.wait_output().expect("retried batch completes");
            assert_eq!(out.data(), &vec![i as f32 * 2.0; 4][..]);
        }
        let m = h.finish();
        assert_eq!(m.completed, 4);
        assert_eq!(m.failed, 0);
    }

    #[test]
    fn zero_retry_budget_stays_fail_fast() {
        let h = ServiceHandle::new(
            Arc::new(FlakyThenHealed {
                flaky: std::sync::atomic::AtomicUsize::new(1),
                retries: 0,
            }),
            IngressConfig::default(),
            None,
        );
        // One request = one batch: the single flaky call must surface.
        let r = h.submit(req(1.0)).unwrap();
        match r.wait() {
            Outcome::Failed(e) => {
                assert!(format!("{e:#}").contains("lost a node"))
            }
            other => panic!("expected fail-fast failure, got {other:?}"),
        }
        let m = h.finish();
        assert_eq!(m.failed, 1);
    }

    #[test]
    fn expired_deadline_is_shed_not_served() {
        let h = handle_over(1, None);
        // Deadline of ~0: by the time the dispatcher pops it, expired.
        let r = h.req_with_tiny_deadline();
        match r.wait() {
            Outcome::Shed(ShedReason::DeadlineExpired) => {}
            other => panic!("expected expired shed, got {other:?}"),
        }
        let m = h.finish();
        assert_eq!(m.completed, 0);
        let c = m.class(Priority::NORMAL.class()).expect("class metrics");
        assert_eq!(c.shed_expired, 1);
    }

    impl ServiceHandle {
        /// Test helper: a request whose deadline has effectively already
        /// passed at submission.
        fn req_with_tiny_deadline(&self) -> ResponseHandle {
            self.request(req(1.0))
                .deadline(Duration::from_nanos(1))
                .submit()
                .unwrap()
        }
    }

    #[test]
    fn predicted_miss_is_shed_once_estimate_warm() {
        // Doubler sleeps 2 ms per batch; after one completion the EWMA
        // estimate is ~2 ms, so a 0.1 ms deadline sheds predictively.
        let h = handle_over(1, None);
        h.submit(req(1.0)).unwrap().wait_output().unwrap();
        assert!(h.queue().estimate_ms().unwrap() > 0.0);
        let r = h
            .request(req(2.0))
            .deadline(Duration::from_micros(100))
            .submit()
            .unwrap();
        match r.wait() {
            Outcome::Shed(_) => {}
            other => panic!("expected shed, got {other:?}"),
        }
        let (expired, predicted) = h.queue().shed_counts();
        assert_eq!(expired + predicted, 1);
        let m = h.finish();
        assert_eq!(m.completed, 1);
    }

    #[test]
    fn deep_queue_sheds_doomed_deadline_at_submission() {
        // Queue-depth-aware predictive shedding: a deadline that one
        // batch wave could meet (slack > EWMA estimate) is still doomed
        // behind a deep same-class backlog — it must resolve as
        // PredictedMiss *at submission*, before waiting in the queue.
        let h = ServiceHandle::new(
            Arc::new(Doubler { batch: 1 }),
            IngressConfig {
                workers: 1,
                capacity: 256,
                max_wait: Duration::from_millis(1),
                ..IngressConfig::default()
            },
            None,
        );
        // Warm the estimate (~2 ms per batch).
        h.submit(req(0.0)).unwrap().wait_output().unwrap();
        let est = h.queue().estimate_ms().expect("warm estimate");
        // Same-class backlog: tens of batch waves ahead.
        let backlog: Vec<_> =
            (0..40).map(|i| h.submit(req(i as f32)).unwrap()).collect();
        assert!(h.queue().queued_ahead(Priority::NORMAL.class()) > 5);
        // Slack comfortably above one wave's estimate, far below the
        // backlog's: the single-wave dispatch check would admit it, the
        // depth-aware one sheds it immediately.
        let doomed = h
            .request(req(99.0))
            .deadline(Duration::from_secs_f64(est * 3.0 / 1e3))
            .submit()
            .unwrap();
        match doomed.try_wait() {
            Some(Outcome::Shed(ShedReason::PredictedMiss)) => {}
            other => panic!(
                "expected an immediate predicted-miss shed, got {other:?}"
            ),
        }
        for r in backlog {
            r.wait_output().unwrap();
        }
        let m = h.finish();
        assert_eq!(m.completed, 41);
        let c = m.class(Priority::NORMAL.class()).unwrap();
        assert_eq!(c.shed_predicted, 1);
    }

    #[test]
    fn priority_lanes_dequeue_high_first() {
        // Single worker + a service gated on a channel: the first batch
        // blocks the worker, everything else queues; when released, the
        // high-priority request must be dispatched before the earlier
        // best-effort backlog.
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::mpsc::SyncSender;
        struct Gated {
            gate: Mutex<std::sync::mpsc::Receiver<()>>,
            order: Mutex<Vec<usize>>,
            calls: AtomicUsize,
        }
        impl InferenceService for Gated {
            fn infer_batch(&self, batch: &Tensor) -> Result<(Tensor, f64, f64)> {
                Ok((batch.clone(), 0.0, 0.0))
            }
            fn infer_batch_meta(
                &self,
                batch: &Tensor,
                meta: BatchMeta,
            ) -> Result<(Tensor, f64, f64)> {
                self.calls.fetch_add(1, Ordering::SeqCst);
                self.order.lock().unwrap().push(meta.class);
                let _ = self.gate.lock().unwrap().recv();
                self.infer_batch(batch)
            }
            fn batch_size(&self) -> usize {
                1
            }
            fn model_id(&self) -> u64 {
                5
            }
        }
        let (gate_tx, gate_rx): (SyncSender<()>, _) =
            std::sync::mpsc::sync_channel(64);
        let svc = Arc::new(Gated {
            gate: Mutex::new(gate_rx),
            order: Mutex::new(Vec::new()),
            calls: AtomicUsize::new(0),
        });
        let h = ServiceHandle::new(
            Arc::clone(&svc) as Arc<dyn InferenceService>,
            IngressConfig {
                workers: 1,
                max_wait: Duration::from_millis(1),
                ..IngressConfig::default()
            },
            None,
        );
        // 4 best-effort requests; the first occupies the single worker.
        let rs: Vec<_> = (0..4)
            .map(|i| {
                h.request(req(i as f32))
                    .priority(Priority::BEST_EFFORT)
                    .submit()
                    .unwrap()
            })
            .collect();
        // Wait until the first batch is actually in the worker.
        while svc.calls.load(Ordering::SeqCst) == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        // Now a high-priority request arrives behind the backlog.
        let hi = h
            .request(req(9.0))
            .priority(Priority::HIGH)
            .submit()
            .unwrap();
        // Release everything.
        for _ in 0..8 {
            let _ = gate_tx.send(());
        }
        hi.wait_output().unwrap();
        for r in rs {
            r.wait_output().unwrap();
        }
        let m = h.finish();
        assert_eq!(m.completed, 5);
        let order = svc.order.lock().unwrap().clone();
        // First dispatched batch was best-effort (it was alone); the
        // high-priority class must appear before the best-effort
        // backlog finishes.
        let hi_pos = order
            .iter()
            .position(|&c| c == Priority::HIGH.class())
            .expect("high-priority batch dispatched");
        assert!(
            order[hi_pos + 1..]
                .contains(&Priority::BEST_EFFORT.class()),
            "high priority did not jump the backlog: {order:?}"
        );
    }

    #[test]
    fn per_class_metrics_are_recorded() {
        let h = handle_over(2, None);
        let a = h
            .request(req(1.0))
            .priority(Priority::HIGH)
            .deadline(Duration::from_secs(10))
            .submit()
            .unwrap();
        let b = h
            .request(req(2.0))
            .priority(Priority::BEST_EFFORT)
            .submit()
            .unwrap();
        a.wait_output().unwrap();
        b.wait_output().unwrap();
        let m = h.finish();
        let hi = m.class(Priority::HIGH.class()).expect("high class");
        assert_eq!(hi.completed, 1);
        assert_eq!(hi.deadline_total, 1);
        assert_eq!(hi.deadline_met, 1);
        let be = m
            .class(Priority::BEST_EFFORT.class())
            .expect("best-effort class");
        assert_eq!(be.completed, 1);
        assert_eq!(be.deadline_total, 0);
    }

    #[test]
    fn backpressure_blocks_then_accepts() {
        // Capacity 2 with a slow single worker: the third submit blocks
        // until the dispatcher drains one — and everything completes.
        let h = ServiceHandle::new(
            Arc::new(Doubler { batch: 1 }),
            IngressConfig {
                capacity: 2,
                workers: 1,
                max_wait: Duration::from_millis(1),
                ..IngressConfig::default()
            },
            None,
        );
        let rs: Vec<_> =
            (0..8).map(|i| h.submit(req(i as f32)).unwrap()).collect();
        for r in rs {
            r.wait_output().unwrap();
        }
        let m = h.finish();
        assert_eq!(m.completed, 8);
    }

    #[test]
    fn finish_drains_and_closed_queue_rejects_pushes() {
        let h = handle_over(1, None);
        let q = Arc::clone(&h.queue);
        let m = h.finish();
        assert_eq!(m.completed, 0);
        assert_eq!(q.len(), 0);
        // The closed queue refuses new work (returns false, does not
        // block); the dropped reply sender resolves the would-be
        // handle.
        let (reply, rx) = channel();
        let rejected = QueuedRequest {
            input: req(1.0),
            class: 0,
            tenant: 0,
            deadline: None,
            tag: None,
            enqueued: Instant::now(),
            reply,
        };
        assert!(!q.push(rejected));
        assert!(matches!(
            (ResponseHandle { rx }).wait(),
            Outcome::Failed(_)
        ));
    }

    #[test]
    fn panicking_service_resolves_handles_and_keeps_serving() {
        // A service that panics on a sentinel input must fail only that
        // request's handle; the worker, slot, and drain bookkeeping all
        // survive, so later requests complete and finish() returns.
        struct Landmine;
        impl InferenceService for Landmine {
            fn infer_batch(&self, batch: &Tensor) -> Result<(Tensor, f64, f64)> {
                if batch.data().first() == Some(&13.0) {
                    panic!("injected service panic");
                }
                Ok((batch.clone(), 0.0, 0.0))
            }
            fn batch_size(&self) -> usize {
                1
            }
            fn model_id(&self) -> u64 {
                13
            }
        }
        let h = ServiceHandle::new(
            Arc::new(Landmine),
            IngressConfig { workers: 1, ..IngressConfig::default() },
            None,
        );
        let boom = h.submit(req(13.0)).unwrap();
        match boom.wait() {
            Outcome::Failed(_) => {}
            other => panic!("expected failure, got {other:?}"),
        }
        // The single worker survived the panic and keeps serving.
        let ok = h.submit(req(2.0)).unwrap();
        assert_eq!(ok.wait_output().unwrap().data(), &[2.0; 4][..]);
        let m = h.finish();
        assert_eq!(m.completed, 1);
    }

    fn queued(v: f32, class: usize, tenant: usize) -> QueuedRequest {
        let (reply, _rx) = channel();
        QueuedRequest {
            input: req(v),
            class,
            tenant,
            deadline: None,
            tag: None,
            enqueued: Instant::now(),
            reply,
        }
    }

    #[test]
    fn wfq_lane_interleaves_tenants_within_a_class() {
        // Equal weights, both tenants backlogged in one class: the lane
        // must alternate between them instead of draining tenant 0
        // first (which plain FIFO arrival order would do here).
        let q = IngressQueue::new(64, 2, &[1.0, 1.0]);
        for i in 0..4 {
            assert!(q.push(queued(i as f32, 0, 0)));
        }
        for i in 0..4 {
            assert!(q.push(queued(10.0 + i as f32, 0, 1)));
        }
        let mut tenants = Vec::new();
        let mut st = q.state.lock().unwrap();
        while let Some(r) = IngressQueue::take(&mut st) {
            tenants.push(r.tenant);
        }
        drop(st);
        assert_eq!(tenants.len(), 8);
        assert_eq!(
            tenants,
            vec![0, 1, 0, 1, 0, 1, 0, 1],
            "equal-weight DRR must alternate tenants"
        );
        // Strict priority still wins across classes: a class-0 arrival
        // from any tenant jumps a class-1 backlog.
        let q = IngressQueue::new(64, 2, &[1.0, 1.0]);
        assert!(q.push(queued(1.0, 1, 0)));
        assert!(q.push(queued(2.0, 0, 1)));
        let mut st = q.state.lock().unwrap();
        assert_eq!(IngressQueue::take(&mut st).unwrap().class, 0);
        assert_eq!(IngressQueue::take(&mut st).unwrap().class, 1);
    }

    #[test]
    fn single_tenant_lane_is_plain_fifo() {
        // No weight table: one queue per lane, arrival order preserved
        // exactly (the PR-8 degeneracy guarantee, structurally).
        let q = IngressQueue::new(64, 1, &[]);
        for i in 0..6 {
            assert!(q.push(queued(i as f32, 0, 0)));
        }
        let mut st = q.state.lock().unwrap();
        assert_eq!(st.lanes[0].queues.len(), 1);
        let mut order = Vec::new();
        while let Some(r) = IngressQueue::take(&mut st) {
            order.push(r.input.data()[0]);
        }
        assert_eq!(order, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn per_tenant_metrics_are_recorded() {
        let h = ServiceHandle::new(
            Arc::new(Doubler { batch: 2 }),
            IngressConfig {
                tenant_weights: vec![2.0, 1.0],
                ..IngressConfig::default()
            },
            None,
        );
        let a = h.request(req(1.0)).tenant(0).submit().unwrap();
        let b = h.request(req(2.0)).tenant(1).submit().unwrap();
        // Out-of-range tenants clamp to the last configured one.
        let c = h.request(req(3.0)).tenant(99).submit().unwrap();
        for r in [a, b, c] {
            r.wait_output().unwrap();
        }
        let m = h.finish();
        assert_eq!(m.completed, 3);
        assert_eq!(m.tenant_completed(0), 1);
        assert_eq!(m.tenant_completed(1), 2);
        let t1 = m
            .tenant_class(1, Priority::NORMAL.class())
            .expect("tenant 1 metrics");
        assert_eq!(t1.completed, 2);
    }

    #[test]
    fn class_names_render() {
        assert_eq!(class_name(0), "high");
        assert_eq!(class_name(1), "normal");
        assert_eq!(class_name(2), "best-effort");
        assert_eq!(class_name(7), "class-7");
    }
}
