//! Per-node executor thread.
//!
//! The `xla` crate's handles (`PjRtClient`, `PjRtBuffer`,
//! `PjRtLoadedExecutable`) are `!Send`/`!Sync` (Rc + raw pointers), so they
//! must live and die on one thread. Each virtual edge node therefore runs
//! a dedicated executor thread that owns its *own* PJRT CPU client,
//! compiled executables, and device-resident weight buffers — which is
//! also the honest simulation of the paper's deployment: every edge
//! container runs its own model server with its own runtime.
//!
//! The handle is `Send + Sync` (it is just an mpsc sender), so the router
//! worker pool can drive many nodes concurrently for true pipeline
//! overlap.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Sender};
use std::sync::Arc;
use std::thread;
use anyhow::{Context, Result};

use super::{Tensor, XlaRuntime};

/// Identifies a (compiled executable + uploaded weights) pair on the
/// executor thread.
pub type BlockHandle = usize;

/// CPU time consumed by the calling thread, in milliseconds.
pub fn thread_cpu_ms() -> f64 {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    let rc = unsafe {
        libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts)
    };
    if rc != 0 {
        return 0.0;
    }
    ts.tv_sec as f64 * 1e3 + ts.tv_nsec as f64 / 1e6
}

enum Command {
    /// Compile an HLO artifact and upload its weight sidecar.
    Load {
        hlo: PathBuf,
        weights: PathBuf,
        param_count: usize,
        out_shape: Vec<usize>,
        reply: Sender<Result<BlockHandle>>,
    },
    /// Run a chain of loaded blocks, feeding each output to the next.
    RunChain {
        blocks: Vec<BlockHandle>,
        input: Tensor,
        reply: Sender<Result<(Tensor, f64)>>,
    },
    /// Drop a loaded block (undeploy).
    Unload {
        block: BlockHandle,
        reply: Sender<()>,
    },
    Shutdown,
}

struct Loaded {
    exe: super::Executable,
    weights: super::DeviceBuffer,
    out_shape: Vec<usize>,
}

/// Handle to one node's executor thread. Cloneable and thread-safe.
pub struct Executor {
    tx: Sender<Command>,
    thread: Option<thread::JoinHandle<()>>,
    name: String,
    /// Chain runs submitted but not yet completed on the executor
    /// thread — the per-node backlog gauge the streaming engine and
    /// monitors can read without blocking.
    pending: Arc<AtomicUsize>,
}

impl Executor {
    /// Spawn the executor thread (creates its own PJRT CPU client).
    pub fn spawn(name: &str) -> Result<Executor> {
        let (tx, rx) = mpsc::channel::<Command>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let pending = Arc::new(AtomicUsize::new(0));
        let pending_t = Arc::clone(&pending);
        let tname = name.to_string();
        let thread = thread::Builder::new()
            .name(format!("exec-{name}"))
            .spawn(move || {
                let rt = match XlaRuntime::cpu() {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let mut loaded: HashMap<BlockHandle, Loaded> = HashMap::new();
                let mut next_id: BlockHandle = 0;
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Command::Load { hlo, weights, param_count, out_shape, reply } => {
                            let result = (|| {
                                let exe = rt.load_hlo(&hlo)?;
                                let w = Tensor::from_f32_file(
                                    &weights,
                                    vec![param_count],
                                )?;
                                let wbuf = rt.upload(&w)?;
                                Ok::<_, anyhow::Error>(Loaded {
                                    exe,
                                    weights: wbuf,
                                    out_shape,
                                })
                            })();
                            let _ = reply.send(result.map(|l| {
                                let id = next_id;
                                next_id += 1;
                                loaded.insert(id, l);
                                id
                            }));
                        }
                        Command::RunChain { blocks, input, reply } => {
                            let t0 = thread_cpu_ms();
                            let result = (|| {
                                let mut cur = input;
                                for b in &blocks {
                                    let l = loaded.get(b).with_context(|| {
                                        format!("block handle {b} not loaded")
                                    })?;
                                    let act = rt.upload(&cur)?;
                                    let out = l.exe.run_with_weights(
                                        &l.weights,
                                        &act,
                                        &l.out_shape,
                                    )?;
                                    // The consumed activation's buffer
                                    // feeds the pool once it is device
                                    // resident (no-op for shared views).
                                    std::mem::replace(&mut cur, out)
                                        .recycle();
                                }
                                Ok::<_, anyhow::Error>(cur)
                            })();
                            // Thread CPU time, not wall time: excludes
                            // contention from other executor threads on
                            // the shared build host, so the virtual
                            // node's CPU-quota dilation is applied to
                            // the *nominal* compute cost (a real edge
                            // device does not share cores with its
                            // peers).
                            let host_ms = thread_cpu_ms() - t0;
                            // Relaxed: the gauge is monotonic bookkeeping,
                            // not a synchronization edge — keep the hot
                            // path free of ordering cost.
                            pending_t.fetch_sub(1, Ordering::Relaxed);
                            let _ = reply.send(result.map(|t| (t, host_ms)));
                        }
                        Command::Unload { block, reply } => {
                            loaded.remove(&block);
                            let _ = reply.send(());
                        }
                        Command::Shutdown => break,
                    }
                }
                let _ = tname; // keep for debugging symmetry
            })
            .context("spawning executor thread")?;
        ready_rx
            .recv()
            .context("executor thread died during init")??;
        Ok(Executor {
            tx,
            thread: Some(thread),
            name: name.to_string(),
            pending,
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Chain runs queued or executing on this node right now. The
    /// persistent pipeline engine keeps each stage's executor fed from
    /// its driver thread; this gauge exposes the resulting per-node
    /// backlog for diagnostics and depth decisions.
    pub fn queue_depth(&self) -> usize {
        self.pending.load(Ordering::Relaxed)
    }

    /// Compile an artifact and upload its weights; returns a handle.
    pub fn load_block(
        &self,
        hlo: PathBuf,
        weights: PathBuf,
        param_count: usize,
        out_shape: Vec<usize>,
    ) -> Result<BlockHandle> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Command::Load { hlo, weights, param_count, out_shape, reply })
            .map_err(|_| anyhow::anyhow!("executor {} gone", self.name))?;
        rx.recv().map_err(|_| anyhow::anyhow!("executor {} died", self.name))?
    }

    /// Submit a chain run without blocking: the command is queued on the
    /// executor thread and a [`PendingRun`] is returned immediately,
    /// letting one caller thread keep several nodes' executors busy at
    /// once. [`Executor::run_chain`] is the blocking submit-and-wait
    /// over this primitive; the streaming engine gets its concurrency
    /// from per-stage driver threads instead, so this is the building
    /// block for callers that fan out across nodes from a single thread
    /// (e.g. calibration sweeps or future cross-batch streaming).
    pub fn submit_chain(
        &self,
        blocks: Vec<BlockHandle>,
        input: Tensor,
    ) -> Result<PendingRun> {
        let (reply, rx) = mpsc::channel();
        self.pending.fetch_add(1, Ordering::Relaxed);
        if self
            .tx
            .send(Command::RunChain { blocks, input, reply })
            .is_err()
        {
            self.pending.fetch_sub(1, Ordering::Relaxed);
            anyhow::bail!("executor {} gone", self.name);
        }
        Ok(PendingRun { rx, name: self.name.clone() })
    }

    /// Run loaded blocks as a chain. Returns output + host compute cost
    /// in thread-CPU milliseconds (contention-free nominal cost).
    /// Blocking convenience over [`Executor::submit_chain`].
    pub fn run_chain(
        &self,
        blocks: Vec<BlockHandle>,
        input: Tensor,
    ) -> Result<(Tensor, f64)> {
        self.submit_chain(blocks, input)?.wait()
    }

    pub fn unload_block(&self, block: BlockHandle) {
        let (reply, rx) = mpsc::channel();
        if self.tx.send(Command::Unload { block, reply }).is_ok() {
            let _ = rx.recv();
        }
    }
}

/// An in-flight [`Executor::submit_chain`] call. The executor thread is
/// already working on it; `wait` collects the result.
pub struct PendingRun {
    rx: mpsc::Receiver<Result<(Tensor, f64)>>,
    name: String,
}

impl PendingRun {
    /// Block until the chain finishes; returns output + host compute
    /// cost in thread-CPU milliseconds.
    pub fn wait(self) -> Result<(Tensor, f64)> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("executor {} died", self.name))?
    }

    /// Non-blocking poll: `None` while the chain is still running. A
    /// dead executor yields `Some(Err(..))`, not `None` — otherwise a
    /// poll loop would spin forever on a crashed node.
    pub fn try_wait(&self) -> Option<Result<(Tensor, f64)>> {
        match self.rx.try_recv() {
            Ok(result) => Some(result),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => Some(Err(
                anyhow::anyhow!("executor {} died", self.name),
            )),
        }
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        let _ = self.tx.send(Command::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

// Executor integration tests (needing real artifacts) live in rust/tests/.

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_depth_tracks_chain_submissions() {
        // The stub PJRT client boots, so spawn works without artifacts;
        // a chain on an unloaded handle errors on the executor thread
        // but must still balance the pending gauge.
        let exec = Executor::spawn("gauge-test").unwrap();
        assert_eq!(exec.queue_depth(), 0);
        let run = exec
            .submit_chain(vec![0], Tensor::zeros(vec![1, 2]))
            .unwrap();
        assert!(run.wait().is_err(), "unloaded handle must error");
        assert_eq!(
            exec.queue_depth(),
            0,
            "gauge must return to zero after completion (even on error)"
        );
    }
}
