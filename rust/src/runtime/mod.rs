//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! client (the `xla` crate). This is the only module that touches XLA —
//! everything above it works with [`Tensor`]s.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file
//! -> XlaComputation::from_proto -> client.compile -> execute`. Artifacts
//! are HLO *text*, not serialized protos (jax >= 0.5 emits 64-bit ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns them).
//!
//! Every block artifact has the signature `(weights f32[P], x f32[B,H,W,C])
//! -> (y,)` — a 1-tuple because the AOT path lowers with
//! `return_tuple=True`. Weights are uploaded once per deployment as a
//! device-resident [`xla::PjRtBuffer`] and reused across requests (the hot
//! path only uploads the activation).

pub mod executor;

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

pub use executor::{BlockHandle, Executor, PendingRun};

/// A host-side f32 tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let expect: usize = shape.iter().product();
        anyhow::ensure!(
            expect == data.len(),
            "shape {:?} needs {expect} elements, got {}",
            shape,
            data.len()
        );
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn byte_len(&self) -> u64 {
        (self.data.len() * 4) as u64
    }

    /// Read a little-endian f32 binary sidecar (weights / goldens).
    pub fn from_f32_file(path: &Path, shape: Vec<usize>) -> Result<Tensor> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        anyhow::ensure!(
            bytes.len() % 4 == 0,
            "{} is not a multiple of 4 bytes",
            path.display()
        );
        let mut data = Vec::with_capacity(bytes.len() / 4);
        for chunk in bytes.chunks_exact(4) {
            data.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        Tensor::new(shape, data)
    }

    /// Max |a-b| against another tensor (golden comparisons).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Shared PJRT CPU client.
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

impl XlaRuntime {
    /// Create the CPU client. One per process is plenty.
    pub fn cpu() -> Result<XlaRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(XlaRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))?;
        Ok(Executable {
            exe: Arc::new(exe),
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }

    /// Upload a tensor to a device-resident buffer (weights, reused across
    /// calls).
    pub fn upload(&self, t: &Tensor) -> Result<DeviceBuffer> {
        let buf = self
            .client
            .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
            .map_err(|e| anyhow::anyhow!("uploading buffer: {e:?}"))?;
        Ok(DeviceBuffer { buf, shape: t.shape.clone() })
    }
}

/// A device-resident input buffer (weights stay uploaded per deployment).
pub struct DeviceBuffer {
    buf: xla::PjRtBuffer,
    pub shape: Vec<usize>,
}

/// A compiled HLO module ready to execute.
#[derive(Clone)]
pub struct Executable {
    exe: Arc<xla::PjRtLoadedExecutable>,
    pub name: String,
}

impl Executable {
    /// Execute with host tensors (uploads everything; convenience path).
    pub fn run(&self, inputs: &[&Tensor], out_shape: &[usize]) -> Result<Tensor> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .map_err(|e| anyhow::anyhow!("reshape literal: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let out = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.name))?;
        self.collect_output(out, out_shape)
    }

    /// Hot path: device-resident weights + freshly-uploaded activation.
    pub fn run_with_weights(
        &self,
        weights: &DeviceBuffer,
        activation: &DeviceBuffer,
        out_shape: &[usize],
    ) -> Result<Tensor> {
        let out = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&[&weights.buf, &activation.buf])
            .map_err(|e| anyhow::anyhow!("execute_b {}: {e:?}", self.name))?;
        self.collect_output(out, out_shape)
    }

    fn collect_output(
        &self,
        out: Vec<Vec<xla::PjRtBuffer>>,
        out_shape: &[usize],
    ) -> Result<Tensor> {
        anyhow::ensure!(
            !out.is_empty() && !out[0].is_empty(),
            "executable {} produced no output",
            self.name
        );
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch output: {e:?}"))?;
        // AOT lowers with return_tuple=True: unwrap the 1-tuple.
        let inner = lit
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple output: {e:?}"))?;
        let data = inner
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("output to_vec: {e:?}"))?;
        Tensor::new(out_shape.to_vec(), data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_validation() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        let z = Tensor::zeros(vec![4, 4]);
        assert_eq!(z.len(), 16);
        assert_eq!(z.byte_len(), 64);
    }

    #[test]
    fn tensor_from_file_roundtrip() {
        let dir = std::env::temp_dir().join("amp4ec_test_tensor");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let vals: Vec<f32> = vec![1.5, -2.25, 3.0];
        let bytes: Vec<u8> =
            vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let t = Tensor::from_f32_file(&path, vec![3]).unwrap();
        assert_eq!(t.data, vals);
        assert!(Tensor::from_f32_file(&path, vec![4]).is_err());
    }

    #[test]
    fn max_abs_diff() {
        let a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::new(vec![3], vec![1.0, 2.5, 2.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }

    // PJRT-backed tests live in rust/tests/ since they need artifacts.
}
