//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! client (the `xla` crate). This is the only module that touches XLA —
//! everything above it works with [`Tensor`]s.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file
//! -> XlaComputation::from_proto -> client.compile -> execute`. Artifacts
//! are HLO *text*, not serialized protos (jax >= 0.5 emits 64-bit ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns them).
//!
//! Every block artifact has the signature `(weights f32[P], x f32[B,H,W,C])
//! -> (y,)` — a 1-tuple because the AOT path lowers with
//! `return_tuple=True`. Weights are uploaded once per deployment as a
//! device-resident [`xla::PjRtBuffer`] and reused across requests (the hot
//! path only uploads the activation).

pub mod executor;

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

pub use executor::{BlockHandle, Executor, PendingRun};

/// Shared activation storage: one refcounted buffer backing any number
/// of [`Tensor`] views. `Arc<Vec<f32>>` rather than `Arc<[f32]>` so a
/// `Vec` wraps with **zero copy** and a sole-owner buffer can be
/// reclaimed into the [`crate::util::pool::BufferPool`]
/// (`Arc::try_unwrap`) when its last view drops.
pub type TensorBuf = Arc<Vec<f32>>;

/// A host-side f32 tensor (row-major): a shape plus a *view* into a
/// shared backing buffer (`offset..offset + len` elements of `buf`).
///
/// Cloning a tensor, slicing rows out of it ([`Tensor::view_rows`]),
/// and splitting a batch into micro-batches are all refcount-and-slice
/// operations — no activation bytes move. The data plane copies only
/// when fresh contiguous storage is genuinely required (zero-padding,
/// stacking disjoint buffers, executor output collection); every such
/// copy is counted in [`crate::metrics::data_plane`] so the zero-copy
/// win stays measurable.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    buf: TensorBuf,
    offset: usize,
    len: usize,
}

impl PartialEq for Tensor {
    fn eq(&self, other: &Tensor) -> bool {
        self.shape == other.shape && self.data() == other.data()
    }
}

impl Tensor {
    /// Wrap an owned buffer — zero copy.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let expect: usize = shape.iter().product();
        anyhow::ensure!(
            expect == data.len(),
            "shape {:?} needs {expect} elements, got {}",
            shape,
            data.len()
        );
        let len = data.len();
        Ok(Tensor { shape, buf: Arc::new(data), offset: 0, len })
    }

    /// View into an already-shared buffer — zero copy. The view covers
    /// `offset..offset + shape.product()` elements of `buf`.
    pub fn from_buf(
        shape: Vec<usize>,
        buf: TensorBuf,
        offset: usize,
    ) -> Result<Tensor> {
        let len: usize = shape.iter().product();
        anyhow::ensure!(
            offset.checked_add(len).is_some_and(|end| end <= buf.len()),
            "view of {len} elements at offset {offset} outside buffer of \
             {} elements",
            buf.len()
        );
        Ok(Tensor { shape, buf, offset, len })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, buf: Arc::new(vec![0.0; n]), offset: 0, len: n }
    }

    /// The view's elements.
    pub fn data(&self) -> &[f32] {
        &self.buf[self.offset..self.offset + self.len]
    }

    /// Mutable access, copy-on-write: a sole-owner full-buffer tensor
    /// mutates in place; a shared or partial view first materializes its
    /// own buffer (a counted copy). Mutating through here can therefore
    /// never alter another view or a cached row.
    pub fn data_mut(&mut self) -> &mut [f32] {
        let exclusive = self.offset == 0
            && self.len == self.buf.len()
            && Arc::get_mut(&mut self.buf).is_some();
        if !exclusive {
            crate::metrics::data_plane::count_copy(self.byte_len());
            let mut owned =
                crate::util::pool::BufferPool::global().take(self.len);
            owned.extend_from_slice(self.data());
            self.buf = Arc::new(owned);
            self.offset = 0;
        }
        let len = self.len;
        &mut Arc::get_mut(&mut self.buf).expect("exclusive buffer")[..len]
    }

    /// The shared backing buffer (for contiguity checks — two views are
    /// adjacent when they share a buffer and their ranges abut).
    pub fn buf(&self) -> &TensorBuf {
        &self.buf
    }

    /// Element offset of this view inside [`Tensor::buf`].
    pub fn offset(&self) -> usize {
        self.offset
    }

    /// Elements per row (`shape[1..]` product).
    pub fn row_len(&self) -> usize {
        self.shape.iter().skip(1).product()
    }

    /// Zero-copy view of a contiguous row range of a `[rows, ...]`
    /// tensor: shares the backing buffer, adjusts offset and shape.
    pub fn view_rows(&self, range: std::ops::Range<usize>) -> Result<Tensor> {
        anyhow::ensure!(
            !self.shape.is_empty()
                && range.start < range.end
                && range.end <= self.shape[0],
            "row range {range:?} outside tensor {:?}",
            self.shape
        );
        let row_len = self.row_len();
        let mut shape = self.shape.clone();
        shape[0] = range.end - range.start;
        crate::metrics::data_plane::count_view(
            ((range.end - range.start) * row_len * 4) as u64,
        );
        Ok(Tensor {
            shape,
            buf: Arc::clone(&self.buf),
            offset: self.offset + range.start * row_len,
            len: (range.end - range.start) * row_len,
        })
    }

    /// Whether `next` is the view immediately following this one in the
    /// same backing buffer (so the pair concatenates without a copy).
    pub fn abuts(&self, next: &Tensor) -> bool {
        Arc::ptr_eq(&self.buf, &next.buf)
            && self.offset + self.len == next.offset
    }

    /// Copy the view out into an owned `Vec` (counted, pooled storage).
    pub fn to_vec(&self) -> Vec<f32> {
        crate::metrics::data_plane::count_copy(self.byte_len());
        let mut out = crate::util::pool::BufferPool::global().take(self.len);
        out.extend_from_slice(self.data());
        out
    }

    /// Consume the tensor into an owned `Vec`: zero-copy when this view
    /// is the buffer's sole owner and covers it fully, a counted copy
    /// (from pooled storage) otherwise.
    pub fn into_vec(self) -> Vec<f32> {
        if self.offset == 0 && self.len == self.buf.len() {
            match Arc::try_unwrap(self.buf) {
                Ok(v) => return v,
                Err(buf) => {
                    crate::metrics::data_plane::count_copy(
                        (self.len * 4) as u64,
                    );
                    let mut out = crate::util::pool::BufferPool::global()
                        .take(self.len);
                    out.extend_from_slice(&buf[..self.len]);
                    return out;
                }
            }
        }
        crate::metrics::data_plane::count_copy((self.len * 4) as u64);
        let mut out = crate::util::pool::BufferPool::global().take(self.len);
        out.extend_from_slice(self.data());
        out
    }

    /// Drop the tensor, returning its backing storage to the global
    /// [`crate::util::pool::BufferPool`] when this view was the sole
    /// owner (no-op otherwise). Hot loops that churn activations call
    /// this so fresh-allocation sites can reuse the storage.
    pub fn recycle(self) {
        if self.offset == 0 && self.len == self.buf.len() {
            if let Ok(v) = Arc::try_unwrap(self.buf) {
                crate::util::pool::BufferPool::global().give(v);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn byte_len(&self) -> u64 {
        (self.len * 4) as u64
    }

    /// Read a little-endian f32 binary sidecar (weights / goldens).
    pub fn from_f32_file(path: &Path, shape: Vec<usize>) -> Result<Tensor> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        anyhow::ensure!(
            bytes.len() % 4 == 0,
            "{} is not a multiple of 4 bytes",
            path.display()
        );
        let mut data = Vec::with_capacity(bytes.len() / 4);
        for chunk in bytes.chunks_exact(4) {
            data.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        Tensor::new(shape, data)
    }

    /// Max |a-b| against another tensor (golden comparisons).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data()
            .iter()
            .zip(other.data().iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Shared PJRT CPU client.
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

impl XlaRuntime {
    /// Create the CPU client. One per process is plenty.
    pub fn cpu() -> Result<XlaRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(XlaRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))?;
        Ok(Executable {
            exe: Arc::new(exe),
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }

    /// Upload a tensor to a device-resident buffer (weights, reused across
    /// calls).
    pub fn upload(&self, t: &Tensor) -> Result<DeviceBuffer> {
        let buf = self
            .client
            .buffer_from_host_buffer::<f32>(t.data(), &t.shape, None)
            .map_err(|e| anyhow::anyhow!("uploading buffer: {e:?}"))?;
        Ok(DeviceBuffer { buf, shape: t.shape.clone() })
    }
}

/// A device-resident input buffer (weights stay uploaded per deployment).
pub struct DeviceBuffer {
    buf: xla::PjRtBuffer,
    pub shape: Vec<usize>,
}

/// A compiled HLO module ready to execute.
#[derive(Clone)]
pub struct Executable {
    exe: Arc<xla::PjRtLoadedExecutable>,
    pub name: String,
}

impl Executable {
    /// Execute with host tensors (uploads everything; convenience path).
    pub fn run(&self, inputs: &[&Tensor], out_shape: &[usize]) -> Result<Tensor> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(t.data())
                    .reshape(&dims)
                    .map_err(|e| anyhow::anyhow!("reshape literal: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let out = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.name))?;
        self.collect_output(out, out_shape)
    }

    /// Hot path: device-resident weights + freshly-uploaded activation.
    pub fn run_with_weights(
        &self,
        weights: &DeviceBuffer,
        activation: &DeviceBuffer,
        out_shape: &[usize],
    ) -> Result<Tensor> {
        let out = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&[&weights.buf, &activation.buf])
            .map_err(|e| anyhow::anyhow!("execute_b {}: {e:?}", self.name))?;
        self.collect_output(out, out_shape)
    }

    fn collect_output(
        &self,
        out: Vec<Vec<xla::PjRtBuffer>>,
        out_shape: &[usize],
    ) -> Result<Tensor> {
        anyhow::ensure!(
            !out.is_empty() && !out[0].is_empty(),
            "executable {} produced no output",
            self.name
        );
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch output: {e:?}"))?;
        // AOT lowers with return_tuple=True: unwrap the 1-tuple.
        let inner = lit
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("untuple output: {e:?}"))?;
        let data = inner
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("output to_vec: {e:?}"))?;
        Tensor::new(out_shape.to_vec(), data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_validation() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        let z = Tensor::zeros(vec![4, 4]);
        assert_eq!(z.len(), 16);
        assert_eq!(z.byte_len(), 64);
    }

    #[test]
    fn tensor_from_file_roundtrip() {
        let dir = std::env::temp_dir().join("amp4ec_test_tensor");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let vals: Vec<f32> = vec![1.5, -2.25, 3.0];
        let bytes: Vec<u8> =
            vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let t = Tensor::from_f32_file(&path, vec![3]).unwrap();
        assert_eq!(t.data(), &vals[..]);
        assert!(Tensor::from_f32_file(&path, vec![4]).is_err());
    }

    #[test]
    fn max_abs_diff() {
        let a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::new(vec![3], vec![1.0, 2.5, 2.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }

    #[test]
    fn view_rows_shares_the_backing_buffer() {
        let t = Tensor::new(vec![4, 2], (0..8).map(|i| i as f32).collect())
            .unwrap();
        let mid = t.view_rows(1..3).unwrap();
        assert_eq!(mid.shape, vec![2, 2]);
        assert_eq!(mid.data(), &[2.0, 3.0, 4.0, 5.0]);
        assert!(Arc::ptr_eq(t.buf(), mid.buf()), "view must not copy");
        assert_eq!(mid.offset(), 2);
        assert_eq!(mid.byte_len(), 16);
        assert!(t.view_rows(3..5).is_err());
        assert!(t.view_rows(2..2).is_err());
        // Adjacent views abut; overlapping/gapped ones do not.
        let head = t.view_rows(0..1).unwrap();
        assert!(head.abuts(&mid));
        assert!(!mid.abuts(&head));
        // A view of a view composes offsets.
        let sub = mid.view_rows(1..2).unwrap();
        assert_eq!(sub.data(), &[4.0, 5.0]);
        assert_eq!(sub.offset(), 3 * 2);
    }

    #[test]
    fn from_buf_wraps_shared_storage_without_copy() {
        let buf: TensorBuf = Arc::new(vec![1.0, 2.0, 3.0, 4.0]);
        let t = Tensor::from_buf(vec![1, 2], Arc::clone(&buf), 2).unwrap();
        assert_eq!(t.data(), &[3.0, 4.0]);
        assert!(Arc::ptr_eq(&buf, t.buf()));
        assert!(Tensor::from_buf(vec![1, 3], Arc::clone(&buf), 2).is_err());
    }

    #[test]
    fn clone_is_refcount_not_copy() {
        let t = Tensor::zeros(vec![2, 2]);
        let c = t.clone();
        assert!(Arc::ptr_eq(t.buf(), c.buf()));
        assert_eq!(t, c);
    }

    #[test]
    fn data_mut_is_copy_on_write() {
        // Sole owner: in-place, same buffer.
        let mut t = Tensor::zeros(vec![2, 2]);
        let before = Arc::as_ptr(t.buf());
        t.data_mut()[0] = 5.0;
        assert_eq!(Arc::as_ptr(t.buf()), before);
        // Shared: the mutating side re-buffers, the other view is
        // untouched (the aliasing guarantee).
        let view = t.view_rows(0..1).unwrap();
        t.data_mut()[0] = 9.0;
        assert_eq!(view.data()[0], 5.0);
        assert_eq!(t.data()[0], 9.0);
        assert!(!Arc::ptr_eq(t.buf(), view.buf()));
    }

    #[test]
    fn into_vec_zero_copy_when_exclusive() {
        let t = Tensor::new(vec![3], vec![7.0, 8.0, 9.0]).unwrap();
        assert_eq!(t.into_vec(), vec![7.0, 8.0, 9.0]);
        // Partial view copies just its window.
        let t = Tensor::new(vec![2, 2], vec![0.0, 1.0, 2.0, 3.0]).unwrap();
        let tail = t.view_rows(1..2).unwrap();
        assert_eq!(tail.into_vec(), vec![2.0, 3.0]);
    }

    // PJRT-backed tests live in rust/tests/ since they need artifacts.
}
