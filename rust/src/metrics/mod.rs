//! Metrics registry: the numbers behind every table in the paper's
//! evaluation — latency distributions, throughput, communication overhead,
//! scheduling overhead, bandwidth, stability.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Summary;

/// Data-plane copy accounting: process-global counters fed by the
/// activation path (`runtime::Tensor`, `pipeline::stack_batch`, the
/// engine feeder/collector). `copied_bytes` counts every activation
/// memcpy the data plane performs; `viewed_bytes` counts bytes handed
/// off as zero-copy views instead — the bytes the Arc-backed tensor
/// refactor stopped moving. Benches snapshot before/after a section to
/// report the copy tax of a workload (counters are global, so deltas
/// are only exact in single-threaded harnesses).
pub mod data_plane {
    use std::sync::atomic::{AtomicU64, Ordering};

    static COPIED_BYTES: AtomicU64 = AtomicU64::new(0);
    static COPIES: AtomicU64 = AtomicU64::new(0);
    static VIEWED_BYTES: AtomicU64 = AtomicU64::new(0);

    /// Point-in-time view of the process-global data-plane counters.
    #[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
    pub struct DataPlaneStats {
        /// Activation bytes physically copied since process start.
        pub copied_bytes: u64,
        /// Individual copy operations.
        pub copies: u64,
        /// Activation bytes shared as zero-copy views instead of copied.
        pub viewed_bytes: u64,
    }

    impl DataPlaneStats {
        /// Counter movement since an earlier snapshot.
        pub fn since(&self, earlier: &DataPlaneStats) -> DataPlaneStats {
            DataPlaneStats {
                copied_bytes: self.copied_bytes - earlier.copied_bytes,
                copies: self.copies - earlier.copies,
                viewed_bytes: self.viewed_bytes - earlier.viewed_bytes,
            }
        }
    }

    /// Record one activation memcpy of `bytes`.
    pub fn count_copy(bytes: u64) {
        COPIED_BYTES.fetch_add(bytes, Ordering::Relaxed);
        COPIES.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `bytes` handed off as a zero-copy view.
    pub fn count_view(bytes: u64) {
        VIEWED_BYTES.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn snapshot() -> DataPlaneStats {
        DataPlaneStats {
            copied_bytes: COPIED_BYTES.load(Ordering::Relaxed),
            copies: COPIES.load(Ordering::Relaxed),
            viewed_bytes: VIEWED_BYTES.load(Ordering::Relaxed),
        }
    }
}

/// Process-global wire-transport counters, mirroring [`data_plane`]:
/// every frame the transport codec writes or reads is counted here
/// (frames, bytes, and the nanoseconds spent encoding/decoding —
/// including the socket wait, so the numbers reflect what the wire
/// actually cost, not just the marshalling). Snapshot before/after a
/// run and diff with [`WireStats::since`].
pub mod wire {
    use std::sync::atomic::{AtomicU64, Ordering};

    static FRAMES_TX: AtomicU64 = AtomicU64::new(0);
    static BYTES_TX: AtomicU64 = AtomicU64::new(0);
    static ENCODE_NS: AtomicU64 = AtomicU64::new(0);
    static FRAMES_RX: AtomicU64 = AtomicU64::new(0);
    static BYTES_RX: AtomicU64 = AtomicU64::new(0);
    static DECODE_NS: AtomicU64 = AtomicU64::new(0);
    static HEDGES: AtomicU64 = AtomicU64::new(0);
    static HEDGE_WINS: AtomicU64 = AtomicU64::new(0);
    static HEDGE_WASTED: AtomicU64 = AtomicU64::new(0);

    /// Point-in-time view of the process-global wire counters.
    #[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
    pub struct WireStats {
        /// Frames written to a wire stream.
        pub frames_tx: u64,
        /// Bytes written (length prefix + CRC + kind + payload).
        pub bytes_tx: u64,
        /// Nanoseconds spent encoding + writing frames.
        pub encode_ns: u64,
        /// Frames read from a wire stream.
        pub frames_rx: u64,
        /// Bytes read.
        pub bytes_rx: u64,
        /// Nanoseconds spent reading + decoding frames.
        pub decode_ns: u64,
        /// Straggler hedges issued (a micro-batch re-sent to a second
        /// replica after blowing its EWMA-derived threshold).
        pub hedges: u64,
        /// Hedges whose re-issue finished first (the hedge paid off).
        pub hedge_wins: u64,
        /// Hedged executions whose result was discarded (the other
        /// copy won) — the redundancy cost of hedging.
        pub hedge_wasted: u64,
    }

    impl WireStats {
        /// Counter movement since an earlier snapshot.
        pub fn since(&self, earlier: &WireStats) -> WireStats {
            WireStats {
                frames_tx: self.frames_tx - earlier.frames_tx,
                bytes_tx: self.bytes_tx - earlier.bytes_tx,
                encode_ns: self.encode_ns - earlier.encode_ns,
                frames_rx: self.frames_rx - earlier.frames_rx,
                bytes_rx: self.bytes_rx - earlier.bytes_rx,
                decode_ns: self.decode_ns - earlier.decode_ns,
                hedges: self.hedges - earlier.hedges,
                hedge_wins: self.hedge_wins - earlier.hedge_wins,
                hedge_wasted: self.hedge_wasted - earlier.hedge_wasted,
            }
        }
    }

    /// Record one frame written: `bytes` on the wire, `ns` to encode.
    pub fn count_tx(bytes: u64, ns: u64) {
        FRAMES_TX.fetch_add(1, Ordering::Relaxed);
        BYTES_TX.fetch_add(bytes, Ordering::Relaxed);
        ENCODE_NS.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record one frame read: `bytes` off the wire, `ns` to decode.
    pub fn count_rx(bytes: u64, ns: u64) {
        FRAMES_RX.fetch_add(1, Ordering::Relaxed);
        BYTES_RX.fetch_add(bytes, Ordering::Relaxed);
        DECODE_NS.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record one straggler hedge being issued.
    pub fn count_hedge_issued() {
        HEDGES.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a hedge that finished first.
    pub fn count_hedge_win() {
        HEDGE_WINS.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a hedged execution whose result was discarded.
    pub fn count_hedge_wasted() {
        HEDGE_WASTED.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot() -> WireStats {
        WireStats {
            frames_tx: FRAMES_TX.load(Ordering::Relaxed),
            bytes_tx: BYTES_TX.load(Ordering::Relaxed),
            encode_ns: ENCODE_NS.load(Ordering::Relaxed),
            frames_rx: FRAMES_RX.load(Ordering::Relaxed),
            bytes_rx: BYTES_RX.load(Ordering::Relaxed),
            decode_ns: DECODE_NS.load(Ordering::Relaxed),
            hedges: HEDGES.load(Ordering::Relaxed),
            hedge_wins: HEDGE_WINS.load(Ordering::Relaxed),
            hedge_wasted: HEDGE_WASTED.load(Ordering::Relaxed),
        }
    }
}

/// Aggregated view over one serving run; feeds the Table I / II harnesses.
#[derive(Debug, Default, Clone)]
pub struct RunMetrics {
    /// End-to-end per-request latency, ms.
    pub latency: Vec<f64>,
    /// Per-request compute time summed over stages, ms.
    pub compute: Vec<f64>,
    /// Per-request communication (activation transfer) time, ms.
    pub comm: Vec<f64>,
    /// Per-request scheduling overhead (selection + queueing), ms.
    pub sched: Vec<f64>,
    /// Requests served from the result cache.
    pub cache_hits: u64,
    /// Total requests completed.
    pub completed: u64,
    /// Total requests failed.
    pub failed: u64,
    /// Wall-clock duration of the run, ms.
    pub wall_ms: f64,
    /// Weight-transfer bytes during deployment (Table I "network
    /// bandwidth").
    pub deploy_bytes: u64,
    /// Activation bytes moved between nodes.
    pub activation_bytes: u64,
    /// Per-priority-class breakdown (index = class; empty when the run
    /// never recorded class-tagged requests).
    pub classes: Vec<ClassMetrics>,
    /// Per-(tenant, class) breakdown, sorted by (tenant, class); empty
    /// when the run never recorded tenant-tagged requests. Single-tenant
    /// runs land everything under tenant 0.
    pub tenants: Vec<TenantClassMetrics>,
}

/// Per-priority-class serving metrics: latency distribution, shed
/// counts, and deadline hit rate for one class of the run's traffic.
#[derive(Debug, Default, Clone)]
pub struct ClassMetrics {
    pub class: usize,
    /// End-to-end per-request latency, ms.
    pub latency: Vec<f64>,
    pub completed: u64,
    pub failed: u64,
    pub cache_hits: u64,
    /// Requests shed because their deadline had already passed.
    pub shed_expired: u64,
    /// Requests shed because the service-time estimate said the
    /// deadline could not be met.
    pub shed_predicted: u64,
    /// Completed requests that carried a deadline.
    pub deadline_total: u64,
    /// Of those, how many finished within it.
    pub deadline_met: u64,
}

impl ClassMetrics {
    pub fn latency_summary(&self) -> Summary {
        let mut s = Summary::new();
        s.extend(&self.latency);
        s
    }

    /// All sheds (expired + predicted-miss).
    pub fn shed(&self) -> u64 {
        self.shed_expired + self.shed_predicted
    }
}

/// Per-(tenant, priority-class) serving metrics — the WFQ ingress's
/// isolation evidence: each tenant's latency distribution, completions,
/// and shed counts within each class of the run's traffic.
#[derive(Debug, Default, Clone)]
pub struct TenantClassMetrics {
    pub tenant: usize,
    pub class: usize,
    /// End-to-end per-request latency, ms.
    pub latency: Vec<f64>,
    pub completed: u64,
    pub failed: u64,
    pub cache_hits: u64,
    /// Requests shed because their deadline had already passed.
    pub shed_expired: u64,
    /// Requests shed because the service-time estimate said the
    /// deadline could not be met.
    pub shed_predicted: u64,
    /// Completed requests that carried a deadline.
    pub deadline_total: u64,
    /// Of those, how many finished within it.
    pub deadline_met: u64,
}

impl TenantClassMetrics {
    pub fn latency_summary(&self) -> Summary {
        let mut s = Summary::new();
        s.extend(&self.latency);
        s
    }

    /// All sheds (expired + predicted-miss).
    pub fn shed(&self) -> u64 {
        self.shed_expired + self.shed_predicted
    }
}

impl RunMetrics {
    pub fn latency_summary(&self) -> Summary {
        let mut s = Summary::new();
        s.extend(&self.latency);
        s
    }

    pub fn throughput_rps(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            0.0
        } else {
            self.completed as f64 / (self.wall_ms / 1e3)
        }
    }

    pub fn mean_latency_ms(&self) -> f64 {
        self.latency_summary().mean()
    }

    pub fn mean_comm_ms(&self) -> f64 {
        let mut s = Summary::new();
        s.extend(&self.comm);
        s.mean()
    }

    pub fn mean_sched_ms(&self) -> f64 {
        let mut s = Summary::new();
        s.extend(&self.sched);
        s.mean()
    }

    /// Metrics for one priority class, if any were recorded for it.
    pub fn class(&self, class: usize) -> Option<&ClassMetrics> {
        self.classes.get(class)
    }

    /// Total requests shed across all classes.
    pub fn total_shed(&self) -> u64 {
        self.classes.iter().map(ClassMetrics::shed).sum()
    }

    /// Metrics for one (tenant, class) pair, if any were recorded.
    pub fn tenant_class(
        &self,
        tenant: usize,
        class: usize,
    ) -> Option<&TenantClassMetrics> {
        self.tenants
            .iter()
            .find(|t| t.tenant == tenant && t.class == class)
    }

    /// One tenant's latency distribution merged across classes.
    pub fn tenant_latency_summary(&self, tenant: usize) -> Summary {
        let mut s = Summary::new();
        for t in self.tenants.iter().filter(|t| t.tenant == tenant) {
            s.extend(&t.latency);
        }
        s
    }

    /// One tenant's completions across classes.
    pub fn tenant_completed(&self, tenant: usize) -> u64 {
        self.tenants
            .iter()
            .filter(|t| t.tenant == tenant)
            .map(|t| t.completed)
            .sum()
    }

    /// One tenant's sheds (expired + predicted) across classes.
    pub fn tenant_shed(&self, tenant: usize) -> u64 {
        self.tenants
            .iter()
            .filter(|t| t.tenant == tenant)
            .map(TenantClassMetrics::shed)
            .sum()
    }

    /// Stability score: fraction of requests within 2x median latency,
    /// scaled by the success rate. A tight, jitter-free run scores 1.0.
    pub fn stability_score(&self) -> f64 {
        let total = self.completed + self.failed;
        if total == 0 {
            return 1.0;
        }
        let success = self.completed as f64 / total as f64;
        let s = self.latency_summary();
        if s.count() == 0 {
            return success;
        }
        let median = s.p50();
        let within = self
            .latency
            .iter()
            .filter(|&&l| l <= 2.0 * median)
            .count() as f64
            / s.count() as f64;
        success * within
    }
}

/// A live collector with thread-safe interior (shared by router workers).
#[derive(Default)]
pub struct MetricsCollector {
    inner: Mutex<RunMetrics>,
    started: Mutex<Option<Instant>>,
}

impl MetricsCollector {
    pub fn new() -> MetricsCollector {
        MetricsCollector::default()
    }

    pub fn start_run(&self) {
        *self.started.lock().unwrap() = Some(Instant::now());
    }

    pub fn record_request(
        &self,
        latency_ms: f64,
        compute_ms: f64,
        comm_ms: f64,
        sched_ms: f64,
        cache_hit: bool,
    ) {
        let mut m = self.inner.lock().unwrap();
        m.latency.push(latency_ms);
        m.compute.push(compute_ms);
        m.comm.push(comm_ms);
        m.sched.push(sched_ms);
        m.completed += 1;
        if cache_hit {
            m.cache_hits += 1;
        }
    }

    /// [`MetricsCollector::record_request`] plus the per-class
    /// breakdown, under one lock acquisition (this is the serving
    /// ingress's per-request hot path). `deadline_met` is `None` for
    /// deadline-free requests.
    #[allow(clippy::too_many_arguments)]
    pub fn record_request_class(
        &self,
        class: usize,
        latency_ms: f64,
        compute_ms: f64,
        comm_ms: f64,
        sched_ms: f64,
        cache_hit: bool,
        deadline_met: Option<bool>,
    ) {
        self.record_request_tenant(
            crate::tenancy::DEFAULT_TENANT,
            class,
            latency_ms,
            compute_ms,
            comm_ms,
            sched_ms,
            cache_hit,
            deadline_met,
        );
    }

    /// [`MetricsCollector::record_request_class`] plus the per-tenant
    /// breakdown, still one lock acquisition. Single-tenant callers use
    /// the class-only name, which lands under tenant 0.
    #[allow(clippy::too_many_arguments)]
    pub fn record_request_tenant(
        &self,
        tenant: usize,
        class: usize,
        latency_ms: f64,
        compute_ms: f64,
        comm_ms: f64,
        sched_ms: f64,
        cache_hit: bool,
        deadline_met: Option<bool>,
    ) {
        let mut m = self.inner.lock().unwrap();
        m.latency.push(latency_ms);
        m.compute.push(compute_ms);
        m.comm.push(comm_ms);
        m.sched.push(sched_ms);
        m.completed += 1;
        if cache_hit {
            m.cache_hits += 1;
        }
        let c = class_slot(&mut m.classes, class);
        c.latency.push(latency_ms);
        c.completed += 1;
        if cache_hit {
            c.cache_hits += 1;
        }
        if let Some(met) = deadline_met {
            c.deadline_total += 1;
            if met {
                c.deadline_met += 1;
            }
        }
        let t = tenant_slot(&mut m.tenants, tenant, class);
        t.latency.push(latency_ms);
        t.completed += 1;
        if cache_hit {
            t.cache_hits += 1;
        }
        if let Some(met) = deadline_met {
            t.deadline_total += 1;
            if met {
                t.deadline_met += 1;
            }
        }
    }

    pub fn record_failure(&self) {
        self.inner.lock().unwrap().failed += 1;
    }

    pub fn record_failure_class(&self, class: usize) {
        self.record_failure_tenant(crate::tenancy::DEFAULT_TENANT, class);
    }

    pub fn record_failure_tenant(&self, tenant: usize, class: usize) {
        let mut m = self.inner.lock().unwrap();
        m.failed += 1;
        class_slot(&mut m.classes, class).failed += 1;
        tenant_slot(&mut m.tenants, tenant, class).failed += 1;
    }

    /// A request shed by the ingress (deadline expired or predicted to
    /// miss). Sheds are neither completions nor failures.
    pub fn record_shed(&self, class: usize, expired: bool) {
        self.record_shed_tenant(crate::tenancy::DEFAULT_TENANT, class, expired);
    }

    pub fn record_shed_tenant(&self, tenant: usize, class: usize, expired: bool) {
        let mut m = self.inner.lock().unwrap();
        {
            let c = class_slot(&mut m.classes, class);
            if expired {
                c.shed_expired += 1;
            } else {
                c.shed_predicted += 1;
            }
        }
        let t = tenant_slot(&mut m.tenants, tenant, class);
        if expired {
            t.shed_expired += 1;
        } else {
            t.shed_predicted += 1;
        }
    }

    pub fn add_deploy_bytes(&self, bytes: u64) {
        self.inner.lock().unwrap().deploy_bytes += bytes;
    }

    pub fn add_activation_bytes(&self, bytes: u64) {
        self.inner.lock().unwrap().activation_bytes += bytes;
    }

    /// Finish the run and return the aggregate.
    pub fn finish(&self) -> RunMetrics {
        let mut m = self.inner.lock().unwrap().clone();
        if let Some(t) = *self.started.lock().unwrap() {
            m.wall_ms = t.elapsed().as_secs_f64() * 1e3;
        }
        m
    }
}

/// Grow-and-index into the per-class vector (classes are small dense
/// indices assigned by the serving ingress).
fn class_slot(classes: &mut Vec<ClassMetrics>, class: usize) -> &mut ClassMetrics {
    while classes.len() <= class {
        let c = classes.len();
        classes.push(ClassMetrics { class: c, ..ClassMetrics::default() });
    }
    &mut classes[class]
}

/// Find-or-insert into the (tenant, class)-sorted tenant breakdown.
/// Unlike classes, tenant pairs are sparse — only observed combinations
/// get a slot.
fn tenant_slot(
    tenants: &mut Vec<TenantClassMetrics>,
    tenant: usize,
    class: usize,
) -> &mut TenantClassMetrics {
    let pos = tenants
        .binary_search_by_key(&(tenant, class), |t| (t.tenant, t.class))
        .unwrap_or_else(|insert_at| {
            tenants.insert(
                insert_at,
                TenantClassMetrics {
                    tenant,
                    class,
                    ..TenantClassMetrics::default()
                },
            );
            insert_at
        });
    &mut tenants[pos]
}

/// Per-pipeline-stage occupancy counters produced by the streaming
/// engine's critical-path accounting (`pipeline::timing`). All times are
/// simulated milliseconds.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageCounter {
    pub stage: usize,
    /// Node hosting the stage.
    pub node: usize,
    /// Simulated compute time the stage spent busy.
    pub busy_ms: f64,
    /// Idle gaps between consecutive micro-batches while the pipeline
    /// was active (excludes initial pipeline fill).
    pub bubble_ms: f64,
    /// Simulated ingress communication time.
    pub comm_ms: f64,
    /// Micro-batches this stage processed.
    pub micro_batches: u64,
}

impl StageCounter {
    /// Fraction of the traversal the stage spent computing.
    pub fn occupancy(&self, makespan_ms: f64) -> f64 {
        if makespan_ms <= 0.0 {
            0.0
        } else {
            (self.busy_ms / makespan_ms).min(1.0)
        }
    }

    /// Fraction of the stage's active span spent idle between
    /// micro-batches (`bubble / (busy + bubble)`). This is the signal
    /// the adaptive depth controller watches: a saturated bottleneck
    /// stage reads ~0, a credit-starved one reads high.
    pub fn bubble_fraction(&self) -> f64 {
        let span = self.busy_ms + self.bubble_ms;
        if span <= 0.0 {
            0.0
        } else {
            self.bubble_ms / span
        }
    }
}

/// Per-replica occupancy counters for a replicated stage — the
/// scale-out companion to [`StageCounter`]. A stage's aggregated
/// counter sums its replicas, which hides per-replica skew (one starved
/// replica behind a hot one); this type keeps each replica lane
/// visible. All times are simulated milliseconds.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ReplicaCounter {
    pub stage: usize,
    /// Replica index within the stage (0 = primary).
    pub replica: usize,
    /// Node hosting this replica.
    pub node: usize,
    /// Simulated compute time this replica spent busy.
    pub busy_ms: f64,
    /// Idle gaps between consecutive micro-batches (excludes fill).
    pub bubble_ms: f64,
    /// Simulated ingress communication time.
    pub comm_ms: f64,
    /// Micro-batches this replica processed.
    pub micro_batches: u64,
}

impl ReplicaCounter {
    /// Fraction of the traversal this replica spent computing.
    pub fn occupancy(&self, makespan_ms: f64) -> f64 {
        if makespan_ms <= 0.0 {
            0.0
        } else {
            (self.busy_ms / makespan_ms).min(1.0)
        }
    }

    /// Fraction of the replica's active span spent idle between
    /// micro-batches (`bubble / (busy + bubble)`).
    pub fn bubble_fraction(&self) -> f64 {
        let span = self.busy_ms + self.bubble_ms;
        if span <= 0.0 {
            0.0
        } else {
            self.bubble_ms / span
        }
    }
}

/// Feeder-side batch-coalescing counters from the persistent pipeline
/// engine: how many transports were formed, how many of them merged
/// multiple member batches, and how many padded micro-batches the
/// merging saved (the DEFER-style "merge small transfers" win).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CoalesceStats {
    /// Transports formed by the feeder (coalesced or not).
    pub transports: u64,
    /// Transports that merged more than one member batch.
    pub coalesced_transports: u64,
    /// Member batches carried across all transports.
    pub member_batches: u64,
    /// Micro-batches avoided by merging short tails, vs feeding every
    /// member separately.
    pub saved_micro_batches: u64,
}

/// Self-healing counters for a serving run under node churn (ISSUE 8):
/// what the liveness feed observed and how the heal ladder responded.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ChurnStats {
    /// Nodes the monitor declared dead (>= miss_threshold consecutive
    /// missed samples).
    pub nodes_died: u64,
    /// Dead nodes later observed back online (warm re-admission).
    pub nodes_returned: u64,
    /// Heals resolved by re-placing only the dead replicas' stages
    /// (every affected stage kept a surviving replica).
    pub heals_replaced: u64,
    /// Heals that fell back to a full re-partition (some stage lost its
    /// only copy).
    pub heals_repartitioned: u64,
    /// In-flight micro-batches the engine re-ran on a surviving replica
    /// after a stage execution failed.
    pub replays_attempted: u64,
    /// Replays that produced the micro-batch's output (the batch kept
    /// streaming instead of failing).
    pub replays_succeeded: u64,
}

impl ChurnStats {
    /// True when any churn or heal activity was recorded.
    pub fn any(&self) -> bool {
        *self != ChurnStats::default()
    }
}

/// Thread-safe accumulator merging [`StageCounter`]s across traversals
/// (the per-deployment view a serving run reports).
#[derive(Default)]
pub struct StageCounterSet {
    inner: Mutex<Vec<StageCounter>>,
}

impl StageCounterSet {
    pub fn new() -> StageCounterSet {
        StageCounterSet::default()
    }

    /// Fold one traversal's counters in, summing by stage index.
    pub fn merge(&self, counters: &[StageCounter]) {
        let mut inner = self.inner.lock().unwrap();
        for c in counters {
            if let Some(existing) =
                inner.iter_mut().find(|e| e.stage == c.stage)
            {
                existing.node = c.node; // latest deployment wins
                existing.busy_ms += c.busy_ms;
                existing.bubble_ms += c.bubble_ms;
                existing.comm_ms += c.comm_ms;
                existing.micro_batches += c.micro_batches;
            } else {
                inner.push(c.clone());
            }
        }
        inner.sort_by_key(|c| c.stage);
    }

    pub fn snapshot(&self) -> Vec<StageCounter> {
        self.inner.lock().unwrap().clone()
    }

    pub fn reset(&self) {
        self.inner.lock().unwrap().clear();
    }
}

/// Render a markdown table from (metric, value) rows — used by the bench
/// harness binaries to print paper-style tables.
pub fn markdown_table(title: &str, headers: &[&str],
                      rows: &[Vec<String>]) -> String {
    let mut out = format!("\n### {title}\n\n");
    out.push_str(&format!("| {} |\n", headers.join(" | ")));
    out.push_str(&format!(
        "|{}\n",
        headers.iter().map(|_| "---|").collect::<String>()
    ));
    for row in rows {
        out.push_str(&format!("| {} |\n", row.join(" | ")));
    }
    out
}

/// Simple key->f64 gauge set exported as JSON for tooling.
#[derive(Default)]
pub struct GaugeSet {
    gauges: Mutex<BTreeMap<String, f64>>,
}

impl GaugeSet {
    pub fn set(&self, key: &str, value: f64) {
        self.gauges.lock().unwrap().insert(key.to_string(), value);
    }

    pub fn get(&self, key: &str) -> Option<f64> {
        self.gauges.lock().unwrap().get(key).copied()
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        let map = self
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), crate::util::json::Json::Num(*v)))
            .collect();
        crate::util::json::Json::Obj(map)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_computation() {
        let mut m = RunMetrics::default();
        m.completed = 50;
        m.wall_ms = 10_000.0;
        assert!((m.throughput_rps() - 5.0).abs() < 1e-9);
        m.wall_ms = 0.0;
        assert_eq!(m.throughput_rps(), 0.0);
    }

    #[test]
    fn stability_perfect_run() {
        let mut m = RunMetrics::default();
        m.completed = 4;
        m.latency = vec![10.0, 10.0, 10.0, 10.0];
        assert_eq!(m.stability_score(), 1.0);
    }

    #[test]
    fn stability_penalizes_outliers_and_failures() {
        let mut m = RunMetrics::default();
        m.completed = 4;
        m.latency = vec![10.0, 10.0, 10.0, 100.0];
        let jittery = m.stability_score();
        assert!(jittery < 1.0);
        m.failed = 4;
        assert!(m.stability_score() < jittery);
    }

    #[test]
    fn stability_empty_run_is_one() {
        assert_eq!(RunMetrics::default().stability_score(), 1.0);
    }

    #[test]
    fn collector_aggregates() {
        let c = MetricsCollector::new();
        c.start_run();
        c.record_request(12.0, 10.0, 1.0, 0.5, false);
        c.record_request(14.0, 11.0, 2.0, 0.5, true);
        c.record_failure();
        c.add_deploy_bytes(100);
        c.add_activation_bytes(50);
        std::thread::sleep(std::time::Duration::from_millis(5));
        let m = c.finish();
        assert_eq!(m.completed, 2);
        assert_eq!(m.failed, 1);
        assert_eq!(m.cache_hits, 1);
        assert_eq!(m.deploy_bytes, 100);
        assert_eq!(m.activation_bytes, 50);
        assert!(m.wall_ms >= 5.0);
        assert!((m.mean_latency_ms() - 13.0).abs() < 1e-9);
        assert!((m.mean_comm_ms() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn per_class_accounting() {
        let c = MetricsCollector::new();
        c.start_run();
        c.record_request_class(0, 5.0, 4.0, 0.5, 0.1, false, Some(true));
        c.record_request_class(0, 6.0, 4.0, 0.5, 0.1, false, Some(false));
        c.record_request_class(2, 50.0, 4.0, 0.5, 0.1, true, None);
        c.record_failure_class(2);
        c.record_shed(2, true);
        c.record_shed(2, false);
        let m = c.finish();
        // Aggregate view still counts everything.
        assert_eq!(m.completed, 3);
        assert_eq!(m.failed, 1);
        assert_eq!(m.cache_hits, 1);
        assert_eq!(m.total_shed(), 2);
        let hi = m.class(0).unwrap();
        assert_eq!(hi.completed, 2);
        assert_eq!(hi.deadline_total, 2);
        assert_eq!(hi.deadline_met, 1);
        assert_eq!(hi.shed(), 0);
        assert!((hi.latency_summary().mean() - 5.5).abs() < 1e-9);
        // Class 1 exists as a zeroed slot (dense indexing), class 2 has
        // the best-effort traffic.
        assert_eq!(m.class(1).unwrap().completed, 0);
        let be = m.class(2).unwrap();
        assert_eq!(be.completed, 1);
        assert_eq!(be.failed, 1);
        assert_eq!(be.cache_hits, 1);
        assert_eq!(be.shed_expired, 1);
        assert_eq!(be.shed_predicted, 1);
        assert_eq!(be.shed(), 2);
        assert!(m.class(3).is_none());
    }

    #[test]
    fn per_tenant_accounting() {
        let c = MetricsCollector::new();
        c.start_run();
        // Tenant 1 traffic in two classes; tenant 0 in one.
        c.record_request_tenant(1, 0, 5.0, 4.0, 0.5, 0.1, false, Some(true));
        c.record_request_tenant(1, 2, 9.0, 4.0, 0.5, 0.1, true, None);
        c.record_request_tenant(0, 0, 7.0, 4.0, 0.5, 0.1, false, None);
        c.record_failure_tenant(1, 2);
        c.record_shed_tenant(1, 2, true);
        c.record_shed_tenant(0, 0, false);
        let m = c.finish();
        // Aggregate and per-class views still count everything.
        assert_eq!(m.completed, 3);
        assert_eq!(m.class(0).unwrap().completed, 2);
        assert_eq!(m.total_shed(), 2);
        // Tenant slots are sparse and (tenant, class)-sorted.
        let pairs: Vec<(usize, usize)> =
            m.tenants.iter().map(|t| (t.tenant, t.class)).collect();
        assert_eq!(pairs, vec![(0, 0), (1, 0), (1, 2)]);
        let t10 = m.tenant_class(1, 0).unwrap();
        assert_eq!(t10.completed, 1);
        assert_eq!(t10.deadline_met, 1);
        let t12 = m.tenant_class(1, 2).unwrap();
        assert_eq!(t12.failed, 1);
        assert_eq!(t12.cache_hits, 1);
        assert_eq!(t12.shed_expired, 1);
        assert_eq!(m.tenant_completed(1), 2);
        assert_eq!(m.tenant_shed(1), 1);
        assert_eq!(m.tenant_shed(0), 1);
        assert!((m.tenant_latency_summary(1).mean() - 7.0).abs() < 1e-9);
        // The class-only names land under tenant 0.
        let c2 = MetricsCollector::new();
        c2.record_request_class(0, 5.0, 4.0, 0.5, 0.1, false, None);
        c2.record_shed(1, false);
        let m2 = c2.finish();
        assert_eq!(m2.tenant_class(0, 0).unwrap().completed, 1);
        assert_eq!(m2.tenant_class(0, 1).unwrap().shed_predicted, 1);
    }

    #[test]
    fn markdown_rendering() {
        let t = markdown_table(
            "Table I",
            &["Metric", "Value"],
            &[vec!["Latency".into(), "1.0".into()]],
        );
        assert!(t.contains("### Table I"));
        assert!(t.contains("| Latency | 1.0 |"));
    }

    #[test]
    fn stage_counters_merge_and_occupancy() {
        let set = StageCounterSet::new();
        let a = StageCounter {
            stage: 0, node: 3, busy_ms: 10.0, bubble_ms: 1.0,
            comm_ms: 2.0, micro_batches: 4,
        };
        let b = StageCounter {
            stage: 0, node: 3, busy_ms: 5.0, bubble_ms: 0.5,
            comm_ms: 1.0, micro_batches: 2,
        };
        let c = StageCounter {
            stage: 1, node: 5, busy_ms: 20.0, bubble_ms: 0.0,
            comm_ms: 4.0, micro_batches: 6,
        };
        set.merge(&[a, c.clone()]);
        set.merge(&[b]);
        let snap = set.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].stage, 0);
        assert!((snap[0].busy_ms - 15.0).abs() < 1e-9);
        assert_eq!(snap[0].micro_batches, 6);
        assert!((snap[0].bubble_ms - 1.5).abs() < 1e-9);
        assert_eq!(snap[1], c);
        assert!((snap[1].occupancy(40.0) - 0.5).abs() < 1e-9);
        assert_eq!(snap[1].occupancy(0.0), 0.0);
        // 15 busy + 1.5 bubble across the merged stage-0 counters.
        assert!((snap[0].bubble_fraction() - 1.5 / 16.5).abs() < 1e-9);
        assert_eq!(StageCounter::default().bubble_fraction(), 0.0);
        set.reset();
        assert!(set.snapshot().is_empty());
    }

    #[test]
    fn data_plane_counters_accumulate() {
        // Counters are process-global and shared across parallel tests,
        // so assert monotonic movement, not absolute values.
        let before = data_plane::snapshot();
        data_plane::count_copy(128);
        data_plane::count_view(256);
        let after = data_plane::snapshot();
        let d = after.since(&before);
        assert!(d.copied_bytes >= 128);
        assert!(d.copies >= 1);
        assert!(d.viewed_bytes >= 256);
    }

    #[test]
    fn gauges() {
        let g = GaugeSet::default();
        g.set("cpu", 0.5);
        assert_eq!(g.get("cpu"), Some(0.5));
        assert!(g.to_json().to_string().contains("cpu"));
    }
}
