//! Model Deployer — paper §III-D.
//!
//! Takes a partition [`Plan`], picks a node per partition via the Task
//! Scheduler (Algorithm 1), "transfers" each partition's weight payload
//! over the node's link (the Table I *network bandwidth* metric), loads
//! the partition's block artifacts into the node's executor thread (each
//! node owns its own PJRT client — see `runtime::executor`), and reserves
//! node memory for the partition working set.
//!
//! Nodes keep a **model cache** of weight payloads they have already
//! received: redeploying a cached partition moves zero bytes — this is the
//! deployment half of AMP4EC+Cache (the paper's bandwidth column dropping
//! from 100 MB to 0). `undeploy` releases memory; `redeploy_on_change`
//! re-plans after a node joins or leaves (§I's two motivating scenarios).
//!
//! Deployment is split into two halves (ISSUE 9): [`ModelDeployer::place`]
//! does node selection plus memory reservation alone — the artifact-free
//! step multi-model co-deployment packing plans and validates against a
//! shared cluster — and the ship half moves weights onto the chosen
//! nodes. `deploy_replicated` composes both and rolls the placement back
//! if shipping fails.

use std::collections::{HashMap, HashSet};
use std::ops::Range;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::cluster::{Cluster, VirtualNode};
use crate::manifest::Manifest;
use crate::partitioner::{self, Plan};
use crate::runtime::{BlockHandle, Executor};
use crate::scheduler::{Scheduler, TaskRequirements};

/// One partition placed on one node, ready to execute.
pub struct Stage {
    pub partition_idx: usize,
    pub node: Arc<VirtualNode>,
    pub executor: Arc<Executor>,
    pub block_range: Range<usize>,
    /// Executor-side handles, one per block in the range.
    pub blocks: Vec<BlockHandle>,
    /// Weight payload represented by this stage.
    pub weights_bytes: u64,
    /// Memory reserved on the node for this stage (bytes).
    pub mem_reserved: u64,
    /// Extra data-parallel replicas of this stage (scale-out): replica
    /// `r + 1` lives in `replicas[r]`; the fields above are replica 0.
    /// Empty for every unreplicated deployment, so the whole pre-replica
    /// API surface is the k=1 case.
    pub replicas: Vec<StageReplica>,
}

/// One extra replica of a stage, fully provisioned on its own node
/// (weights shipped, blocks loaded, working set reserved).
pub struct StageReplica {
    pub node: Arc<VirtualNode>,
    pub executor: Arc<Executor>,
    pub blocks: Vec<BlockHandle>,
    pub mem_reserved: u64,
}

impl Stage {
    /// Total replica count including the primary (>= 1).
    pub fn replica_count(&self) -> usize {
        1 + self.replicas.len()
    }

    /// Node hosting replica `r` (0 = primary).
    pub fn replica_node(&self, r: usize) -> &Arc<VirtualNode> {
        if r == 0 {
            &self.node
        } else {
            &self.replicas[r - 1].node
        }
    }
}

/// A stage's chosen placement before any bytes move: the nodes that
/// will host each replica (`nodes[0]` is the primary), with their
/// working-set memory already reserved. Produced by
/// [`ModelDeployer::place`]; consumed by the ship half of
/// [`ModelDeployer::deploy_replicated`] or released unused via
/// [`ModelDeployer::release_placement`].
pub struct StagePlacement {
    pub partition_idx: usize,
    pub block_range: Range<usize>,
    /// Working-set bytes reserved on every node in `nodes`.
    pub mem_bytes: u64,
    /// Chosen replica hosts; index 0 is the primary.
    pub nodes: Vec<Arc<VirtualNode>>,
    /// True when the primary landed via the last-resort overcommit
    /// fallback (its node's working set now exceeds its limit).
    pub overcommitted: bool,
}

/// A live deployment of a partition plan.
pub struct Deployment {
    pub batch: usize,
    pub stages: Vec<Stage>,
    /// Bytes actually moved over links during deployment.
    pub transfer_bytes: u64,
    pub deploy_ms: f64,
    /// Final output shape, e.g. [batch, 1000].
    pub out_shape: Vec<usize>,
}

impl Deployment {
    pub fn node_ids(&self) -> Vec<usize> {
        self.stages.iter().map(|s| s.node.id()).collect()
    }

    /// Replica map: `replica_node_ids()[k][r]` hosts replica `r` of stage
    /// `k` (`[k][0]` is the primary). All-singleton for k=1 deployments.
    pub fn replica_node_ids(&self) -> Vec<Vec<usize>> {
        self.stages
            .iter()
            .map(|s| (0..s.replica_count()).map(|r| s.replica_node(r).id()).collect())
            .collect()
    }
}

/// Deploys/undeploys partition plans onto the virtual cluster.
pub struct ModelDeployer {
    manifest: Arc<Manifest>,
    /// One executor (PJRT client thread) per node, created lazily.
    executors: Mutex<HashMap<usize, Arc<Executor>>>,
    /// (node, block) pairs whose weights the node already holds.
    model_cache: Mutex<HashSet<(usize, usize)>>,
    /// When true, cached (node, block) weight payloads skip the link
    /// transfer — the +Cache configuration.
    pub use_model_cache: bool,
}

impl ModelDeployer {
    pub fn new(manifest: Arc<Manifest>) -> ModelDeployer {
        ModelDeployer {
            manifest,
            executors: Mutex::new(HashMap::new()),
            model_cache: Mutex::new(HashSet::new()),
            use_model_cache: true,
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Get or spawn the executor for a node.
    pub fn executor_for(&self, node: &VirtualNode) -> Result<Arc<Executor>> {
        let mut map = self.executors.lock().unwrap();
        if let Some(e) = map.get(&node.id()) {
            return Ok(Arc::clone(e));
        }
        let exec = Arc::new(Executor::spawn(node.name())?);
        map.insert(node.id(), Arc::clone(&exec));
        Ok(exec)
    }

    /// Estimate the working-set bytes a partition needs on its node:
    /// weights + double-buffered largest activation at `batch`.
    fn stage_mem_bytes(&self, range: &Range<usize>, batch: usize) -> u64 {
        let weights: u64 = self.manifest.weights_bytes_for(range.clone());
        let act = self.manifest.blocks[range.clone()]
            .iter()
            .map(|b| b.input_bytes(batch).max(b.output_bytes(batch)))
            .max()
            .unwrap_or(0);
        weights + 2 * act
    }

    /// Ship one partition's blocks to `node`: move uncached weight
    /// payloads over the node's link and load every block into the
    /// node's executor. Returns the handles, the stage's total weight
    /// bytes, and the bytes actually moved (cache hits move nothing).
    fn ship_blocks(
        &self,
        node: &VirtualNode,
        executor: &Executor,
        range: &Range<usize>,
        batch: usize,
    ) -> Result<(Vec<BlockHandle>, u64, u64)> {
        let mut handles = Vec::new();
        let mut stage_bytes = 0u64;
        let mut transferred = 0u64;
        for bi in range.clone() {
            let block = &self.manifest.blocks[bi];
            let cached = self
                .model_cache
                .lock()
                .unwrap()
                .contains(&(node.id(), bi));
            if !(self.use_model_cache && cached) {
                node.link().receive(block.weights_bytes);
                transferred += block.weights_bytes;
            }
            self.model_cache.lock().unwrap().insert((node.id(), bi));
            stage_bytes += block.weights_bytes;

            let hlo = self.manifest.artifact_path(block, batch)?;
            let handle = executor
                .load_block(
                    hlo,
                    self.manifest.weights_path(block),
                    block.param_count as usize,
                    vec![
                        batch,
                        block.out_shape[0],
                        block.out_shape[1],
                        block.out_shape[2],
                    ],
                )
                .with_context(|| format!("loading block {}", block.name))?;
            handles.push(handle);
        }
        Ok((handles, stage_bytes, transferred))
    }

    /// Deploy `plan` at `batch`, choosing a node per partition with the
    /// scheduler. Prefers distinct nodes per partition (pipelining);
    /// falls back to reuse when partitions outnumber nodes.
    pub fn deploy(
        &self,
        plan: &Plan,
        cluster: &Cluster,
        scheduler: &Scheduler,
        batch: usize,
    ) -> Result<Deployment> {
        self.deploy_replicated(
            plan,
            cluster,
            scheduler,
            batch,
            &vec![1; plan.partitions.len()],
        )
    }

    /// Scale-out deployment: like [`ModelDeployer::deploy`] but places
    /// `replica_counts[i]` data-parallel copies of partition `i`
    /// (`partitioner::replica_counts` picks the counts bottleneck-first).
    /// Extras go on *fresh* nodes chosen by the scheduler's replica-set
    /// extension under its per-node memory guard; when fewer nodes can
    /// afford a replica than requested, the stage runs with what was
    /// placeable (never overcommitted — a paged-out replica would slow
    /// the stage it exists to speed up). All-ones `replica_counts`
    /// reproduces `deploy` exactly.
    pub fn deploy_replicated(
        &self,
        plan: &Plan,
        cluster: &Cluster,
        scheduler: &Scheduler,
        batch: usize,
        replica_counts: &[usize],
    ) -> Result<Deployment> {
        let t0 = Instant::now();
        let placements =
            self.place(plan, cluster, scheduler, batch, replica_counts)?;
        let mut stages = Vec::with_capacity(placements.len());
        match self.ship_placements(&placements, batch, &mut stages) {
            Ok(transfer_bytes) => Ok(Deployment {
                batch,
                stages,
                transfer_bytes,
                deploy_ms: t0.elapsed().as_secs_f64() * 1e3,
                out_shape: vec![batch, self.manifest.num_classes],
            }),
            Err(e) => {
                // Roll back so a failed deploy holds nothing: unload
                // the stages that did ship, then release every memory
                // reservation the placement made.
                for s in &stages {
                    for b in &s.blocks {
                        s.executor.unload_block(*b);
                    }
                    for r in &s.replicas {
                        for b in &r.blocks {
                            r.executor.unload_block(*b);
                        }
                    }
                }
                self.release_placement(&placements);
                Err(e)
            }
        }
    }

    /// The selection half of a deployment: choose the hosting nodes for
    /// every partition (and its extra replicas) and reserve their
    /// working-set memory, moving **zero bytes** and touching no
    /// executor. The scheduler's scoring reads live node state — load,
    /// *remaining* memory, stability — so placing a second model on a
    /// cluster automatically packs around whatever earlier deployments
    /// already reserved. Release an unused placement with
    /// [`ModelDeployer::release_placement`].
    pub fn place(
        &self,
        plan: &Plan,
        cluster: &Cluster,
        scheduler: &Scheduler,
        batch: usize,
        replica_counts: &[usize],
    ) -> Result<Vec<StagePlacement>> {
        anyhow::ensure!(
            replica_counts.len() == plan.partitions.len(),
            "need one replica count per partition ({} != {})",
            replica_counts.len(),
            plan.partitions.len()
        );
        anyhow::ensure!(
            replica_counts.iter().all(|&r| r >= 1),
            "every partition needs >= 1 replica"
        );
        let nodes = cluster.online_nodes();
        anyhow::ensure!(!nodes.is_empty(), "no online nodes to deploy to");

        let mut placements = Vec::with_capacity(plan.partitions.len());
        let mut used: HashSet<usize> = HashSet::new();

        for (i, part) in plan.partitions.iter().enumerate() {
            let mem_bytes = self.stage_mem_bytes(&part.block_range, batch);
            let req = TaskRequirements {
                cpu: 0.1,
                mem_mb: mem_bytes as f64 / (1024.0 * 1024.0),
                priority: 0,
            };
            // Prefer nodes not already hosting a partition.
            let fresh: Vec<_> = nodes
                .iter()
                .filter(|n| !used.contains(&n.id()))
                .cloned()
                .collect();
            let candidates = if fresh.is_empty() { nodes.clone() } else { fresh };
            let picked = scheduler
                .select_node(&candidates, &req)
                .or_else(|| scheduler.select_node(&nodes, &req));
            // Last resort: overcommit the least-loaded online node. A
            // cgroup doesn't refuse an oversized working set — it pages;
            // our memory model charges the same penalty (DESIGN.md).
            let (node, overcommitted) = match picked {
                Some((node, _score)) => (node, false),
                None => {
                    let node = nodes
                        .iter()
                        .filter(|n| n.is_online())
                        .min_by(|a, b| {
                            a.current_load()
                                .partial_cmp(&b.current_load())
                                .unwrap()
                        })
                        .cloned()
                        .ok_or_else(|| {
                            anyhow::anyhow!(
                                "no online node for partition {i} \
                                 (need {:.1} MB)",
                                req.mem_mb
                            )
                        })?;
                    crate::log_warn!(
                        "deployer",
                        "overcommitting partition {i} ({:.1} MB) onto {}",
                        req.mem_mb,
                        node.name()
                    );
                    (node, true)
                }
            };
            used.insert(node.id());
            node.mem_reserve(mem_bytes);
            let mut chosen = vec![node];

            // Extra replicas go on fresh nodes only, under the
            // scheduler's memory guard — no overcommit fallback.
            let want_extra = replica_counts[i] - 1;
            if want_extra > 0 {
                let fresh: Vec<_> = nodes
                    .iter()
                    .filter(|n| !used.contains(&n.id()))
                    .cloned()
                    .collect();
                let set = scheduler.select_replica_set(&fresh, &req, want_extra);
                if set.len() < want_extra {
                    crate::log_warn!(
                        "deployer",
                        "partition {i}: placed {} of {} extra replicas \
                         ({} fresh nodes can afford {:.1} MB)",
                        set.len(),
                        want_extra,
                        set.len(),
                        req.mem_mb
                    );
                }
                for (rnode, _score) in set {
                    used.insert(rnode.id());
                    rnode.mem_reserve(mem_bytes);
                    chosen.push(rnode);
                }
            }

            placements.push(StagePlacement {
                partition_idx: i,
                block_range: part.block_range.clone(),
                mem_bytes,
                nodes: chosen,
                overcommitted,
            });
        }
        Ok(placements)
    }

    /// Release the node memory a [`ModelDeployer::place`] call reserved
    /// without shipping anything — the undo for a placement that was
    /// probed (packing feasibility) or abandoned (ship failure).
    pub fn release_placement(&self, placements: &[StagePlacement]) {
        for p in placements {
            for node in &p.nodes {
                node.mem_release(p.mem_bytes);
            }
        }
    }

    /// The ship half of a deployment: move weights to every placed node
    /// and load blocks into its executor, appending fully provisioned
    /// stages to `stages` as they complete. On error the partially
    /// shipped placement's blocks are unloaded before returning; the
    /// caller rolls back `stages` and the memory reservations.
    fn ship_placements(
        &self,
        placements: &[StagePlacement],
        batch: usize,
        stages: &mut Vec<Stage>,
    ) -> Result<u64> {
        let mut transfer_bytes = 0u64;
        for p in placements {
            let mut shipped = Vec::with_capacity(p.nodes.len());
            let mut err = None;
            for node in &p.nodes {
                let r = self.executor_for(node).and_then(|executor| {
                    self.ship_blocks(node, &executor, &p.block_range, batch)
                        .map(|(blocks, bytes, moved)| {
                            (executor, blocks, bytes, moved)
                        })
                });
                match r {
                    Ok((executor, blocks, stage_bytes, moved)) => {
                        transfer_bytes += moved;
                        shipped.push((
                            Arc::clone(node),
                            executor,
                            blocks,
                            stage_bytes,
                        ));
                    }
                    Err(e) => {
                        err = Some(e);
                        break;
                    }
                }
            }
            if let Some(e) = err {
                for (_node, executor, blocks, _bytes) in &shipped {
                    for b in blocks {
                        executor.unload_block(*b);
                    }
                }
                return Err(e);
            }
            let (node, executor, blocks, weights_bytes) = shipped.remove(0);
            let replicas = shipped
                .into_iter()
                .map(|(node, executor, blocks, _bytes)| StageReplica {
                    node,
                    executor,
                    blocks,
                    mem_reserved: p.mem_bytes,
                })
                .collect();
            stages.push(Stage {
                partition_idx: p.partition_idx,
                node,
                executor,
                block_range: p.block_range.clone(),
                blocks,
                weights_bytes,
                mem_reserved: p.mem_bytes,
                replicas,
            });
        }
        Ok(transfer_bytes)
    }

    /// Heal ladder step 1 (ISSUE 8): rebuild a deployment around dead
    /// nodes *without re-partitioning* — every stage keeps its block
    /// range and its surviving placements (the model cache makes the
    /// re-ship near-free), and each replica slot lost to a dead node is
    /// re-placed on a fresh online node by the scheduler's replica-set
    /// extension (no overcommit: a degraded replica count beats a paging
    /// replica). The first surviving replica is promoted to primary when
    /// the primary died. Errors when some stage has no surviving replica
    /// — the caller falls back to a full re-partition. The old
    /// deployment stays live until the caller swaps engines and
    /// undeploys it, the same transient double-reservation a rebalance
    /// makes.
    pub fn heal_replace(
        &self,
        old: &Deployment,
        dead: &HashSet<usize>,
        cluster: &Cluster,
        scheduler: &Scheduler,
    ) -> Result<Deployment> {
        let t0 = Instant::now();
        let batch = old.batch;
        let nodes = cluster.online_nodes();
        anyhow::ensure!(!nodes.is_empty(), "no online nodes to heal onto");
        let alive =
            |n: &Arc<VirtualNode>| n.is_online() && !dead.contains(&n.id());

        // Surviving replica placements per stage; a stage with none
        // cannot be healed by re-placement alone.
        let mut survivors: Vec<Vec<Arc<VirtualNode>>> = Vec::new();
        for (k, s) in old.stages.iter().enumerate() {
            let alive_nodes: Vec<Arc<VirtualNode>> = (0..s.replica_count())
                .map(|r| Arc::clone(s.replica_node(r)))
                .filter(|n| alive(n))
                .collect();
            anyhow::ensure!(
                !alive_nodes.is_empty(),
                "stage {k} has no surviving replica; re-partition required"
            );
            survivors.push(alive_nodes);
        }
        let mut used: HashSet<usize> = survivors
            .iter()
            .flat_map(|v| v.iter().map(|n| n.id()))
            .collect();

        let mut stages = Vec::with_capacity(old.stages.len());
        let mut transfer_bytes = 0u64;
        for (s, alive_nodes) in old.stages.iter().zip(survivors) {
            let mem_bytes = self.stage_mem_bytes(&s.block_range, batch);
            let req = TaskRequirements {
                cpu: 0.1,
                mem_mb: mem_bytes as f64 / (1024.0 * 1024.0),
                priority: 0,
            };
            // Re-place each slot lost to a dead node on a fresh node.
            let lost = s.replica_count() - alive_nodes.len();
            let mut placements = alive_nodes;
            if lost > 0 {
                let fresh: Vec<_> = nodes
                    .iter()
                    .filter(|n| !used.contains(&n.id()) && alive(n))
                    .cloned()
                    .collect();
                let set = scheduler.select_replica_set(&fresh, &req, lost);
                if set.len() < lost {
                    crate::log_warn!(
                        "deployer",
                        "heal: stage {}: re-placed {} of {} lost replicas \
                         ({} fresh nodes can afford {:.1} MB)",
                        s.partition_idx,
                        set.len(),
                        lost,
                        fresh.len(),
                        req.mem_mb
                    );
                }
                for (rnode, _score) in set {
                    used.insert(rnode.id());
                    placements.push(rnode);
                }
            }

            // Ship (model-cache hits move zero bytes) and reserve on
            // every placement; the first is the — possibly promoted —
            // primary.
            let mut shipped = Vec::with_capacity(placements.len());
            for node in &placements {
                let executor = self.executor_for(node)?;
                let (blocks, stage_bytes, moved) =
                    self.ship_blocks(node, &executor, &s.block_range, batch)?;
                transfer_bytes += moved;
                node.mem_reserve(mem_bytes);
                shipped.push((
                    Arc::clone(node),
                    executor,
                    blocks,
                    stage_bytes,
                ));
            }
            let (node, executor, blocks, weights_bytes) = shipped.remove(0);
            let replicas = shipped
                .into_iter()
                .map(|(node, executor, blocks, _)| StageReplica {
                    node,
                    executor,
                    blocks,
                    mem_reserved: mem_bytes,
                })
                .collect();
            stages.push(Stage {
                partition_idx: s.partition_idx,
                node,
                executor,
                block_range: s.block_range.clone(),
                blocks,
                weights_bytes,
                mem_reserved: mem_bytes,
                replicas,
            });
        }

        Ok(Deployment {
            batch,
            stages,
            transfer_bytes,
            deploy_ms: t0.elapsed().as_secs_f64() * 1e3,
            out_shape: old.out_shape.clone(),
        })
    }

    /// Release node memory and executor-side blocks held by a deployment
    /// (every replica's, not just the primaries').
    pub fn undeploy(&self, deployment: &Deployment) {
        for s in &deployment.stages {
            s.node.mem_release(s.mem_reserved);
            for b in &s.blocks {
                s.executor.unload_block(*b);
            }
            for r in &s.replicas {
                r.node.mem_release(r.mem_reserved);
                for b in &r.blocks {
                    r.executor.unload_block(*b);
                }
            }
        }
    }

    /// Handle a topology change: re-plan for the current online node count
    /// and redeploy. The old deployment is undeployed first.
    pub fn redeploy_on_change(
        &self,
        old: Deployment,
        cluster: &Cluster,
        scheduler: &Scheduler,
    ) -> Result<Deployment> {
        let batch = old.batch;
        self.undeploy(&old);
        drop(old);
        let n = cluster.online_count().min(self.manifest.blocks.len()).max(1);
        let plan = partitioner::plan(&self.manifest, n)?;
        self.deploy(&plan, cluster, scheduler, batch)
    }

    /// Diagnostic: how many (node, block) payloads are cached.
    pub fn cached_payloads(&self) -> usize {
        self.model_cache.lock().unwrap().len()
    }

    /// Drop all cached payload records (forces full re-transfer).
    pub fn clear_model_cache(&self) {
        self.model_cache.lock().unwrap().clear();
    }
}

// Integration tests for the deployer live in rust/tests/ (they need the
// artifacts directory and PJRT clients).
