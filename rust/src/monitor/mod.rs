//! Resource Monitor — paper §III-A.
//!
//! A background sampler thread polls every node's counters (CPU load,
//! memory working set, network rx/tx, stability) at a configurable rate
//! (the paper samples Docker stats at 1 Hz) and keeps a bounded history of
//! cluster snapshots. The partitioner and scheduler consume the *latest*
//! snapshot; offline nodes are detected and excluded (the "device offline"
//! scenario in §I).
//!
//! The monitor also measures its own cost: §IV-E claims monitoring adds
//! <= 1% CPU — [`MonitorHandle::overhead_cpu_pct`] reports the sampler
//! thread's busy fraction so the scalability bench can verify that claim.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::cluster::{Cluster, NodeSnapshot};

/// One timestamped cluster-wide sample.
#[derive(Debug, Clone)]
pub struct ClusterSnapshot {
    /// Milliseconds since the monitor started.
    pub t_ms: f64,
    pub nodes: Vec<NodeSnapshot>,
}

impl ClusterSnapshot {
    pub fn online(&self) -> impl Iterator<Item = &NodeSnapshot> {
        self.nodes.iter().filter(|n| n.online)
    }

    pub fn total_rx_tx(&self) -> (u64, u64) {
        self.nodes
            .iter()
            .fold((0, 0), |(rx, tx), n| (rx + n.rx_bytes, tx + n.tx_bytes))
    }

    pub fn mean_load(&self) -> f64 {
        let online: Vec<_> = self.online().collect();
        if online.is_empty() {
            return 0.0;
        }
        online.iter().map(|n| n.current_load).sum::<f64>() / online.len() as f64
    }

    pub fn mean_stability(&self) -> f64 {
        let online: Vec<_> = self.online().collect();
        if online.is_empty() {
            return 1.0;
        }
        online.iter().map(|n| n.stability).sum::<f64>() / online.len() as f64
    }
}

/// Monitor configuration.
#[derive(Debug, Clone)]
pub struct MonitorConfig {
    pub sample_interval: Duration,
    /// Max snapshots retained (ring buffer).
    pub history_len: usize,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        // Paper: 1 Hz sampling, 100 ms aggregation window. We default to
        // 10 Hz so short benchmark runs still collect useful history.
        MonitorConfig { sample_interval: Duration::from_millis(100), history_len: 4096 }
    }
}

struct Shared {
    history: Mutex<VecDeque<ClusterSnapshot>>,
    busy: Mutex<SelfCost>,
    stop: AtomicBool,
}

#[derive(Default)]
struct SelfCost {
    busy_ms: f64,
    wall_start: Option<Instant>,
}

/// Handle to a running monitor; dropping it stops the sampler thread.
pub struct MonitorHandle {
    shared: Arc<Shared>,
    thread: Option<thread::JoinHandle<()>>,
}

/// Spawn the sampling thread over `cluster`.
pub fn spawn(cluster: Arc<Cluster>, config: MonitorConfig) -> MonitorHandle {
    let shared = Arc::new(Shared {
        history: Mutex::new(VecDeque::with_capacity(config.history_len)),
        busy: Mutex::new(SelfCost { busy_ms: 0.0, wall_start: Some(Instant::now()) }),
        stop: AtomicBool::new(false),
    });
    let worker_shared = Arc::clone(&shared);
    let start = Instant::now();
    let thread = thread::Builder::new()
        .name("amp4ec-monitor".into())
        .spawn(move || {
            while !worker_shared.stop.load(Ordering::SeqCst) {
                let t0 = Instant::now();
                let snapshot = ClusterSnapshot {
                    t_ms: start.elapsed().as_secs_f64() * 1e3,
                    nodes: cluster
                        .all_nodes()
                        .iter()
                        .map(|n| n.snapshot())
                        .collect(),
                };
                {
                    let mut hist = worker_shared.history.lock().unwrap();
                    if hist.len() == config.history_len {
                        hist.pop_front();
                    }
                    hist.push_back(snapshot);
                }
                let spent = t0.elapsed().as_secs_f64() * 1e3;
                worker_shared.busy.lock().unwrap().busy_ms += spent;
                thread::sleep(config.sample_interval);
            }
        })
        .expect("spawn monitor thread");
    MonitorHandle { shared, thread: Some(thread) }
}

impl MonitorHandle {
    /// Most recent snapshot, if any sample completed yet.
    pub fn latest(&self) -> Option<ClusterSnapshot> {
        self.shared.history.lock().unwrap().back().cloned()
    }

    /// Full retained history (oldest first).
    pub fn history(&self) -> Vec<ClusterSnapshot> {
        self.shared.history.lock().unwrap().iter().cloned().collect()
    }

    pub fn samples_taken(&self) -> usize {
        self.shared.history.lock().unwrap().len()
    }

    /// The sampler thread's own CPU cost as a percentage of wall time —
    /// the §IV-E "monitoring overhead <= 1%" metric.
    pub fn overhead_cpu_pct(&self) -> f64 {
        let busy = self.shared.busy.lock().unwrap();
        match busy.wall_start {
            None => 0.0,
            Some(t0) => {
                let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
                if wall_ms <= 0.0 {
                    0.0
                } else {
                    100.0 * busy.busy_ms / wall_ms
                }
            }
        }
    }

    pub fn stop(mut self) -> Vec<ClusterSnapshot> {
        self.stop_inner();
        self.history()
    }

    fn stop_inner(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for MonitorHandle {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{NodeSpec, SimParams};

    fn cluster_with(n: usize) -> Arc<Cluster> {
        let c = Arc::new(Cluster::new(SimParams::default()));
        for i in 0..n {
            c.add_node(NodeSpec::new(&format!("n{i}"), 1.0, 512.0));
        }
        c
    }

    #[test]
    fn samples_accumulate() {
        let c = cluster_with(2);
        let m = spawn(
            Arc::clone(&c),
            MonitorConfig { sample_interval: Duration::from_millis(5), history_len: 100 },
        );
        thread::sleep(Duration::from_millis(60));
        assert!(m.samples_taken() >= 3);
        let latest = m.latest().unwrap();
        assert_eq!(latest.nodes.len(), 2);
        assert!(latest.online().count() == 2);
    }

    #[test]
    fn detects_offline_nodes() {
        let c = cluster_with(2);
        let id = c.all_nodes()[0].id();
        let m = spawn(
            Arc::clone(&c),
            MonitorConfig { sample_interval: Duration::from_millis(5), history_len: 100 },
        );
        thread::sleep(Duration::from_millis(20));
        c.remove_node(id);
        thread::sleep(Duration::from_millis(20));
        let latest = m.latest().unwrap();
        assert_eq!(latest.online().count(), 1);
        assert_eq!(latest.nodes.len(), 2); // still reported, marked offline
    }

    #[test]
    fn history_ring_bounded() {
        let c = cluster_with(1);
        let m = spawn(
            Arc::clone(&c),
            MonitorConfig { sample_interval: Duration::from_millis(1), history_len: 5 },
        );
        thread::sleep(Duration::from_millis(50));
        assert!(m.samples_taken() <= 5);
        let h = m.history();
        // Oldest-first ordering.
        for pair in h.windows(2) {
            assert!(pair[0].t_ms <= pair[1].t_ms);
        }
    }

    #[test]
    fn overhead_is_small() {
        let c = cluster_with(3);
        let m = spawn(
            Arc::clone(&c),
            MonitorConfig { sample_interval: Duration::from_millis(100), history_len: 100 },
        );
        thread::sleep(Duration::from_millis(250));
        // The paper claims <= 1% CPU for 1 Hz; at 10 Hz over 3 nodes we
        // should still be far below 5%.
        assert!(m.overhead_cpu_pct() < 5.0, "{}", m.overhead_cpu_pct());
    }

    #[test]
    fn stop_returns_history() {
        let c = cluster_with(1);
        let m = spawn(
            Arc::clone(&c),
            MonitorConfig { sample_interval: Duration::from_millis(5), history_len: 100 },
        );
        thread::sleep(Duration::from_millis(20));
        let h = m.stop();
        assert!(!h.is_empty());
    }

    #[test]
    fn snapshot_aggregates() {
        let c = cluster_with(2);
        let snap = ClusterSnapshot {
            t_ms: 0.0,
            nodes: c.all_nodes().iter().map(|n| n.snapshot()).collect(),
        };
        assert_eq!(snap.total_rx_tx(), (0, 0));
        assert_eq!(snap.mean_load(), 0.0);
        assert_eq!(snap.mean_stability(), 1.0);
    }
}
