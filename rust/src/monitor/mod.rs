//! Resource Monitor — paper §III-A.
//!
//! A background sampler thread polls every node's counters (CPU load,
//! memory working set, network rx/tx, stability) at a configurable rate
//! (the paper samples Docker stats at 1 Hz) and keeps a bounded history of
//! cluster snapshots. The partitioner and scheduler consume the *latest*
//! snapshot; offline nodes are detected and excluded (the "device offline"
//! scenario in §I).
//!
//! The monitor also measures its own cost: §IV-E claims monitoring adds
//! <= 1% CPU — [`MonitorHandle::overhead_cpu_pct`] reports the sampler
//! thread's busy fraction so the scalability bench can verify that claim.
//!
//! **Liveness** (ISSUE 8): beyond the point-in-time `online` flags, the
//! sampler counts *consecutive* offline samples per node. A node past
//! [`MonitorConfig::miss_threshold`] misses is declared dead — the
//! liveness epoch bumps and a [`NodeEvent::Died`] lands on the event
//! feed; a dead node sampling online again is declared returned
//! ([`NodeEvent::Returned`], epoch bump). The serving layer's heal
//! watchdog keys off [`MonitorHandle::liveness_epoch`] instead of
//! polling flags, so an equal-count leave+join is never invisible.

use std::collections::{BTreeSet, HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::cluster::{Cluster, NodeId, NodeSnapshot};

/// One timestamped cluster-wide sample.
#[derive(Debug, Clone)]
pub struct ClusterSnapshot {
    /// Milliseconds since the monitor started.
    pub t_ms: f64,
    pub nodes: Vec<NodeSnapshot>,
}

impl ClusterSnapshot {
    pub fn online(&self) -> impl Iterator<Item = &NodeSnapshot> {
        self.nodes.iter().filter(|n| n.online)
    }

    pub fn total_rx_tx(&self) -> (u64, u64) {
        self.nodes
            .iter()
            .fold((0, 0), |(rx, tx), n| (rx + n.rx_bytes, tx + n.tx_bytes))
    }

    pub fn mean_load(&self) -> f64 {
        let online: Vec<_> = self.online().collect();
        if online.is_empty() {
            return 0.0;
        }
        online.iter().map(|n| n.current_load).sum::<f64>() / online.len() as f64
    }

    pub fn mean_stability(&self) -> f64 {
        let online: Vec<_> = self.online().collect();
        if online.is_empty() {
            return 1.0;
        }
        online.iter().map(|n| n.stability).sum::<f64>() / online.len() as f64
    }
}

/// Monitor configuration.
#[derive(Debug, Clone)]
pub struct MonitorConfig {
    pub sample_interval: Duration,
    /// Max snapshots retained (ring buffer).
    pub history_len: usize,
    /// Consecutive offline samples before a node is declared *dead*
    /// (heartbeat misses). One flaky sample is not a death; the
    /// threshold trades detection latency (`miss_threshold *
    /// sample_interval`) against false positives.
    pub miss_threshold: u32,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        // Paper: 1 Hz sampling, 100 ms aggregation window. We default to
        // 10 Hz so short benchmark runs still collect useful history.
        MonitorConfig {
            sample_interval: Duration::from_millis(100),
            history_len: 4096,
            miss_threshold: 3,
        }
    }
}

/// A liveness transition observed by the sampler.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NodeEvent {
    /// The node missed `miss_threshold` consecutive samples.
    Died { node: NodeId, t_ms: f64 },
    /// A previously-dead node sampled online again (warm re-admission).
    Returned { node: NodeId, t_ms: f64 },
}

impl NodeEvent {
    pub fn node(&self) -> NodeId {
        match *self {
            NodeEvent::Died { node, .. } | NodeEvent::Returned { node, .. } => node,
        }
    }
}

/// Bound on the pending event feed: a reader that never drains still
/// leaves the sampler O(1); the epoch counter is the lossless signal.
const MAX_PENDING_EVENTS: usize = 1024;

#[derive(Default)]
struct Liveness {
    /// Consecutive offline samples per node.
    misses: HashMap<NodeId, u32>,
    /// Nodes currently declared dead.
    dead: BTreeSet<NodeId>,
    /// Undelivered transitions (oldest first, bounded).
    events: VecDeque<NodeEvent>,
}

struct Shared {
    history: Mutex<VecDeque<ClusterSnapshot>>,
    busy: Mutex<SelfCost>,
    liveness: Mutex<Liveness>,
    /// Bumped on every death/return declaration; watchers poll this.
    liveness_epoch: AtomicU64,
    /// Interruptible stop: `stop()` flips the flag and notifies, so a
    /// sampler mid-wait wakes immediately instead of finishing its
    /// interval.
    stop: Mutex<bool>,
    stop_cv: Condvar,
}

#[derive(Default)]
struct SelfCost {
    busy_ms: f64,
    wall_start: Option<Instant>,
}

/// Handle to a running monitor; dropping it stops the sampler thread.
pub struct MonitorHandle {
    shared: Arc<Shared>,
    thread: Option<thread::JoinHandle<()>>,
}

/// Fold one sample into the liveness state: offline nodes accumulate
/// consecutive misses and cross into `dead` at the threshold; online
/// nodes reset their counter and resurrect out of `dead`. Returns how
/// many transitions were declared (the epoch delta).
fn observe_liveness(
    lv: &mut Liveness,
    snapshot: &ClusterSnapshot,
    miss_threshold: u32,
) -> u64 {
    let mut transitions = 0;
    for n in &snapshot.nodes {
        if n.online {
            lv.misses.insert(n.id, 0);
            if lv.dead.remove(&n.id) {
                lv.events.push_back(NodeEvent::Returned {
                    node: n.id,
                    t_ms: snapshot.t_ms,
                });
                transitions += 1;
            }
        } else {
            let misses = lv.misses.entry(n.id).or_insert(0);
            *misses = misses.saturating_add(1);
            if *misses >= miss_threshold && lv.dead.insert(n.id) {
                lv.events.push_back(NodeEvent::Died {
                    node: n.id,
                    t_ms: snapshot.t_ms,
                });
                transitions += 1;
            }
        }
    }
    while lv.events.len() > MAX_PENDING_EVENTS {
        lv.events.pop_front();
    }
    transitions
}

/// Spawn the sampling thread over `cluster`.
pub fn spawn(cluster: Arc<Cluster>, config: MonitorConfig) -> MonitorHandle {
    let shared = Arc::new(Shared {
        history: Mutex::new(VecDeque::with_capacity(config.history_len)),
        busy: Mutex::new(SelfCost { busy_ms: 0.0, wall_start: Some(Instant::now()) }),
        liveness: Mutex::new(Liveness::default()),
        liveness_epoch: AtomicU64::new(0),
        stop: Mutex::new(false),
        stop_cv: Condvar::new(),
    });
    let worker_shared = Arc::clone(&shared);
    let start = Instant::now();
    let miss_threshold = config.miss_threshold.max(1);
    let thread = thread::Builder::new()
        .name("amp4ec-monitor".into())
        .spawn(move || {
            // Deadline-based tick: each sample is due one interval after
            // the *previous deadline*, not one interval after the sample
            // finished — so the effective rate stays pinned at the
            // configured one instead of drifting low by the per-sample
            // cost.
            let mut next = Instant::now();
            loop {
                // Interruptible wait until the deadline: stop() flips
                // the flag and notifies, so teardown never blocks a
                // full interval behind a sleeping sampler.
                {
                    let mut stopped = worker_shared.stop.lock().unwrap();
                    loop {
                        if *stopped {
                            return;
                        }
                        let now = Instant::now();
                        if now >= next {
                            break;
                        }
                        let (guard, _) = worker_shared
                            .stop_cv
                            .wait_timeout(stopped, next - now)
                            .unwrap();
                        stopped = guard;
                    }
                }
                let t0 = Instant::now();
                let snapshot = ClusterSnapshot {
                    t_ms: start.elapsed().as_secs_f64() * 1e3,
                    nodes: cluster
                        .all_nodes()
                        .iter()
                        .map(|n| n.snapshot())
                        .collect(),
                };
                {
                    let mut lv = worker_shared.liveness.lock().unwrap();
                    let transitions =
                        observe_liveness(&mut lv, &snapshot, miss_threshold);
                    if transitions > 0 {
                        worker_shared
                            .liveness_epoch
                            .fetch_add(transitions, Ordering::SeqCst);
                    }
                }
                {
                    let mut hist = worker_shared.history.lock().unwrap();
                    if hist.len() == config.history_len {
                        hist.pop_front();
                    }
                    hist.push_back(snapshot);
                }
                let spent = t0.elapsed().as_secs_f64() * 1e3;
                worker_shared.busy.lock().unwrap().busy_ms += spent;
                next += config.sample_interval;
                let now = Instant::now();
                if next < now {
                    // A sample overran whole intervals: skip ahead
                    // rather than bursting to catch up.
                    next = now;
                }
            }
        })
        .expect("spawn monitor thread");
    MonitorHandle { shared, thread: Some(thread) }
}

impl MonitorHandle {
    /// Most recent snapshot, if any sample completed yet.
    pub fn latest(&self) -> Option<ClusterSnapshot> {
        self.shared.history.lock().unwrap().back().cloned()
    }

    /// Full retained history (oldest first).
    pub fn history(&self) -> Vec<ClusterSnapshot> {
        self.shared.history.lock().unwrap().iter().cloned().collect()
    }

    pub fn samples_taken(&self) -> usize {
        self.shared.history.lock().unwrap().len()
    }

    /// The sampler thread's own CPU cost as a percentage of wall time —
    /// the §IV-E "monitoring overhead <= 1%" metric.
    pub fn overhead_cpu_pct(&self) -> f64 {
        let busy = self.shared.busy.lock().unwrap();
        match busy.wall_start {
            None => 0.0,
            Some(t0) => {
                let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
                if wall_ms <= 0.0 {
                    0.0
                } else {
                    100.0 * busy.busy_ms / wall_ms
                }
            }
        }
    }

    /// Liveness epoch: bumped once per death/return declaration.
    /// Watchers poll this and react to changes — cheaper and more
    /// complete than diffing snapshots (an equal-count leave+join moves
    /// the epoch twice).
    pub fn liveness_epoch(&self) -> u64 {
        self.shared.liveness_epoch.load(Ordering::SeqCst)
    }

    /// Nodes currently declared dead (>= `miss_threshold` consecutive
    /// missed samples, not yet seen back online).
    pub fn dead_nodes(&self) -> Vec<NodeId> {
        self.shared.liveness.lock().unwrap().dead.iter().copied().collect()
    }

    /// Drain the pending liveness transitions (oldest first). Each event
    /// is delivered to exactly one drainer.
    pub fn drain_events(&self) -> Vec<NodeEvent> {
        self.shared.liveness.lock().unwrap().events.drain(..).collect()
    }

    pub fn stop(mut self) -> Vec<ClusterSnapshot> {
        self.stop_inner();
        self.history()
    }

    fn stop_inner(&mut self) {
        *self.shared.stop.lock().unwrap() = true;
        self.shared.stop_cv.notify_all();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for MonitorHandle {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{NodeSpec, SimParams};

    fn cluster_with(n: usize) -> Arc<Cluster> {
        let c = Arc::new(Cluster::new(SimParams::default()));
        for i in 0..n {
            c.add_node(NodeSpec::new(&format!("n{i}"), 1.0, 512.0));
        }
        c
    }

    #[test]
    fn samples_accumulate() {
        let c = cluster_with(2);
        let m = spawn(
            Arc::clone(&c),
            MonitorConfig {
                sample_interval: Duration::from_millis(5),
                history_len: 100,
                ..MonitorConfig::default()
            },
        );
        thread::sleep(Duration::from_millis(60));
        assert!(m.samples_taken() >= 3);
        let latest = m.latest().unwrap();
        assert_eq!(latest.nodes.len(), 2);
        assert!(latest.online().count() == 2);
    }

    #[test]
    fn detects_offline_nodes() {
        let c = cluster_with(2);
        let id = c.all_nodes()[0].id();
        let m = spawn(
            Arc::clone(&c),
            MonitorConfig {
                sample_interval: Duration::from_millis(5),
                history_len: 100,
                ..MonitorConfig::default()
            },
        );
        thread::sleep(Duration::from_millis(20));
        c.remove_node(id);
        thread::sleep(Duration::from_millis(20));
        let latest = m.latest().unwrap();
        assert_eq!(latest.online().count(), 1);
        assert_eq!(latest.nodes.len(), 2); // still reported, marked offline
    }

    #[test]
    fn history_ring_bounded() {
        let c = cluster_with(1);
        let m = spawn(
            Arc::clone(&c),
            MonitorConfig {
                sample_interval: Duration::from_millis(1),
                history_len: 5,
                ..MonitorConfig::default()
            },
        );
        thread::sleep(Duration::from_millis(50));
        assert!(m.samples_taken() <= 5);
        let h = m.history();
        // Oldest-first ordering.
        for pair in h.windows(2) {
            assert!(pair[0].t_ms <= pair[1].t_ms);
        }
    }

    #[test]
    fn overhead_is_small() {
        let c = cluster_with(3);
        let m = spawn(
            Arc::clone(&c),
            MonitorConfig {
                sample_interval: Duration::from_millis(100),
                history_len: 100,
                ..MonitorConfig::default()
            },
        );
        thread::sleep(Duration::from_millis(250));
        // The paper claims <= 1% CPU for 1 Hz; at 10 Hz over 3 nodes we
        // should still be far below 5%.
        assert!(m.overhead_cpu_pct() < 5.0, "{}", m.overhead_cpu_pct());
    }

    #[test]
    fn stop_returns_history() {
        let c = cluster_with(1);
        let m = spawn(
            Arc::clone(&c),
            MonitorConfig {
                sample_interval: Duration::from_millis(5),
                history_len: 100,
                ..MonitorConfig::default()
            },
        );
        thread::sleep(Duration::from_millis(20));
        let h = m.stop();
        assert!(!h.is_empty());
    }

    #[test]
    fn sample_rate_pinned_by_deadline_tick() {
        // The ISSUE-8 rate-drift regression: the sampler must hit the
        // configured rate (deadline tick), not interval-plus-sample-cost.
        // With the old post-cost sleep the count was only guaranteed to
        // be wall / (interval + cost); the deadline tick guarantees
        // close to wall / interval.
        let c = cluster_with(2);
        let interval = Duration::from_millis(10);
        let m = spawn(
            Arc::clone(&c),
            MonitorConfig {
                sample_interval: interval,
                history_len: 1000,
                ..MonitorConfig::default()
            },
        );
        thread::sleep(Duration::from_millis(205));
        let taken = m.samples_taken();
        // 205 ms / 10 ms = ~20 deadlines; allow generous scheduler slop
        // but fail on systematic drift (the old behaviour loses one tick
        // for every interval's worth of accumulated sample cost).
        assert!(taken >= 12, "sampler drifted: {taken} samples in 205 ms");
        drop(m);
    }

    #[test]
    fn stop_is_prompt_even_mid_interval() {
        // With a multi-second interval the old stop()/Drop joined a
        // sleeping thread for up to the whole interval. The condvar wait
        // must wake immediately.
        let c = cluster_with(1);
        let m = spawn(
            Arc::clone(&c),
            MonitorConfig {
                sample_interval: Duration::from_secs(30),
                history_len: 10,
                ..MonitorConfig::default()
            },
        );
        // Let the first sample land so the thread is parked in its wait.
        thread::sleep(Duration::from_millis(30));
        let t0 = Instant::now();
        let h = m.stop();
        assert!(
            t0.elapsed() < Duration::from_millis(500),
            "stop blocked {:?} behind a sleeping sampler",
            t0.elapsed()
        );
        assert!(!h.is_empty());
    }

    #[test]
    fn death_declared_after_miss_threshold_and_return_observed() {
        let c = cluster_with(2);
        let id = c.all_nodes()[0].id();
        let m = spawn(
            Arc::clone(&c),
            MonitorConfig {
                sample_interval: Duration::from_millis(3),
                history_len: 1000,
                miss_threshold: 3,
            },
        );
        thread::sleep(Duration::from_millis(20));
        assert_eq!(m.liveness_epoch(), 0);
        assert!(m.dead_nodes().is_empty());

        c.remove_node(id);
        // 3 consecutive misses at 3 ms apiece: well within 100 ms.
        let deadline = Instant::now() + Duration::from_millis(1000);
        while m.dead_nodes().is_empty() && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(m.dead_nodes(), vec![id]);
        assert_eq!(m.liveness_epoch(), 1);
        let events = m.drain_events();
        assert!(
            matches!(events.as_slice(), [NodeEvent::Died { node, .. }] if *node == id),
            "expected one Died event, got {events:?}"
        );

        // Warm return: the node resurrects out of the dead set.
        c.readmit_node(id);
        let deadline = Instant::now() + Duration::from_millis(1000);
        while !m.dead_nodes().is_empty() && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(5));
        }
        assert!(m.dead_nodes().is_empty());
        assert_eq!(m.liveness_epoch(), 2);
        let events = m.drain_events();
        assert!(
            matches!(events.as_slice(), [NodeEvent::Returned { node, .. }] if *node == id),
            "expected one Returned event, got {events:?}"
        );
        assert!(m.drain_events().is_empty(), "events drain exactly once");
    }

    #[test]
    fn misses_below_threshold_are_not_death() {
        // A huge threshold: the node stays merely offline, never dead.
        let c = cluster_with(1);
        let id = c.all_nodes()[0].id();
        let m = spawn(
            Arc::clone(&c),
            MonitorConfig {
                sample_interval: Duration::from_millis(2),
                history_len: 1000,
                miss_threshold: 100_000,
            },
        );
        c.remove_node(id);
        thread::sleep(Duration::from_millis(40));
        assert!(m.dead_nodes().is_empty());
        assert_eq!(m.liveness_epoch(), 0);
        assert!(m.drain_events().is_empty());
    }

    #[test]
    fn observe_liveness_counts_transitions() {
        // Unit-level: threshold crossing, no double-death, resurrection.
        let mk = |online: bool| ClusterSnapshot {
            t_ms: 1.0,
            nodes: vec![NodeSnapshot { online, ..cluster_with(1).all_nodes()[0].snapshot() }],
        };
        let mut lv = Liveness::default();
        assert_eq!(observe_liveness(&mut lv, &mk(false), 2), 0);
        assert_eq!(observe_liveness(&mut lv, &mk(false), 2), 1);
        assert_eq!(observe_liveness(&mut lv, &mk(false), 2), 0); // already dead
        assert_eq!(observe_liveness(&mut lv, &mk(true), 2), 1); // returned
        assert_eq!(observe_liveness(&mut lv, &mk(true), 2), 0);
        assert_eq!(lv.events.len(), 2);
    }

    #[test]
    fn snapshot_aggregates() {
        let c = cluster_with(2);
        let snap = ClusterSnapshot {
            t_ms: 0.0,
            nodes: c.all_nodes().iter().map(|n| n.snapshot()).collect(),
        };
        assert_eq!(snap.total_rx_tx(), (0, 0));
        assert_eq!(snap.mean_load(), 0.0);
        assert_eq!(snap.mean_stability(), 1.0);
    }
}
