//! Result cache — the "+Cache" in AMP4EC+Cache (paper §IV-B).
//!
//! An LRU keyed by an FNV-1a hash of (model id, input tensor bytes). A hit
//! short-circuits the whole distributed pipeline: no node compute, no
//! activation transfers — which is how the paper's cached configuration
//! drives both the 2.6x latency cut over plain AMP4EC and the
//! bandwidth-to-zero effect on repeated inputs.
//!
//! Rows are stored as [`TensorBuf`]s (`Arc<Vec<f32>>`): a hit hands the
//! serving path a refcounted buffer it wraps into a zero-copy
//! [`crate::runtime::Tensor`] view, and inserts copy the row *once* out
//! of the batched output so a cached row can never alias a live
//! activation buffer (mutating an executor output must never change a
//! cached answer — pinned by the data-plane aliasing test).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::runtime::TensorBuf;

/// FNV-1a over arbitrary bytes; deterministic across runs and platforms.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Hash a model-scoped f32 input tensor.
pub fn input_key(model_id: u64, input: &[f32]) -> u64 {
    let mut h = fnv1a(&model_id.to_le_bytes());
    // Hash the raw f32 bits in bulk.
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(input.as_ptr() as *const u8,
                                   std::mem::size_of_val(input))
    };
    h ^= fnv1a(bytes);
    h.wrapping_mul(0x9E3779B97F4A7C15)
}

struct Entry {
    /// Shared with the serving response path: hits hand back a cheap
    /// `Arc` clone the caller wraps into a zero-copy tensor view.
    value: TensorBuf,
    /// LRU tick at last touch.
    last_used: u64,
}

/// Bounded LRU result cache.
pub struct ResultCache {
    map: Mutex<HashMap<u64, Entry>>,
    max_entries: usize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

impl ResultCache {
    pub fn new(max_entries: usize) -> ResultCache {
        assert!(max_entries > 0);
        ResultCache {
            map: Mutex::new(HashMap::new()),
            max_entries,
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn get(&self, key: u64) -> Option<TensorBuf> {
        let tick = self.tick.fetch_add(1, Ordering::SeqCst);
        let mut map = self.map.lock().unwrap();
        match map.get_mut(&key) {
            Some(e) => {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::SeqCst);
                Some(Arc::clone(&e.value))
            }
            None => {
                self.misses.fetch_add(1, Ordering::SeqCst);
                None
            }
        }
    }

    /// Stats-neutral presence probe: no hit/miss accounting, no LRU
    /// touch. The serving ingress uses this at admission — a request
    /// whose answer is already cached costs ~0 ms to serve, so the
    /// deadline shedder must not reject it on the batch service-time
    /// estimate, and the probe must not distort the cache metrics the
    /// real lookup records later.
    pub fn contains(&self, key: u64) -> bool {
        self.map.lock().unwrap().contains_key(&key)
    }

    pub fn put(&self, key: u64, value: TensorBuf) {
        let tick = self.tick.fetch_add(1, Ordering::SeqCst);
        let mut map = self.map.lock().unwrap();
        if map.len() >= self.max_entries && !map.contains_key(&key) {
            // Evict the least-recently-used entry.
            if let Some((&lru_key, _)) =
                map.iter().min_by_key(|(_, e)| e.last_used)
            {
                map.remove(&lru_key);
            }
        }
        map.insert(key, Entry { value, last_used: tick });
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::SeqCst),
            misses: self.misses.load(Ordering::SeqCst),
            entries: self.map.lock().unwrap().len(),
        }
    }

    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn fnv_known_vectors() {
        // FNV-1a("") = offset basis; FNV-1a("a") known value.
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn input_key_sensitive_to_model_and_data() {
        let a = input_key(1, &[1.0, 2.0]);
        let b = input_key(2, &[1.0, 2.0]);
        let c = input_key(1, &[1.0, 2.5]);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, input_key(1, &[1.0, 2.0]));
    }

    fn row(vals: &[f32]) -> TensorBuf {
        Arc::new(vals.to_vec())
    }

    #[test]
    fn hit_miss_accounting() {
        let cache = ResultCache::new(4);
        assert!(cache.get(1).is_none());
        cache.put(1, row(&[1.0]));
        assert_eq!(&cache.get(1).unwrap()[..], &[1.0f32][..]);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn hits_share_the_stored_row() {
        // A hit is an Arc clone of the inserted row, not a copy.
        let cache = ResultCache::new(4);
        let stored = row(&[4.0, 5.0]);
        cache.put(9, Arc::clone(&stored));
        let hit = cache.get(9).unwrap();
        assert!(Arc::ptr_eq(&stored, &hit));
    }

    #[test]
    fn lru_evicts_oldest() {
        let cache = ResultCache::new(2);
        cache.put(1, row(&[1.0]));
        cache.put(2, row(&[2.0]));
        cache.get(1); // touch 1, so 2 is LRU
        cache.put(3, row(&[3.0]));
        assert!(cache.get(2).is_none());
        assert!(cache.get(1).is_some());
        assert!(cache.get(3).is_some());
    }

    #[test]
    fn property_never_exceeds_capacity() {
        forall(50, 0xCAC4E, |rng| {
            let cap = rng.range(1, 8);
            let cache = ResultCache::new(cap);
            for _ in 0..50 {
                cache.put(rng.next_u64() % 20, row(&[0.0]));
                assert!(cache.stats().entries <= cap);
            }
        });
    }

    #[test]
    fn overwrite_same_key_is_not_eviction() {
        let cache = ResultCache::new(1);
        cache.put(5, row(&[1.0]));
        cache.put(5, row(&[2.0]));
        assert_eq!(&cache.get(5).unwrap()[..], &[2.0f32][..]);
        assert_eq!(cache.stats().entries, 1);
    }
}
