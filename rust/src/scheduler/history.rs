//! Per-node performance history (paper §III-C: "maintains a performance
//! history cache that tracks execution patterns and node capabilities").

use std::collections::VecDeque;

/// Sliding window of recent execution times plus lifetime aggregates.
#[derive(Debug, Clone)]
pub struct PerformanceHistory {
    window: VecDeque<f64>,
    capacity: usize,
    total_tasks: u64,
    total_ms: f64,
}

impl PerformanceHistory {
    pub fn new(capacity: usize) -> PerformanceHistory {
        assert!(capacity > 0);
        PerformanceHistory {
            window: VecDeque::with_capacity(capacity),
            capacity,
            total_tasks: 0,
            total_ms: 0.0,
        }
    }

    pub fn record(&mut self, exec_ms: f64) {
        if self.window.len() == self.capacity {
            self.window.pop_front();
        }
        self.window.push_back(exec_ms);
        self.total_tasks += 1;
        self.total_ms += exec_ms;
    }

    /// Average execution time over the recent window, ms. 0 when empty.
    pub fn avg_exec_ms(&self) -> f64 {
        if self.window.is_empty() {
            0.0
        } else {
            self.window.iter().sum::<f64>() / self.window.len() as f64
        }
    }

    /// Paper Eq. 7: S_P = 1 / (1 + AvgExecTime), with exec time expressed
    /// in seconds so the score stays meaningfully spread over ms-scale
    /// inference latencies.
    pub fn performance_score(&self) -> f64 {
        1.0 / (1.0 + self.avg_exec_ms() / 1000.0)
    }

    /// "Recent task performance normalized into a 0-1 range" (§III-C):
    /// newest sample scaled against the window max (1 = fastest recent).
    pub fn normalized_recent(&self) -> f64 {
        let max = self.window.iter().copied().fold(f64::MIN, f64::max);
        match self.window.back() {
            None => 1.0,
            Some(_last) if max <= 0.0 => 1.0,
            Some(last) => 1.0 - (last / max).clamp(0.0, 1.0) + 1.0 / (1.0 + max),
        }
    }

    pub fn total_tasks(&self) -> u64 {
        self.total_tasks
    }

    pub fn lifetime_avg_ms(&self) -> f64 {
        if self.total_tasks == 0 {
            0.0
        } else {
            self.total_ms / self.total_tasks as f64
        }
    }

    pub fn window_len(&self) -> usize {
        self.window.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_history_is_optimistic() {
        let h = PerformanceHistory::new(8);
        assert_eq!(h.avg_exec_ms(), 0.0);
        assert_eq!(h.performance_score(), 1.0);
    }

    #[test]
    fn window_caps_and_slides() {
        let mut h = PerformanceHistory::new(3);
        for v in [10.0, 20.0, 30.0, 40.0] {
            h.record(v);
        }
        assert_eq!(h.window_len(), 3);
        assert!((h.avg_exec_ms() - 30.0).abs() < 1e-9);
        assert_eq!(h.total_tasks(), 4);
        assert!((h.lifetime_avg_ms() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn performance_score_decreases_with_slowness() {
        let mut fast = PerformanceHistory::new(4);
        fast.record(50.0);
        let mut slow = PerformanceHistory::new(4);
        slow.record(2000.0);
        assert!(fast.performance_score() > slow.performance_score());
        assert!(fast.performance_score() <= 1.0);
        assert!(slow.performance_score() > 0.0);
    }

    #[test]
    fn eq7_exact_values() {
        let mut h = PerformanceHistory::new(4);
        h.record(1000.0); // 1 second
        assert!((h.performance_score() - 0.5).abs() < 1e-9);
    }
}
