//! Predictive resource allocation (paper §V future work).
//!
//! [`LoadPredictor`] fits a least-squares line to each node's recent load
//! samples (fed from monitor snapshots) and extrapolates a short horizon
//! ahead. [`super::Scheduler::select_node_predictive`] swaps the
//! *current* load in Eq. 6 for the *predicted* load, so a node that is
//! ramping up stops attracting new work one scheduling period earlier.
//! `benches/ablation.rs` quantifies the effect under a ramping workload.

use std::collections::{HashMap, VecDeque};
use std::sync::Mutex;

use crate::cluster::NodeId;
use crate::monitor::ClusterSnapshot;

/// Per-node sliding window of (t_ms, load) samples.
#[derive(Debug, Clone)]
struct Series {
    samples: VecDeque<(f64, f64)>,
    capacity: usize,
}

impl Series {
    fn new(capacity: usize) -> Series {
        Series { samples: VecDeque::with_capacity(capacity), capacity }
    }

    fn push(&mut self, t_ms: f64, load: f64) {
        if self.samples.len() == self.capacity {
            self.samples.pop_front();
        }
        self.samples.push_back((t_ms, load));
    }

    /// Least-squares slope + intercept over the window. Falls back to the
    /// latest sample when there is not enough signal.
    fn forecast(&self, at_ms: f64) -> Option<f64> {
        let n = self.samples.len();
        if n == 0 {
            return None;
        }
        let last = self.samples.back().unwrap().1;
        if n < 3 {
            return Some(last);
        }
        let (mut sx, mut sy, mut sxx, mut sxy) = (0.0, 0.0, 0.0, 0.0);
        for &(x, y) in &self.samples {
            sx += x;
            sy += y;
            sxx += x * x;
            sxy += x * y;
        }
        let nf = n as f64;
        let denom = nf * sxx - sx * sx;
        if denom.abs() < 1e-9 {
            return Some(last);
        }
        let slope = (nf * sxy - sx * sy) / denom;
        let intercept = (sy - slope * sx) / nf;
        Some((slope * at_ms + intercept).clamp(0.0, 1.0))
    }
}

/// Forecasts per-node load from monitor history.
pub struct LoadPredictor {
    window: usize,
    /// How far ahead to extrapolate, ms.
    pub horizon_ms: f64,
    series: Mutex<HashMap<NodeId, Series>>,
    latest_t: Mutex<f64>,
}

impl LoadPredictor {
    pub fn new(window: usize, horizon_ms: f64) -> LoadPredictor {
        assert!(window >= 1);
        LoadPredictor {
            window,
            horizon_ms,
            series: Mutex::new(HashMap::new()),
            latest_t: Mutex::new(0.0),
        }
    }

    /// Feed one monitor snapshot (call per sample, e.g. from the serving
    /// loop or a dedicated feeder thread).
    pub fn observe(&self, snapshot: &ClusterSnapshot) {
        let mut map = self.series.lock().unwrap();
        for n in &snapshot.nodes {
            map.entry(n.id)
                .or_insert_with(|| Series::new(self.window))
                .push(snapshot.t_ms, n.current_load);
        }
        *self.latest_t.lock().unwrap() = snapshot.t_ms;
    }

    /// Predicted load for `node` at `now + horizon`; None if never seen.
    pub fn predicted_load(&self, node: NodeId) -> Option<f64> {
        let t = *self.latest_t.lock().unwrap() + self.horizon_ms;
        self.series.lock().unwrap().get(&node)?.forecast(t)
    }

    pub fn nodes_tracked(&self) -> usize {
        self.series.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NodeSnapshot;

    fn snap(t_ms: f64, loads: &[(usize, f64)]) -> ClusterSnapshot {
        ClusterSnapshot {
            t_ms,
            nodes: loads
                .iter()
                .map(|&(id, load)| NodeSnapshot {
                    id,
                    name: format!("n{id}"),
                    online: true,
                    cpu_fraction: 1.0,
                    mem_limit_mb: 512.0,
                    current_load: load,
                    mem_used_mb: 0.0,
                    mem_pct: 0.0,
                    rx_bytes: 0,
                    tx_bytes: 0,
                    tasks_completed: 0,
                    tasks_failed: 0,
                    stability: 1.0,
                    link_latency_ms: 1.0,
                })
                .collect(),
        }
    }

    #[test]
    fn unknown_node_is_none() {
        let p = LoadPredictor::new(8, 100.0);
        assert_eq!(p.predicted_load(0), None);
    }

    #[test]
    fn few_samples_fall_back_to_latest() {
        let p = LoadPredictor::new(8, 100.0);
        p.observe(&snap(0.0, &[(0, 0.3)]));
        assert!((p.predicted_load(0).unwrap() - 0.3).abs() < 1e-9);
    }

    #[test]
    fn rising_trend_extrapolates_upward() {
        let p = LoadPredictor::new(8, 200.0);
        for (i, load) in [0.1, 0.2, 0.3, 0.4, 0.5].iter().enumerate() {
            p.observe(&snap(i as f64 * 100.0, &[(0, *load)]));
        }
        // Latest load 0.5 at t=400; slope 0.001/ms; forecast at 600 => 0.7.
        let f = p.predicted_load(0).unwrap();
        assert!((f - 0.7).abs() < 0.02, "forecast {f}");
    }

    #[test]
    fn forecast_clamped_to_unit_interval() {
        let p = LoadPredictor::new(8, 10_000.0);
        for (i, load) in [0.5, 0.7, 0.9].iter().enumerate() {
            p.observe(&snap(i as f64 * 100.0, &[(1, *load)]));
        }
        let f = p.predicted_load(1).unwrap();
        assert!((0.0..=1.0).contains(&f));
    }

    #[test]
    fn flat_series_predicts_flat() {
        let p = LoadPredictor::new(8, 500.0);
        for i in 0..6 {
            p.observe(&snap(i as f64 * 100.0, &[(2, 0.4)]));
        }
        assert!((p.predicted_load(2).unwrap() - 0.4).abs() < 1e-6);
    }

    #[test]
    fn tracks_multiple_nodes() {
        let p = LoadPredictor::new(4, 0.0);
        p.observe(&snap(0.0, &[(0, 0.1), (1, 0.9)]));
        assert_eq!(p.nodes_tracked(), 2);
        assert!(p.predicted_load(1).unwrap() > p.predicted_load(0).unwrap());
    }
}
