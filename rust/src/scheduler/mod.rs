//! Task Scheduler — paper §III-C: the Node Selection Algorithm
//! (Algorithm 1) with the weighted scoring mechanism of Eq. 4–8.
//!
//! ```text
//! TotalScore = 0.2 * S_R + 0.2 * S_L + 0.1 * S_P + 0.5 * S_B     (Eq. 4)
//! S_R = (cpu_avail/cpu_req + mem_avail/mem_req) / 2              (Eq. 5)
//! S_L = 1 - CurrentLoad                                          (Eq. 6)
//! S_P = 1 / (1 + AvgExecTime)                                    (Eq. 7)
//! S_B = 1 / (1 + TaskCount * 2)                                  (Eq. 8)
//! ```
//!
//! Candidates are skipped when overloaded (`current_load > 0.8`), when
//! their link latency exceeds the threshold, or when they lack sufficient
//! resources — exactly Algorithm 1's guard clauses. Sub-scores are clamped
//! to `[0, 1]` (a node with 10x the required memory is "fully sufficient",
//! not 10x better), which keeps the total score in `[0, 1]` — a property
//! the proptests pin down.

pub mod cache;
pub mod history;
pub mod predict;

use std::collections::HashMap;
use std::sync::Arc;
use std::sync::Mutex;

use crate::cluster::{NodeId, VirtualNode};

pub use cache::{CacheStats, ResultCache};
pub use history::PerformanceHistory;
pub use predict::LoadPredictor;

/// Weights of Eq. 4. The paper's experimentally-determined values are the
/// default; the ablation bench sweeps alternatives.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScoringWeights {
    pub resource: f64,
    pub load: f64,
    pub performance: f64,
    pub balance: f64,
}

impl Default for ScoringWeights {
    fn default() -> Self {
        ScoringWeights { resource: 0.2, load: 0.2, performance: 0.1, balance: 0.5 }
    }
}

impl ScoringWeights {
    pub fn validate(&self) -> anyhow::Result<()> {
        let parts = [self.resource, self.load, self.performance, self.balance];
        anyhow::ensure!(
            parts.iter().all(|w| *w >= 0.0),
            "scoring weights must be non-negative"
        );
        let sum: f64 = parts.iter().sum();
        anyhow::ensure!(
            (sum - 1.0).abs() < 1e-6,
            "scoring weights must sum to 1.0, got {sum}"
        );
        Ok(())
    }
}

/// What a task needs from a node (Algorithm 1 "task requirements").
#[derive(Debug, Clone, Copy)]
pub struct TaskRequirements {
    /// CPU share needed, e.g. 0.2 of a core.
    pub cpu: f64,
    /// Memory needed in MB (activations + scratch for the partition).
    pub mem_mb: f64,
    pub priority: u8,
}

impl Default for TaskRequirements {
    fn default() -> Self {
        TaskRequirements { cpu: 0.1, mem_mb: 8.0, priority: 0 }
    }
}

/// Per-candidate score decomposition (reported by the metrics layer).
#[derive(Debug, Clone, Copy)]
pub struct ScoreBreakdown {
    pub resource: f64,
    pub load: f64,
    pub performance: f64,
    pub balance: f64,
    pub total: f64,
}

/// Why a node was skipped (Algorithm 1 guard clauses), for observability.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SkipReason {
    Overloaded,
    HighLatency,
    InsufficientResources,
    Offline,
}

/// The scheduler. Thread-safe; one instance serves the whole cluster.
pub struct Scheduler {
    weights: ScoringWeights,
    /// Algorithm 1 line 4: skip nodes above this load.
    pub overload_threshold: f64,
    /// Algorithm 1 line 7: skip nodes above this link latency (ms).
    pub latency_threshold_ms: f64,
    state: Mutex<SchedState>,
}

struct SchedState {
    history: HashMap<NodeId, PerformanceHistory>,
    active_tasks: HashMap<NodeId, u64>,
    /// Failed executions per node. Kept separate from `history`: a
    /// failure has no meaningful execution time, and feeding a sentinel
    /// (e.g. 1e9 ms) into the window would permanently crater Eq. 7's
    /// S_P for the node.
    failures: HashMap<NodeId, u64>,
    decisions: u64,
    skips: HashMap<&'static str, u64>,
}

/// Snapshot of scheduler bookkeeping for monitoring (§III-C "reports
/// detailed metrics including queue lengths ... task counts, load levels").
#[derive(Debug, Clone)]
pub struct SchedulerReport {
    pub decisions: u64,
    pub active_tasks: Vec<(NodeId, u64)>,
    pub avg_exec_ms: Vec<(NodeId, f64)>,
    pub failures: Vec<(NodeId, u64)>,
    pub skips: Vec<(String, u64)>,
}

impl Scheduler {
    pub fn new(weights: ScoringWeights) -> Scheduler {
        weights.validate().expect("invalid scoring weights");
        Scheduler {
            weights,
            overload_threshold: 0.8,
            latency_threshold_ms: 100.0,
            state: Mutex::new(SchedState {
                history: HashMap::new(),
                active_tasks: HashMap::new(),
                failures: HashMap::new(),
                decisions: 0,
                skips: HashMap::new(),
            }),
        }
    }

    pub fn with_thresholds(mut self, overload: f64, latency_ms: f64) -> Scheduler {
        self.overload_threshold = overload;
        self.latency_threshold_ms = latency_ms;
        self
    }

    pub fn weights(&self) -> ScoringWeights {
        self.weights
    }

    /// Eq. 5, clamped: each sufficiency ratio saturates at 1.
    fn resource_score(&self, node: &VirtualNode, req: &TaskRequirements) -> f64 {
        let cpu_avail = node.spec().cpu_fraction * (1.0 - node.current_load());
        let cpu_ratio = (cpu_avail / req.cpu.max(1e-9)).min(1.0);
        let mem_ratio = (node.mem_available_mb() / req.mem_mb.max(1e-9)).min(1.0);
        (cpu_ratio + mem_ratio) / 2.0
    }

    /// Algorithm 1 line 10.
    fn has_sufficient_resources(
        &self,
        node: &VirtualNode,
        req: &TaskRequirements,
    ) -> bool {
        let cpu_avail = node.spec().cpu_fraction * (1.0 - node.current_load());
        cpu_avail >= req.cpu * 0.5 && node.mem_available_mb() >= req.mem_mb
    }

    /// Score a single candidate (None if a guard clause skips it).
    pub fn score_node(
        &self,
        node: &VirtualNode,
        req: &TaskRequirements,
    ) -> Result<ScoreBreakdown, SkipReason> {
        if !node.is_online() {
            return Err(SkipReason::Offline);
        }
        let load = node.current_load();
        if load > self.overload_threshold {
            return Err(SkipReason::Overloaded);
        }
        if node.spec().link.latency_ms > self.latency_threshold_ms {
            return Err(SkipReason::HighLatency);
        }
        if !self.has_sufficient_resources(node, req) {
            return Err(SkipReason::InsufficientResources);
        }
        let state = self.state.lock().unwrap();
        let perf = state
            .history
            .get(&node.id())
            .map(|h| h.performance_score())
            .unwrap_or(1.0);
        let task_count =
            state.active_tasks.get(&node.id()).copied().unwrap_or(0);
        drop(state);

        let s_r = self.resource_score(node, req).clamp(0.0, 1.0);
        let s_l = (1.0 - load).clamp(0.0, 1.0);
        let s_p = perf.clamp(0.0, 1.0);
        let s_b = 1.0 / (1.0 + task_count as f64 * 2.0);
        let total = self.weights.resource * s_r
            + self.weights.load * s_l
            + self.weights.performance * s_p
            + self.weights.balance * s_b;
        Ok(ScoreBreakdown {
            resource: s_r,
            load: s_l,
            performance: s_p,
            balance: s_b,
            total,
        })
    }

    /// Algorithm 1: pick the best node for a task, or None if every node
    /// is skipped.
    pub fn select_node(
        &self,
        nodes: &[Arc<VirtualNode>],
        req: &TaskRequirements,
    ) -> Option<(Arc<VirtualNode>, ScoreBreakdown)> {
        let mut best: Option<(Arc<VirtualNode>, ScoreBreakdown)> = None;
        for node in nodes {
            match self.score_node(node, req) {
                Ok(score) => {
                    let better = match &best {
                        None => true,
                        Some((_, b)) => score.total > b.total,
                    };
                    if better {
                        best = Some((Arc::clone(node), score));
                    }
                }
                Err(reason) => {
                    let mut state = self.state.lock().unwrap();
                    let key = match reason {
                        SkipReason::Overloaded => "overloaded",
                        SkipReason::HighLatency => "high_latency",
                        SkipReason::InsufficientResources => "insufficient",
                        SkipReason::Offline => "offline",
                    };
                    *state.skips.entry(key).or_insert(0) += 1;
                }
            }
        }
        if best.is_some() {
            self.state.lock().unwrap().decisions += 1;
        }
        best
    }

    /// Scale-out: Algorithm 1 extended from one node to a *replica set*.
    /// Scores every candidate with the Eq. 4 weighted total exactly as
    /// [`Scheduler::select_node`] would, then keeps the top `k` distinct
    /// nodes. Guard clauses (overload / latency / resources / offline)
    /// apply per candidate, so a set is only as large as the nodes that
    /// can actually afford `req` — callers get `result.len() <= k` and
    /// must decide whether a short set is acceptable. Each placed member
    /// counts as one scheduling decision.
    pub fn select_replica_set(
        &self,
        nodes: &[Arc<VirtualNode>],
        req: &TaskRequirements,
        k: usize,
    ) -> Vec<(Arc<VirtualNode>, ScoreBreakdown)> {
        let mut scored: Vec<(Arc<VirtualNode>, ScoreBreakdown)> = Vec::new();
        for node in nodes {
            match self.score_node(node, req) {
                Ok(score) => scored.push((Arc::clone(node), score)),
                Err(reason) => {
                    let mut state = self.state.lock().unwrap();
                    let key = match reason {
                        SkipReason::Overloaded => "overloaded",
                        SkipReason::HighLatency => "high_latency",
                        SkipReason::InsufficientResources => "insufficient",
                        SkipReason::Offline => "offline",
                    };
                    *state.skips.entry(key).or_insert(0) += 1;
                }
            }
        }
        scored.sort_by(|a, b| b.1.total.total_cmp(&a.1.total));
        scored.truncate(k);
        if !scored.is_empty() {
            self.state.lock().unwrap().decisions += scored.len() as u64;
        }
        scored
    }

    /// §V extension: Algorithm 1 with Eq. 6's *current* load replaced by
    /// the predictor's forecast (when available), so ramping nodes shed
    /// new work one period earlier.
    pub fn select_node_predictive(
        &self,
        nodes: &[Arc<VirtualNode>],
        req: &TaskRequirements,
        predictor: &predict::LoadPredictor,
    ) -> Option<(Arc<VirtualNode>, ScoreBreakdown)> {
        let mut best: Option<(Arc<VirtualNode>, ScoreBreakdown)> = None;
        for node in nodes {
            let mut score = match self.score_node(node, req) {
                Ok(s) => s,
                Err(_) => continue,
            };
            if let Some(pred) = predictor.predicted_load(node.id()) {
                if pred > self.overload_threshold {
                    continue; // predicted overload: skip early
                }
                let s_l = (1.0 - pred).clamp(0.0, 1.0);
                score.total += self.weights.load * (s_l - score.load);
                score.load = s_l;
            }
            let better = match &best {
                None => true,
                Some((_, b)) => score.total > b.total,
            };
            if better {
                best = Some((Arc::clone(node), score));
            }
        }
        if best.is_some() {
            self.state.lock().unwrap().decisions += 1;
        }
        best
    }

    /// §V extension: energy-aware selection — among nodes whose total
    /// score is within `tolerance` of the best, pick the one with the
    /// lowest predicted marginal energy for the task. Latency-optimality
    /// is preserved up to the tolerance band; joules drop measurably
    /// (see `benches/ablation.rs`).
    pub fn select_node_energy_aware(
        &self,
        nodes: &[Arc<VirtualNode>],
        req: &TaskRequirements,
        est_ms: f64,
        est_bytes: u64,
        tolerance: f64,
    ) -> Option<(Arc<VirtualNode>, ScoreBreakdown)> {
        let mut scored: Vec<(Arc<VirtualNode>, ScoreBreakdown)> = nodes
            .iter()
            .filter_map(|n| {
                self.score_node(n, req).ok().map(|s| (Arc::clone(n), s))
            })
            .collect();
        if scored.is_empty() {
            return None;
        }
        let best_total = scored
            .iter()
            .map(|(_, s)| s.total)
            .fold(f64::MIN, f64::max);
        scored.retain(|(_, s)| s.total >= best_total - tolerance);
        // Predict each candidate's joules exactly once (the comparator
        // used to re-predict on every comparison — O(n log n) redundant
        // model evaluations) and order with `total_cmp`, which is total
        // over NaN instead of panicking on it.
        let best = scored
            .into_iter()
            .map(|(n, s)| {
                let joules = n.predict_task_joules(est_ms, est_bytes);
                (joules, n, s)
            })
            .min_by(|a, b| a.0.total_cmp(&b.0));
        self.state.lock().unwrap().decisions += 1;
        best.map(|(_, n, s)| (n, s))
    }

    /// Bookkeeping: a task was dispatched to `node`.
    pub fn task_started(&self, node: NodeId) {
        self.tasks_started(std::slice::from_ref(&node));
    }

    /// Bookkeeping for a pipeline batch: every stage node carries it, so
    /// charge them all under one lock. With the persistent engine many
    /// batches are interleaved in flight at once and each charges every
    /// stage node on submit — one lock per batch instead of one per
    /// stage keeps the hot path cheap and the counts atomic with respect
    /// to concurrent submissions.
    pub fn tasks_started(&self, nodes: &[NodeId]) {
        let mut state = self.state.lock().unwrap();
        for node in nodes {
            *state.active_tasks.entry(*node).or_insert(0) += 1;
        }
    }

    /// Bookkeeping: a task finished; feeds the performance history
    /// ("completed tasks are tracked to update execution histories and
    /// recalibrate node loads").
    pub fn task_completed(&self, node: NodeId, exec_ms: f64) {
        let mut state = self.state.lock().unwrap();
        if let Some(c) = state.active_tasks.get_mut(&node) {
            *c = c.saturating_sub(1);
        }
        state
            .history
            .entry(node)
            .or_insert_with(|| PerformanceHistory::new(64))
            .record(exec_ms);
    }

    /// Bookkeeping: a dispatched task failed on `node`. Decrements the
    /// active count like [`Scheduler::task_completed`] but records the
    /// failure in a dedicated counter instead of polluting the
    /// performance history with a sentinel execution time.
    pub fn task_failed(&self, node: NodeId) {
        self.tasks_failed(std::slice::from_ref(&node));
    }

    /// A dispatched batch was *shed* (deadline expired before the
    /// engine admitted it): the nodes never executed anything, so the
    /// started charge is reversed without recording a failure — a shed
    /// is an admission-control decision, not a node fault, and counting
    /// it as one would poison Eq. 7's stability score for healthy
    /// nodes.
    pub fn tasks_cancelled(&self, nodes: &[NodeId]) {
        let mut state = self.state.lock().unwrap();
        for node in nodes {
            if let Some(c) = state.active_tasks.get_mut(node) {
                *c = c.saturating_sub(1);
            }
        }
    }

    /// Batch failure: release and count every stage node at once (the
    /// multi-node counterpart of [`Scheduler::task_failed`]).
    pub fn tasks_failed(&self, nodes: &[NodeId]) {
        let mut state = self.state.lock().unwrap();
        for node in nodes {
            if let Some(c) = state.active_tasks.get_mut(node) {
                *c = c.saturating_sub(1);
            }
            *state.failures.entry(*node).or_insert(0) += 1;
        }
    }

    pub fn failures(&self, node: NodeId) -> u64 {
        self.state
            .lock()
            .unwrap()
            .failures
            .get(&node)
            .copied()
            .unwrap_or(0)
    }

    pub fn active_tasks(&self, node: NodeId) -> u64 {
        self.state
            .lock()
            .unwrap()
            .active_tasks
            .get(&node)
            .copied()
            .unwrap_or(0)
    }

    pub fn report(&self) -> SchedulerReport {
        let state = self.state.lock().unwrap();
        SchedulerReport {
            decisions: state.decisions,
            active_tasks: state
                .active_tasks
                .iter()
                .map(|(k, v)| (*k, *v))
                .collect(),
            avg_exec_ms: state
                .history
                .iter()
                .map(|(k, h)| (*k, h.avg_exec_ms()))
                .collect(),
            failures: state
                .failures
                .iter()
                .map(|(k, v)| (*k, *v))
                .collect(),
            skips: state
                .skips
                .iter()
                .map(|(k, v)| (k.to_string(), *v))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{NodeSpec, SimParams};
    use crate::util::check::forall;

    fn mk_node(id: usize, cpu: f64, mem: f64) -> Arc<VirtualNode> {
        let params = SimParams {
            time_scale: 1.0,
            page_factor: 4.0,
            runtime_overhead_mb: 0.0,
        };
        Arc::new(VirtualNode::new(id, NodeSpec::new(&format!("n{id}"), cpu, mem), params))
    }

    fn req() -> TaskRequirements {
        TaskRequirements { cpu: 0.1, mem_mb: 10.0, priority: 0 }
    }

    #[test]
    fn default_weights_are_papers() {
        let w = ScoringWeights::default();
        assert_eq!((w.resource, w.load, w.performance, w.balance),
                   (0.2, 0.2, 0.1, 0.5));
        w.validate().unwrap();
    }

    #[test]
    fn invalid_weights_rejected() {
        assert!(ScoringWeights { resource: 0.5, load: 0.5, performance: 0.5, balance: 0.5 }
            .validate()
            .is_err());
        assert!(ScoringWeights { resource: -0.2, load: 0.6, performance: 0.1, balance: 0.5 }
            .validate()
            .is_err());
    }

    #[test]
    fn selects_idle_capable_node() {
        let sched = Scheduler::new(ScoringWeights::default());
        let nodes = vec![mk_node(0, 1.0, 1024.0), mk_node(1, 0.4, 512.0)];
        let (node, score) = sched.select_node(&nodes, &req()).unwrap();
        assert!(score.total > 0.0 && score.total <= 1.0);
        // Both idle; equal balance/load/perf; bigger node wins on S_R tie
        // or the first max is kept — either way a node is returned.
        assert!(node.id() == 0 || node.id() == 1);
    }

    #[test]
    fn skips_offline_nodes() {
        let sched = Scheduler::new(ScoringWeights::default());
        let nodes = vec![mk_node(0, 1.0, 1024.0)];
        nodes[0].set_online(false);
        assert!(sched.select_node(&nodes, &req()).is_none());
        let report = sched.report();
        assert_eq!(report.skips, vec![("offline".to_string(), 1)]);
    }

    #[test]
    fn skips_high_latency_nodes() {
        let sched = Scheduler::new(ScoringWeights::default())
            .with_thresholds(0.8, 5.0);
        let spec = NodeSpec::new("far", 1.0, 1024.0)
            .with_link(crate::cluster::LinkSpec::new(50.0, 1000.0));
        let far = Arc::new(VirtualNode::new(7, spec, SimParams::default()));
        assert_eq!(sched.score_node(&far, &req()).unwrap_err(),
                   SkipReason::HighLatency);
    }

    #[test]
    fn skips_insufficient_memory() {
        let sched = Scheduler::new(ScoringWeights::default());
        let tiny = mk_node(2, 1.0, 4.0);
        let r = TaskRequirements { cpu: 0.1, mem_mb: 100.0, priority: 0 };
        assert_eq!(sched.score_node(&tiny, &r).unwrap_err(),
                   SkipReason::InsufficientResources);
    }

    #[test]
    fn balance_score_prefers_less_busy_node() {
        let sched = Scheduler::new(ScoringWeights::default());
        let nodes = vec![mk_node(0, 1.0, 1024.0), mk_node(1, 1.0, 1024.0)];
        // Node 0 has 3 active tasks.
        for _ in 0..3 {
            sched.task_started(0);
        }
        let (selected, _) = sched.select_node(&nodes, &req()).unwrap();
        assert_eq!(selected.id(), 1);
    }

    #[test]
    fn eq8_balance_values() {
        let sched = Scheduler::new(ScoringWeights::default());
        let n = mk_node(0, 1.0, 1024.0);
        sched.task_started(0);
        let s = sched.score_node(&n, &req()).unwrap();
        assert!((s.balance - 1.0 / 3.0).abs() < 1e-9); // 1/(1+1*2)
        sched.task_started(0);
        let s = sched.score_node(&n, &req()).unwrap();
        assert!((s.balance - 0.2).abs() < 1e-9); // 1/(1+2*2)
    }

    #[test]
    fn history_shifts_selection_to_faster_node() {
        let w = ScoringWeights { resource: 0.1, load: 0.1, performance: 0.7, balance: 0.1 };
        let sched = Scheduler::new(w);
        let nodes = vec![mk_node(0, 1.0, 1024.0), mk_node(1, 1.0, 1024.0)];
        sched.task_completed(0, 5000.0); // node 0 slow historically
        sched.task_completed(1, 10.0);
        let (selected, _) = sched.select_node(&nodes, &req()).unwrap();
        assert_eq!(selected.id(), 1);
    }

    #[test]
    fn task_accounting_balances() {
        let sched = Scheduler::new(ScoringWeights::default());
        sched.task_started(3);
        sched.task_started(3);
        assert_eq!(sched.active_tasks(3), 2);
        sched.task_completed(3, 12.0);
        assert_eq!(sched.active_tasks(3), 1);
        sched.task_completed(3, 14.0);
        assert_eq!(sched.active_tasks(3), 0);
        // completing more than started must not underflow
        sched.task_completed(3, 1.0);
        assert_eq!(sched.active_tasks(3), 0);
    }

    #[test]
    fn multi_stage_accounting_charges_every_node() {
        // A 3-stage pipeline batch must charge all three stage nodes —
        // the seed charged only stage 0, so Eq. 8's balance score saw
        // stages 2..N as permanently idle.
        let sched = Scheduler::new(ScoringWeights::default());
        for node in [0, 1, 2] {
            sched.task_started(node);
        }
        for node in [0, 1, 2] {
            assert_eq!(sched.active_tasks(node), 1);
        }
        for (node, ms) in [(0usize, 12.0), (1, 20.0), (2, 30.0)] {
            sched.task_completed(node, ms);
        }
        for node in [0, 1, 2] {
            assert_eq!(sched.active_tasks(node), 0);
        }
        let report = sched.report();
        assert_eq!(report.avg_exec_ms.len(), 3);
        assert!(report
            .avg_exec_ms
            .iter()
            .any(|(n, ms)| *n == 2 && (*ms - 30.0).abs() < 1e-9));
    }

    #[test]
    fn bulk_charging_matches_per_node_calls() {
        // Interleaved persistent-engine batches charge all stage nodes
        // per submit; the bulk APIs must agree with N single calls.
        let sched = Scheduler::new(ScoringWeights::default());
        let nodes = [0usize, 1, 2];
        sched.tasks_started(&nodes);
        sched.tasks_started(&nodes); // two batches in flight
        for n in nodes {
            assert_eq!(sched.active_tasks(n), 2);
        }
        sched.tasks_failed(&nodes); // one batch fails on every stage
        for n in nodes {
            assert_eq!(sched.active_tasks(n), 1);
            assert_eq!(sched.failures(n), 1);
        }
        for n in nodes {
            sched.task_completed(n, 10.0);
            assert_eq!(sched.active_tasks(n), 0);
        }
    }

    #[test]
    fn failures_do_not_poison_performance_history() {
        let sched = Scheduler::new(ScoringWeights::default());
        let node = mk_node(0, 1.0, 1024.0);
        sched.task_started(0);
        sched.task_failed(0);
        assert_eq!(sched.active_tasks(0), 0);
        assert_eq!(sched.failures(0), 1);
        // S_P stays optimistic: no sentinel exec time was recorded.
        let s = sched.score_node(&node, &req()).unwrap();
        assert!((s.performance - 1.0).abs() < 1e-9,
                "failure must not crater S_P, got {}", s.performance);
        // A real completion afterwards is the only thing feeding Eq. 7.
        sched.task_started(0);
        sched.task_completed(0, 1000.0);
        let s = sched.score_node(&node, &req()).unwrap();
        assert!((s.performance - 0.5).abs() < 1e-9);
        let report = sched.report();
        assert_eq!(report.failures, vec![(0, 1)]);
        // Failure accounting never underflows.
        sched.task_failed(0);
        assert_eq!(sched.active_tasks(0), 0);
        assert_eq!(sched.failures(0), 2);
    }

    #[test]
    fn energy_aware_survives_nan_predictions() {
        use crate::cluster::PowerModel;
        let sched = Scheduler::new(ScoringWeights::default());
        let params = SimParams {
            time_scale: 1.0,
            page_factor: 4.0,
            runtime_overhead_mb: 0.0,
        };
        // A corrupt power model predicting NaN joules used to panic the
        // sort comparator; total_cmp orders NaN last instead.
        let broken = Arc::new(VirtualNode::new(
            0,
            NodeSpec::new("broken", 1.0, 1024.0).with_power(PowerModel {
                idle_watts: f64::NAN,
                busy_watts: f64::NAN,
                net_joules_per_byte: 0.0,
            }),
            params.clone(),
        ));
        let sane = Arc::new(VirtualNode::new(
            1,
            NodeSpec::new("sane", 1.0, 1024.0),
            params,
        ));
        let (sel, _) = sched
            .select_node_energy_aware(&[broken, sane], &req(), 50.0, 100, 1.0)
            .unwrap();
        assert_eq!(sel.id(), 1, "NaN-predicting node must lose, not panic");
    }

    #[test]
    fn predictive_selection_avoids_ramping_node() {
        use crate::monitor::ClusterSnapshot;
        let sched = Scheduler::new(ScoringWeights::default());
        let nodes = vec![mk_node(0, 1.0, 1024.0), mk_node(1, 1.0, 1024.0)];
        let predictor = predict::LoadPredictor::new(8, 500.0);
        // Node 0's load ramps hard; node 1 stays flat.
        for i in 0..5 {
            let mut snap_nodes = vec![nodes[0].snapshot(), nodes[1].snapshot()];
            snap_nodes[0].current_load = 0.15 * i as f64;
            snap_nodes[1].current_load = 0.1;
            predictor.observe(&ClusterSnapshot {
                t_ms: i as f64 * 100.0,
                nodes: snap_nodes,
            });
        }
        let (sel, _) = sched
            .select_node_predictive(&nodes, &req(), &predictor)
            .unwrap();
        assert_eq!(sel.id(), 1);
    }

    #[test]
    fn energy_aware_prefers_low_power_within_band() {
        use crate::cluster::PowerModel;
        let sched = Scheduler::new(ScoringWeights::default());
        let params = SimParams {
            time_scale: 1.0,
            page_factor: 4.0,
            runtime_overhead_mb: 0.0,
        };
        let hungry = Arc::new(VirtualNode::new(
            0,
            NodeSpec::new("hungry", 1.0, 1024.0).with_power(PowerModel {
                idle_watts: 3.0,
                busy_watts: 15.0,
                net_joules_per_byte: 0.0,
            }),
            params.clone(),
        ));
        let frugal = Arc::new(VirtualNode::new(
            1,
            NodeSpec::new("frugal", 1.0, 1024.0).with_power(PowerModel {
                idle_watts: 2.0,
                busy_watts: 4.0,
                net_joules_per_byte: 0.0,
            }),
            params,
        ));
        let (sel, _) = sched
            .select_node_energy_aware(
                &[hungry, frugal],
                &req(),
                100.0,
                1000,
                0.2,
            )
            .unwrap();
        assert_eq!(sel.id(), 1);
    }

    #[test]
    fn replica_set_takes_top_k_and_respects_guards() {
        let sched = Scheduler::new(ScoringWeights::default());
        let nodes = vec![
            mk_node(0, 1.0, 1024.0),
            mk_node(1, 1.0, 1024.0),
            mk_node(2, 1.0, 1024.0),
        ];
        // Node 1 is busy: Eq. 8 pushes it below the idle nodes.
        for _ in 0..3 {
            sched.task_started(1);
        }
        nodes[2].set_online(false);
        let set = sched.select_replica_set(&nodes, &req(), 2);
        // Offline node excluded, so only two candidates survive; the
        // idle node must outrank the busy one.
        assert_eq!(set.len(), 2);
        assert_eq!(set[0].0.id(), 0);
        assert_eq!(set[1].0.id(), 1);
        assert!(set[0].1.total >= set[1].1.total);
        // Asking for more replicas than placeable nodes shortens the set.
        let set = sched.select_replica_set(&nodes, &req(), 5);
        assert_eq!(set.len(), 2);
    }

    #[test]
    fn replica_set_of_one_matches_select_node() {
        // k=1 degeneracy: the set head is exactly Algorithm 1's pick.
        let sched = Scheduler::new(ScoringWeights::default());
        let nodes = vec![mk_node(0, 1.0, 1024.0), mk_node(1, 0.4, 256.0)];
        sched.task_started(1);
        let (single, s1) = sched.select_node(&nodes, &req()).unwrap();
        let set = sched.select_replica_set(&nodes, &req(), 1);
        assert_eq!(set.len(), 1);
        assert_eq!(set[0].0.id(), single.id());
        assert!((set[0].1.total - s1.total).abs() < 1e-12);
    }

    #[test]
    fn property_scores_bounded() {
        forall(100, 0x5C0, |rng| {
            let sched = Scheduler::new(ScoringWeights::default());
            let n = mk_node(rng.below(10), 0.1 + rng.f64(), 16.0 + rng.f64() * 2048.0);
            for _ in 0..rng.below(5) {
                sched.task_started(n.id());
            }
            for _ in 0..rng.below(5) {
                sched.task_completed(n.id(), rng.f64() * 3000.0);
            }
            let r = TaskRequirements {
                cpu: 0.01 + rng.f64() * 0.5,
                mem_mb: 1.0 + rng.f64() * 64.0,
                priority: 0,
            };
            if let Ok(s) = sched.score_node(&n, &r) {
                for v in [s.resource, s.load, s.performance, s.balance, s.total] {
                    assert!((0.0..=1.0).contains(&v), "score {v} out of [0,1]");
                }
            }
        });
    }

    #[test]
    fn property_never_selects_offline_or_overloaded() {
        forall(60, 0xDEAD, |rng| {
            let sched = Scheduler::new(ScoringWeights::default());
            let nodes: Vec<_> = (0..rng.range(1, 5))
                .map(|i| {
                    let n = mk_node(i, 1.0, 1024.0);
                    if rng.chance(0.4) {
                        n.set_online(false);
                    }
                    n
                })
                .collect();
            if let Some((sel, _)) = sched.select_node(&nodes, &req()) {
                assert!(sel.is_online());
            } else {
                assert!(nodes.iter().all(|n| !n.is_online()));
            }
        });
    }
}
