//! Seeded, deterministic fault injection for the wire transport.
//!
//! Two entry points, both driven by a [`FaultPlan`] — a per-direction
//! byte-offset schedule of faults derived from a seed:
//!
//! * [`ChaosStream`] wraps any `Read + Write` byte stream (a
//!   [`WireStream`], an in-memory buffer) and applies the plan inline:
//!   adversarial read/write fragmentation, injected delays, a one-shot
//!   stall, single-bit corruption at scheduled byte offsets, and a
//!   scheduled disconnect (every later op fails with
//!   `ConnectionReset`). Unit tests drive the frame codec through it
//!   directly.
//! * [`ChaosProxy`] is a real man-in-the-middle for two-process runs: it
//!   listens on its own UDS/TCP address, forwards each accepted
//!   connection to an upstream agent, and runs each direction's bytes
//!   through its own `FaultPlan`. Point a coordinator at the proxy
//!   instead of the agent and the whole stack — codec, reader threads,
//!   execute deadlines, heal ladder — sees gray failures on a
//!   reproducible schedule.
//!
//! Faults are scheduled by *byte offset* in the direction's stream, not
//! by wall clock, so a given (seed, schedule) corrupts the same byte of
//! the same frame on every run. Delay/stall sleeps are interruptible by
//! the proxy's stop flag so teardown never waits out a stall.

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::TcpListener;
use std::os::unix::net::UnixListener;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::rng::Rng;

use super::{AgentAddr, WireStream};

/// One direction's seeded fault schedule. Build with [`FaultPlan::clean`]
/// and layer faults on with the `with_*` builders; a clean plan passes
/// bytes through untouched (and unfragmented), so the degenerate proxy
/// is a plain relay.
#[derive(Debug)]
pub struct FaultPlan {
    rng: Rng,
    /// Per-op probability of an injected delay.
    delay_chance: f64,
    delay_ms: (f64, f64),
    /// Max bytes one op may move (0 = unlimited). Each op draws a fresh
    /// size in `1..=max`, modelling adversarial short reads/writes.
    max_chunk: usize,
    /// Byte offsets to corrupt (one random bit each), ascending.
    corrupt_at: Vec<u64>,
    corrupt_i: usize,
    /// One-shot stall: when the stream reaches this offset, sleep.
    stall_at: Option<u64>,
    stall_ms: u64,
    stalled: bool,
    /// Sever the direction once this offset is reached.
    disconnect_at: Option<u64>,
    severed: bool,
    pos: u64,
    /// Early-out for sleeps (set by the proxy's stop flag).
    abort: Option<Arc<AtomicBool>>,
}

impl FaultPlan {
    /// A no-fault plan: bytes pass through verbatim in full-size ops.
    pub fn clean(seed: u64) -> FaultPlan {
        FaultPlan {
            rng: Rng::new(seed),
            delay_chance: 0.0,
            delay_ms: (0.0, 0.0),
            max_chunk: 0,
            corrupt_at: Vec::new(),
            corrupt_i: 0,
            stall_at: None,
            stall_ms: 0,
            stalled: false,
            disconnect_at: None,
            severed: false,
            pos: 0,
            abort: None,
        }
    }

    /// Inject a `lo_ms..hi_ms` sleep before an op with probability
    /// `chance`.
    pub fn with_delays(mut self, chance: f64, lo_ms: f64, hi_ms: f64) -> FaultPlan {
        self.delay_chance = chance;
        self.delay_ms = (lo_ms, hi_ms);
        self
    }

    /// Fragment the stream: each op moves at most a fresh `1..=max`
    /// bytes.
    pub fn with_fragmentation(mut self, max: usize) -> FaultPlan {
        self.max_chunk = max;
        self
    }

    /// Flip one random bit in the byte at each listed stream offset.
    pub fn with_corruption_at(mut self, mut offsets: Vec<u64>) -> FaultPlan {
        offsets.sort_unstable();
        self.corrupt_at = offsets;
        self
    }

    /// Sleep `ms` once, when the stream reaches `offset` — a
    /// stalled-but-connected link.
    pub fn with_stall_at(mut self, offset: u64, ms: u64) -> FaultPlan {
        self.stall_at = Some(offset);
        self.stall_ms = ms;
        self
    }

    /// Sever the direction once `offset` bytes have passed.
    pub fn with_disconnect_at(mut self, offset: u64) -> FaultPlan {
        self.disconnect_at = Some(offset);
        self
    }

    fn with_abort(mut self, abort: Arc<AtomicBool>) -> FaultPlan {
        self.abort = Some(abort);
        self
    }

    /// Gate one I/O op that wants to move up to `len` bytes: runs
    /// scheduled delays/stalls, severs at the disconnect offset, and
    /// returns how many bytes the op may move.
    pub fn admit(&mut self, len: usize) -> io::Result<usize> {
        if len == 0 {
            return Ok(0);
        }
        if self.severed {
            return Err(io::ErrorKind::ConnectionReset.into());
        }
        if let Some(at) = self.disconnect_at {
            if self.pos >= at {
                self.severed = true;
                return Err(io::ErrorKind::ConnectionReset.into());
            }
        }
        if let Some(at) = self.stall_at {
            if !self.stalled && self.pos >= at {
                self.stalled = true;
                let ms = self.stall_ms;
                self.sleep_ms(ms as f64);
            }
        }
        if self.delay_chance > 0.0 && self.rng.chance(self.delay_chance) {
            let (lo, hi) = self.delay_ms;
            let ms = lo + (hi - lo) * self.rng.f64();
            self.sleep_ms(ms);
        }
        let mut cap = len;
        if self.max_chunk > 0 {
            cap = cap.min(self.rng.range(1, self.max_chunk));
        }
        if let Some(at) = self.disconnect_at {
            // Never move bytes past the scheduled cut (at > pos here).
            cap = cap.min((at - self.pos) as usize);
        }
        Ok(cap.max(1).min(len))
    }

    /// Account `chunk` as moved: applies scheduled bit corruption in
    /// place and advances the stream offset.
    pub fn commit(&mut self, chunk: &mut [u8]) {
        let start = self.pos;
        let end = start + chunk.len() as u64;
        while self.corrupt_i < self.corrupt_at.len() {
            let at = self.corrupt_at[self.corrupt_i];
            if at < start {
                self.corrupt_i += 1;
                continue;
            }
            if at >= end {
                break;
            }
            let bit = (self.rng.next_u64() % 8) as u8;
            chunk[(at - start) as usize] ^= 1u8 << bit;
            self.corrupt_i += 1;
        }
        self.pos = end;
    }

    /// Bytes moved through this direction so far.
    pub fn offset(&self) -> u64 {
        self.pos
    }

    /// Interruptible sleep: 10 ms slices, early-out on the abort flag.
    fn sleep_ms(&self, ms: f64) {
        let deadline =
            std::time::Instant::now() + Duration::from_secs_f64(ms.max(0.0) / 1000.0);
        loop {
            if let Some(abort) = &self.abort {
                if abort.load(Ordering::SeqCst) {
                    return;
                }
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return;
            }
            std::thread::sleep((deadline - now).min(Duration::from_millis(10)));
        }
    }
}

/// A byte stream with a [`FaultPlan`] on each direction.
pub struct ChaosStream<S> {
    inner: S,
    read_plan: FaultPlan,
    write_plan: FaultPlan,
}

impl<S> ChaosStream<S> {
    pub fn new(inner: S, read_plan: FaultPlan, write_plan: FaultPlan) -> ChaosStream<S> {
        ChaosStream { inner, read_plan, write_plan }
    }

    pub fn into_inner(self) -> S {
        self.inner
    }
}

impl<S: Read> Read for ChaosStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let cap = self.read_plan.admit(buf.len())?;
        if cap == 0 {
            return Ok(0);
        }
        let n = self.inner.read(&mut buf[..cap])?;
        self.read_plan.commit(&mut buf[..n]);
        Ok(n)
    }
}

impl<S: Write> Write for ChaosStream<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let cap = self.write_plan.admit(buf.len())?;
        if cap == 0 {
            return Ok(0);
        }
        // Corruption must hit the wire, so mutate a scratch copy and
        // push all of it; reporting `cap` keeps the caller's view of
        // progress consistent with the plan's offset accounting.
        let mut scratch = buf[..cap].to_vec();
        self.write_plan.commit(&mut scratch);
        self.inner.write_all(&scratch)?;
        Ok(cap)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

/// The fault plans for one proxied connection: one per direction.
#[derive(Debug)]
pub struct ConnPlans {
    /// Applied to coordinator -> agent bytes.
    pub to_upstream: FaultPlan,
    /// Applied to agent -> coordinator bytes.
    pub to_client: FaultPlan,
}

impl ConnPlans {
    /// A plain relay for this connection.
    pub fn clean(seed: u64) -> ConnPlans {
        ConnPlans {
            to_upstream: FaultPlan::clean(seed),
            to_client: FaultPlan::clean(seed.wrapping_add(1)),
        }
    }
}

enum ProxyListener {
    Unix(UnixListener),
    Tcp(TcpListener),
}

impl ProxyListener {
    fn accept(&self) -> io::Result<WireStream> {
        match self {
            ProxyListener::Unix(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                Ok(WireStream::Unix(s))
            }
            ProxyListener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                let _ = s.set_nodelay(true);
                Ok(WireStream::Tcp(s))
            }
        }
    }
}

/// A chaos man-in-the-middle: accepts coordinator connections on its
/// own address and relays each to `upstream`, running every byte
/// through the connection's [`ConnPlans`]. The nth accepted connection
/// consumes `plans[n]`; connections beyond the supplied list relay
/// cleanly. Dropping (or [`ChaosProxy::stop`]ping) the proxy severs all
/// relayed connections and joins its threads — stalls never outlive
/// the proxy.
pub struct ChaosProxy {
    addr: AgentAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    pumps: Arc<Mutex<Vec<JoinHandle<()>>>>,
    conns: Arc<Mutex<Vec<WireStream>>>,
    uds_path: Option<PathBuf>,
}

impl ChaosProxy {
    /// Listen on a Unix socket at `path` (replacing any stale file).
    pub fn start_uds(
        path: impl AsRef<Path>,
        upstream: AgentAddr,
        plans: Vec<ConnPlans>,
    ) -> Result<ChaosProxy> {
        let path = path.as_ref().to_path_buf();
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path)
            .with_context(|| format!("binding chaos proxy at uds:{}", path.display()))?;
        listener.set_nonblocking(true)?;
        ChaosProxy::spawn(
            ProxyListener::Unix(listener),
            AgentAddr::Uds(path.clone()),
            Some(path),
            upstream,
            plans,
        )
    }

    /// Listen on a TCP address; `host:0` picks a free port (see
    /// [`ChaosProxy::addr`] for the bound address).
    pub fn start_tcp(
        listen: &str,
        upstream: AgentAddr,
        plans: Vec<ConnPlans>,
    ) -> Result<ChaosProxy> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("binding chaos proxy at tcp:{listen}"))?;
        let bound = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        ChaosProxy::spawn(
            ProxyListener::Tcp(listener),
            AgentAddr::Tcp(bound.to_string()),
            None,
            upstream,
            plans,
        )
    }

    fn spawn(
        listener: ProxyListener,
        addr: AgentAddr,
        uds_path: Option<PathBuf>,
        upstream: AgentAddr,
        plans: Vec<ConnPlans>,
    ) -> Result<ChaosProxy> {
        let stop = Arc::new(AtomicBool::new(false));
        let pumps: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let conns: Arc<Mutex<Vec<WireStream>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let stop = Arc::clone(&stop);
            let pumps = Arc::clone(&pumps);
            let conns = Arc::clone(&conns);
            let mut queue: VecDeque<ConnPlans> = plans.into();
            let mut accepted = 0u64;
            std::thread::Builder::new()
                .name("amp4ec-chaos-accept".to_string())
                .spawn(move || loop {
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    match listener.accept() {
                        Ok(client) => {
                            accepted += 1;
                            let plan = queue
                                .pop_front()
                                .unwrap_or_else(|| ConnPlans::clean(accepted));
                            if let Err(e) = relay(
                                client,
                                &upstream,
                                plan,
                                &stop,
                                &pumps,
                                &conns,
                            ) {
                                crate::log_warn!(
                                    "chaos",
                                    "relay to {upstream} failed: {e:#}"
                                );
                            }
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(5));
                        }
                        Err(_) => {
                            if stop.load(Ordering::SeqCst) {
                                break;
                            }
                            std::thread::sleep(Duration::from_millis(5));
                        }
                    }
                })
                .context("spawning chaos proxy accept thread")?
        };
        Ok(ChaosProxy {
            addr,
            stop,
            accept: Some(accept),
            pumps,
            conns,
            uds_path,
        })
    }

    /// Where the proxy listens — hand this to the coordinator in place
    /// of the agent's own address.
    pub fn addr(&self) -> &AgentAddr {
        &self.addr
    }

    /// Sever every relayed connection and join all proxy threads.
    pub fn stop(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for conn in self.conns.lock().unwrap().iter() {
            conn.shutdown();
        }
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
        let pumps = std::mem::take(&mut *self.pumps.lock().unwrap());
        for t in pumps {
            let _ = t.join();
        }
        if let Some(path) = self.uds_path.take() {
            let _ = std::fs::remove_file(path);
        }
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.stop_inner();
        }
    }
}

/// Wire one accepted client to the upstream agent: two pump threads,
/// one per direction, each with its own plan.
fn relay(
    client: WireStream,
    upstream: &AgentAddr,
    plan: ConnPlans,
    stop: &Arc<AtomicBool>,
    pumps: &Mutex<Vec<JoinHandle<()>>>,
    conns: &Mutex<Vec<WireStream>>,
) -> Result<()> {
    let agent = upstream.connect_retry(Duration::from_secs(5))?;
    // Short read timeouts keep pumps responsive to the stop flag.
    let _ = client.set_read_timeout(Some(Duration::from_millis(50)));
    let _ = agent.set_read_timeout(Some(Duration::from_millis(50)));
    let c2 = client.try_clone().context("cloning client stream")?;
    let a2 = agent.try_clone().context("cloning agent stream")?;
    {
        let mut held = conns.lock().unwrap();
        held.push(client.try_clone().context("cloning client stream")?);
        held.push(agent.try_clone().context("cloning agent stream")?);
    }
    let mut held = pumps.lock().unwrap();
    let up_plan = plan.to_upstream.with_abort(Arc::clone(stop));
    let down_plan = plan.to_client.with_abort(Arc::clone(stop));
    let up_stop = Arc::clone(stop);
    let down_stop = Arc::clone(stop);
    held.push(
        std::thread::Builder::new()
            .name("amp4ec-chaos-up".to_string())
            .spawn(move || pump(client, a2, up_plan, up_stop))
            .context("spawning chaos pump")?,
    );
    held.push(
        std::thread::Builder::new()
            .name("amp4ec-chaos-down".to_string())
            .spawn(move || pump(agent, c2, down_plan, down_stop))
            .context("spawning chaos pump")?,
    );
    Ok(())
}

/// Forward bytes `from -> to` through `plan` until EOF, a scheduled
/// disconnect, a socket error, or the stop flag. Exiting severs both
/// streams so the peer direction (and the real endpoints) observe the
/// failure instead of hanging.
fn pump(
    mut from: WireStream,
    mut to: WireStream,
    mut plan: FaultPlan,
    stop: Arc<AtomicBool>,
) {
    let mut buf = vec![0u8; 16 * 1024];
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let cap = match plan.admit(buf.len()) {
            Ok(c) => c,
            Err(_) => break,
        };
        let n = match from.read(&mut buf[..cap]) {
            Ok(0) => break,
            Ok(n) => n,
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => break,
        };
        plan.commit(&mut buf[..n]);
        if to.write_all(&buf[..n]).is_err() {
            break;
        }
        let _ = to.flush();
    }
    from.shutdown();
    to.shutdown();
}

#[cfg(test)]
mod tests {
    use super::super::frame::{self, Frame};
    use super::*;
    use crate::runtime::Tensor;

    fn tensor() -> Tensor {
        Tensor::new(vec![4, 8], (0..32).map(|i| i as f32 * 0.5 - 3.0).collect())
            .unwrap()
    }

    #[test]
    fn clean_plan_is_transparent() {
        let mut buf = Vec::new();
        let mut w = ChaosStream::new(
            &mut buf,
            FaultPlan::clean(1),
            FaultPlan::clean(2),
        );
        frame::write_frame(&mut w, &Frame::Execute { seq: 5, tensor: tensor() })
            .unwrap();
        let mut clean = Vec::new();
        frame::write_frame(&mut clean, &Frame::Execute { seq: 5, tensor: tensor() })
            .unwrap();
        assert_eq!(buf, clean);
    }

    #[test]
    fn fragmentation_and_delays_preserve_bits() {
        let t = tensor();
        let mut wire = Vec::new();
        let mut w = ChaosStream::new(
            &mut wire,
            FaultPlan::clean(0),
            FaultPlan::clean(7).with_fragmentation(5),
        );
        frame::write_frame(&mut w, &Frame::ExecuteOk {
            seq: 9,
            compute_ms: 1.25,
            tensor: t.clone(),
        })
        .unwrap();
        let mut r = ChaosStream::new(
            wire.as_slice(),
            FaultPlan::clean(11).with_fragmentation(3).with_delays(0.2, 0.0, 0.2),
            FaultPlan::clean(0),
        );
        match frame::read_frame(&mut r).unwrap() {
            Frame::ExecuteOk { seq, compute_ms, tensor: back } => {
                assert_eq!(seq, 9);
                assert_eq!(compute_ms, 1.25);
                assert_eq!(back.shape, t.shape);
                for (x, y) in back.data().iter().zip(t.data()) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            f => panic!("got {f:?}"),
        }
    }

    #[test]
    fn scheduled_corruption_is_caught_by_crc() {
        let mut wire = Vec::new();
        frame::write_frame(&mut wire, &Frame::Execute { seq: 3, tensor: tensor() })
            .unwrap();
        // Corrupt a byte inside the tensor payload (past the 9-byte
        // header), under fragmentation, and decode: must error cleanly.
        let mut r = ChaosStream::new(
            wire.as_slice(),
            FaultPlan::clean(21)
                .with_fragmentation(7)
                .with_corruption_at(vec![wire.len() as u64 - 5]),
            FaultPlan::clean(0),
        );
        let err = frame::read_frame(&mut r);
        assert!(err.is_err(), "corrupted frame decoded: {err:?}");
    }

    #[test]
    fn scheduled_disconnect_severs_mid_frame() {
        let mut wire = Vec::new();
        frame::write_frame(&mut wire, &Frame::Execute { seq: 4, tensor: tensor() })
            .unwrap();
        let mut r = ChaosStream::new(
            wire.as_slice(),
            FaultPlan::clean(31).with_disconnect_at(wire.len() as u64 / 2),
            FaultPlan::clean(0),
        );
        assert!(frame::read_frame(&mut r).is_err());
        // Every later op keeps failing.
        let mut byte = [0u8; 1];
        assert!(r.read(&mut byte).is_err());
    }
}
