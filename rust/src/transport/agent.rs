//! Node agent: the remote end of the wire transport.
//!
//! An agent listens on one Unix domain socket or TCP address and serves
//! any number of coordinator connections. Each connection hosts exactly
//! one deployed stage (the coordinator opens one connection per stage,
//! so a single agent can host several stages concurrently) and runs a
//! simple request loop: `Hello` → `DeploySim`/`DeployBlocks` → a stream
//! of `Execute` frames answered with `ExecuteOk`/`ExecuteErr`.
//!
//! Lifecycle: a stage-level failure answers `ExecuteErr` and keeps the
//! connection (the engine retries nothing — it fails that batch and
//! keeps feeding); a protocol violation or socket error drops the
//! connection. With [`AgentHandle::exit_when_idle`] set (the `amp4ec
//! node` default) the agent exits once it has served at least one
//! connection and the last one closes — i.e. when the coordinator goes
//! away, the agent goes away.

use std::io::{Read, Write as _};
use std::net::TcpListener;
use std::os::unix::net::UnixListener;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cluster::VirtualNode;
use crate::manifest::Manifest;
use crate::runtime::{Executor, Tensor};
use crate::util::pool::BufferPool;

use super::frame::{
    self, BlockStageSpec, Frame, SimStageSpec, WIRE_VERSION,
};
use super::{AgentAddr, WireStream};

/// One stage a connection is hosting.
enum HostedStage {
    /// Synthetic stage: the exact `SimStages` transform on a locally
    /// rebuilt virtual node — bit-identical outputs and identical
    /// simulated milliseconds to the in-process chain.
    Sim { node: VirtualNode, nominal_ms: f64 },
    /// Real block range loaded from the agent-local artifacts dir.
    Blocks {
        node: VirtualNode,
        executor: Arc<Executor>,
        blocks: Vec<crate::runtime::BlockHandle>,
    },
}

impl HostedStage {
    fn sim(spec: SimStageSpec) -> HostedStage {
        HostedStage::Sim {
            node: spec.virtual_node(),
            nominal_ms: spec.nominal_ms,
        }
    }

    /// Replay the deployer's block-loading sequence for this stage's
    /// range against the agent-local manifest.
    fn blocks(spec: &BlockStageSpec) -> Result<HostedStage> {
        let dir = PathBuf::from(&spec.artifacts_dir);
        let manifest = Manifest::load(&dir)
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let (start, end) = (spec.block_start as usize, spec.block_end as usize);
        anyhow::ensure!(
            start <= end && end <= manifest.blocks.len(),
            "block range {start}..{end} outside manifest ({} blocks)",
            manifest.blocks.len()
        );
        let node = spec.virtual_node();
        let executor = Arc::new(Executor::spawn(&spec.name)?);
        let batch = spec.batch as usize;
        let mut blocks = Vec::with_capacity(end - start);
        for bi in start..end {
            let block = &manifest.blocks[bi];
            let hlo = manifest.artifact_path(block, batch)?;
            let handle = executor
                .load_block(
                    hlo,
                    manifest.weights_path(block),
                    block.param_count as usize,
                    vec![
                        batch,
                        block.out_shape[0],
                        block.out_shape[1],
                        block.out_shape[2],
                    ],
                )
                .with_context(|| format!("loading block {}", block.name))?;
            blocks.push(handle);
        }
        node.mem_reserve(spec.mem_reserve);
        Ok(HostedStage::Blocks { node, executor, blocks })
    }

    fn execute(&self, input: Tensor) -> Result<(Tensor, f64)> {
        match self {
            HostedStage::Sim { node, nominal_ms } => {
                let nominal = *nominal_ms;
                let (out, outcome) = node.execute_costed(move || {
                    // Mirror of `SimStages::execute`: same transform,
                    // same pooled output buffer, same recycle.
                    let mut data = BufferPool::global().take(input.len());
                    data.extend(input.data().iter().map(|v| v * 1.5 + 0.25));
                    let t = Tensor::new(input.shape.clone(), data)?;
                    input.recycle();
                    Ok((t, nominal))
                })?;
                Ok((out, outcome.sim_ms))
            }
            HostedStage::Blocks { node, executor, blocks } => {
                let executor = Arc::clone(executor);
                let blocks = blocks.clone();
                let (out, outcome) =
                    node.execute_costed(move || executor.run_chain(blocks, input))?;
                Ok((out, outcome.sim_ms))
            }
        }
    }
}

enum Listener {
    Unix(UnixListener),
    Tcp(TcpListener),
}

impl Listener {
    fn accept(&self) -> std::io::Result<WireStream> {
        match self {
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                Ok(WireStream::Unix(s))
            }
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                s.set_nonblocking(false)?;
                let _ = s.set_nodelay(true);
                Ok(WireStream::Tcp(s))
            }
        }
    }
}

/// State shared between the accept loop, connection handlers, and the
/// controlling [`AgentHandle`].
struct Shared {
    stop: AtomicBool,
    exit_when_idle: AtomicBool,
    /// In exit-on-idle mode, a connection that has received nothing for
    /// this long is dropped — the escape hatch for a coordinator that
    /// stalled or vanished without closing its socket, which would
    /// otherwise park the handler in `read` forever and leak the agent
    /// process.
    idle_timeout_ms: AtomicU64,
    /// Currently open connections.
    active: AtomicUsize,
    /// Connections accepted over the agent's lifetime.
    served: AtomicUsize,
    /// Socket clones of live connections, so `kill()` can unblock
    /// handlers parked in a read.
    conns: Mutex<Vec<WireStream>>,
    handlers: Mutex<Vec<JoinHandle<()>>>,
}

/// Default idle timeout: generous enough that a paused-but-healthy
/// coordinator (GC, debugger, long rebalance) never loses its agents,
/// small enough that leaked agents reap themselves.
const DEFAULT_IDLE_TIMEOUT_MS: u64 = 120_000;

/// Decrements `active` when a handler exits, however it exits.
struct ActiveGuard(Arc<Shared>);

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.0.active.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A running node agent.
pub struct NodeAgent;

impl NodeAgent {
    /// Listen on a Unix domain socket (any stale socket file at `path`
    /// is replaced).
    pub fn serve_uds(path: impl AsRef<Path>) -> Result<AgentHandle> {
        let path = path.as_ref().to_path_buf();
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path)
            .with_context(|| format!("binding uds:{}", path.display()))?;
        listener.set_nonblocking(true)?;
        AgentHandle::spawn(
            Listener::Unix(listener),
            AgentAddr::Uds(path.clone()),
            Some(path),
        )
    }

    /// Listen on a TCP address; `host:0` picks a free port (the bound
    /// address is available via [`AgentHandle::addr`]).
    pub fn serve_tcp(addr: &str) -> Result<AgentHandle> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding tcp:{addr}"))?;
        let bound = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        AgentHandle::spawn(
            Listener::Tcp(listener),
            AgentAddr::Tcp(bound.to_string()),
            None,
        )
    }
}

/// Control handle for a running agent: query its bound address, flip
/// exit-on-idle, kill it hard, or join until it exits on its own.
pub struct AgentHandle {
    addr: AgentAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    uds_path: Option<PathBuf>,
}

impl AgentHandle {
    fn spawn(
        listener: Listener,
        addr: AgentAddr,
        uds_path: Option<PathBuf>,
    ) -> Result<AgentHandle> {
        let shared = Arc::new(Shared {
            stop: AtomicBool::new(false),
            exit_when_idle: AtomicBool::new(false),
            idle_timeout_ms: AtomicU64::new(DEFAULT_IDLE_TIMEOUT_MS),
            active: AtomicUsize::new(0),
            served: AtomicUsize::new(0),
            conns: Mutex::new(Vec::new()),
            handlers: Mutex::new(Vec::new()),
        });
        let loop_shared = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("amp4ec-agent-accept".to_string())
            .spawn(move || accept_loop(listener, loop_shared))
            .context("spawning agent accept thread")?;
        Ok(AgentHandle { addr, shared, accept: Some(accept), uds_path })
    }

    /// Where the agent is listening (with the resolved port for
    /// `host:0` TCP binds).
    pub fn addr(&self) -> &AgentAddr {
        &self.addr
    }

    /// When set, the agent exits once it has served at least one
    /// connection and the last one closes — the "shut down when the
    /// coordinator goes away" mode `amp4ec node` runs in.
    pub fn exit_when_idle(&self, on: bool) {
        self.shared.exit_when_idle.store(on, Ordering::SeqCst);
    }

    /// In exit-on-idle mode, drop a connection that has received
    /// nothing for `timeout` — how long a stalled or vanished
    /// coordinator can hold this agent alive. Long-lived `--stay`
    /// agents (exit-on-idle off) are unaffected.
    pub fn set_idle_timeout(&self, timeout: Duration) {
        self.shared
            .idle_timeout_ms
            .store(timeout.as_millis().max(1) as u64, Ordering::SeqCst);
    }

    /// Open connections right now.
    pub fn active_connections(&self) -> usize {
        self.shared.active.load(Ordering::SeqCst)
    }

    /// Hard-stop: severs every live connection mid-stream (in-flight
    /// coordinator round-trips fail immediately) and stops accepting.
    /// Does not join — pair with [`AgentHandle::join`] or drop.
    pub fn kill(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        for conn in self.shared.conns.lock().unwrap().iter() {
            conn.shutdown();
        }
    }

    /// Wait until the agent exits (via [`kill`](AgentHandle::kill) or
    /// exit-on-idle) and reap all of its threads.
    pub fn join(mut self) {
        self.join_inner();
    }

    fn join_inner(&mut self) {
        if let Some(t) = self.accept.take() {
            let _ = t.join();
        }
        let handlers = std::mem::take(&mut *self.shared.handlers.lock().unwrap());
        for h in handlers {
            let _ = h.join();
        }
        if let Some(path) = self.uds_path.take() {
            let _ = std::fs::remove_file(path);
        }
    }
}

impl Drop for AgentHandle {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.kill();
            self.join_inner();
        }
    }
}

fn accept_loop(listener: Listener, shared: Arc<Shared>) {
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        if shared.exit_when_idle.load(Ordering::SeqCst)
            && shared.served.load(Ordering::SeqCst) > 0
            && shared.active.load(Ordering::SeqCst) == 0
        {
            break;
        }
        match listener.accept() {
            Ok(stream) => {
                shared.served.fetch_add(1, Ordering::SeqCst);
                shared.active.fetch_add(1, Ordering::SeqCst);
                if let Ok(clone) = stream.try_clone() {
                    shared.conns.lock().unwrap().push(clone);
                }
                let conn_shared = Arc::clone(&shared);
                let handler = std::thread::Builder::new()
                    .name("amp4ec-agent-conn".to_string())
                    .spawn(move || {
                        let _guard = ActiveGuard(Arc::clone(&conn_shared));
                        handle_conn(stream, &conn_shared);
                    });
                match handler {
                    Ok(h) => shared.handlers.lock().unwrap().push(h),
                    // Spawn failure: the ActiveGuard never ran, undo.
                    Err(_) => {
                        shared.active.fetch_sub(1, Ordering::SeqCst);
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => {
                if shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

/// Answer `frame`, reporting whether the connection is still usable.
fn send(stream: &mut WireStream, frame: &Frame) -> bool {
    frame::write_frame(stream, frame).is_ok() && stream.flush().is_ok()
}

/// How often a parked handler wakes to check the stop flag and the
/// idle deadline (the socket's read timeout).
const READ_TICK: Duration = Duration::from_millis(250);

/// `Read` adapter that retries timed-out reads while watching the stop
/// flag and — in exit-on-idle mode — an idle deadline. Retrying at the
/// `read()` level (not around `read_exact`) preserves partial-frame
/// progress, so a slow-but-alive coordinator never desyncs the stream.
struct PatientReader<'a> {
    stream: &'a mut WireStream,
    shared: &'a Shared,
    last_rx: &'a mut Instant,
}

impl Read for PatientReader<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        loop {
            match self.stream.read(buf) {
                Ok(n) => {
                    if n > 0 {
                        *self.last_rx = Instant::now();
                    }
                    return Ok(n);
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    if self.shared.stop.load(Ordering::SeqCst) {
                        return Err(e);
                    }
                    if self.shared.exit_when_idle.load(Ordering::SeqCst) {
                        let idle = Duration::from_millis(
                            self.shared.idle_timeout_ms.load(Ordering::SeqCst),
                        );
                        if self.last_rx.elapsed() >= idle {
                            return Err(std::io::Error::new(
                                std::io::ErrorKind::TimedOut,
                                "connection idle past the agent's idle timeout",
                            ));
                        }
                    }
                }
                Err(e) => return Err(e),
            }
        }
    }
}

fn handle_conn(mut stream: WireStream, shared: &Shared) {
    // Bounded reads: the handler wakes every READ_TICK to notice
    // `stop` and the idle deadline even with no bytes arriving — a
    // stalled coordinator can no longer park this thread (and the
    // whole agent process) in `read` forever.
    let _ = stream.set_read_timeout(Some(READ_TICK));
    let mut last_rx = Instant::now();
    let mut hosted: Option<HostedStage> = None;
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        // EOF or a malformed frame both end the connection; the
        // coordinator side surfaces its own error for in-flight work.
        let frame = {
            let mut patient = PatientReader {
                stream: &mut stream,
                shared,
                last_rx: &mut last_rx,
            };
            match frame::read_frame(&mut patient) {
                Ok(f) => f,
                Err(_) => break,
            }
        };
        match frame {
            Frame::Hello { version } => {
                if version != WIRE_VERSION {
                    let _ = send(
                        &mut stream,
                        &Frame::ExecuteErr {
                            seq: 0,
                            message: format!(
                                "agent speaks protocol v{WIRE_VERSION}, \
                                 coordinator sent v{version}"
                            ),
                        },
                    );
                    break;
                }
                if !send(&mut stream, &Frame::HelloAck { version: WIRE_VERSION }) {
                    break;
                }
            }
            Frame::DeploySim(spec) => {
                let stage = spec.stage;
                hosted = Some(HostedStage::sim(spec));
                if !send(&mut stream, &Frame::DeployAck { stage }) {
                    break;
                }
            }
            Frame::DeployBlocks(spec) => match HostedStage::blocks(&spec) {
                Ok(h) => {
                    hosted = Some(h);
                    if !send(&mut stream, &Frame::DeployAck { stage: spec.stage }) {
                        break;
                    }
                }
                Err(e) => {
                    let _ = send(
                        &mut stream,
                        &Frame::ExecuteErr {
                            seq: 0,
                            message: format!("deploy failed: {e:#}"),
                        },
                    );
                    break;
                }
            },
            Frame::Execute { seq, tensor } => {
                let reply = match &hosted {
                    None => Frame::ExecuteErr {
                        seq,
                        message: "no stage deployed on this connection".to_string(),
                    },
                    Some(stage) => match stage.execute(tensor) {
                        Ok((out, compute_ms)) => {
                            Frame::ExecuteOk { seq, compute_ms, tensor: out }
                        }
                        Err(e) => Frame::ExecuteErr {
                            seq,
                            message: format!("{e:#}"),
                        },
                    },
                };
                let ok = send(&mut stream, &reply);
                // The stage output is on the wire; pool its buffer.
                if let Frame::ExecuteOk { tensor, .. } = reply {
                    tensor.recycle();
                }
                if !ok {
                    break;
                }
            }
            Frame::Shutdown => break,
            other => {
                let _ = send(
                    &mut stream,
                    &Frame::ExecuteErr {
                        seq: 0,
                        message: format!("unexpected {} frame", other.kind_name()),
                    },
                );
                break;
            }
        }
    }
    stream.shutdown();
}
