//! Length-prefixed binary wire protocol for coordinator <-> node-agent
//! traffic.
//!
//! Every message is one frame:
//! `[len: u32 LE][crc: u32 LE][kind: u8][payload]`, where `len` counts
//! the kind byte plus the payload and `crc` is a CRC32 (IEEE) over the
//! same kind+payload bytes. Activation frames ([`Frame::Execute`] /
//! [`Frame::ExecuteOk`]) carry a tensor as
//! `[ndim: u8][dims: u32 x ndim][f32 LE x product]`; encoding writes
//! the header and the tensor's `data()` slice (an offset/len view of
//! its shared `TensorBuf`) with one vectored write — no re-marshal of
//! the activation — and decoding lands the rows directly into a buffer
//! from the global [`BufferPool`], folding the CRC incrementally as
//! bytes stream in so integrity checking never buffers the frame twice.
//! Malformed input (truncated header, oversized length, mid-frame EOF,
//! dimension overflow, CRC mismatch) always returns an error, never
//! panics, never delivers corrupted tensor bytes, and never allocates
//! proportionally to an unvalidated length.
//!
//! All frame traffic is counted in [`crate::metrics::wire`].

use std::io::{self, IoSlice, Read, Write};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::runtime::Tensor;
use crate::util::pool::BufferPool;

/// Protocol magic carried in [`Frame::Hello`] so an agent can reject a
/// stray non-protocol peer on the first frame.
pub const WIRE_MAGIC: u32 = 0xA4EC_0001;
/// Protocol version negotiated in the Hello/HelloAck handshake.
/// Version 2 added the per-frame CRC32 header field.
pub const WIRE_VERSION: u16 = 2;
/// Hard ceiling on one frame's `len` (kind + payload). 256 MiB covers
/// any realistic activation micro-batch while bounding what a corrupt
/// length prefix can make the decoder read.
pub const MAX_FRAME_BYTES: u32 = 256 * 1024 * 1024;

const KIND_HELLO: u8 = 1;
const KIND_HELLO_ACK: u8 = 2;
const KIND_DEPLOY_SIM: u8 = 3;
const KIND_DEPLOY_BLOCKS: u8 = 4;
const KIND_DEPLOY_ACK: u8 = 5;
const KIND_EXECUTE: u8 = 6;
const KIND_EXECUTE_OK: u8 = 7;
const KIND_EXECUTE_ERR: u8 = 8;
const KIND_SHUTDOWN: u8 = 9;

// ---- CRC32 (IEEE 802.3 / zlib polynomial) ----------------------------
//
// Table-driven, built at compile time so the integrity check costs one
// lookup + xor per byte with no runtime init and no dependency.

const fn build_crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC32_TABLE: [u32; 256] = build_crc32_table();

/// Initial CRC32 state; feed bytes with [`crc32_update`] and close with
/// [`crc32_finish`].
pub const CRC32_INIT: u32 = 0xFFFF_FFFF;

/// Fold `bytes` into a running CRC32 state.
pub fn crc32_update(mut state: u32, bytes: &[u8]) -> u32 {
    for &b in bytes {
        state = CRC32_TABLE[((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    state
}

/// Finalize a CRC32 state into the checksum carried on the wire.
pub fn crc32_finish(state: u32) -> u32 {
    state ^ 0xFFFF_FFFF
}

/// One-shot CRC32 of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    crc32_finish(crc32_update(CRC32_INIT, bytes))
}

/// Deployment order for one synthetic (sim) stage: everything the agent
/// needs to rebuild the stage's [`crate::cluster::VirtualNode`] and run
/// the exact `SimStages` transform — so a wire run is bit-identical to
/// the in-process run and charges the same simulated milliseconds.
#[derive(Debug, Clone, PartialEq)]
pub struct SimStageSpec {
    pub stage: u32,
    pub node_id: u32,
    pub name: String,
    pub cpu_fraction: f64,
    pub mem_limit_mb: f64,
    pub link_latency_ms: f64,
    pub link_bandwidth_mbps: f64,
    pub time_scale: f64,
    pub page_factor: f64,
    pub runtime_overhead_mb: f64,
    pub nominal_ms: f64,
}

impl SimStageSpec {
    /// One spec per CPU share — the exact mirror of
    /// `SimStages::heterogeneous` (same node names, memory, default LAN
    /// link, and sim parameters), so an agent chain deployed from these
    /// specs reproduces the in-process chain bit for bit.
    pub fn heterogeneous(cpu_shares: &[f64], nominal_ms: f64) -> Vec<SimStageSpec> {
        cpu_shares
            .iter()
            .enumerate()
            .map(|(i, &cpu)| SimStageSpec {
                stage: i as u32,
                node_id: i as u32,
                name: format!("sim-{i}"),
                cpu_fraction: cpu,
                mem_limit_mb: 1024.0,
                link_latency_ms: 1.0,
                link_bandwidth_mbps: 1000.0,
                time_scale: 1.0,
                page_factor: 4.0,
                runtime_overhead_mb: 0.0,
                nominal_ms,
            })
            .collect()
    }

    pub fn node_spec(&self) -> crate::cluster::NodeSpec {
        crate::cluster::NodeSpec::new(
            &self.name,
            self.cpu_fraction,
            self.mem_limit_mb,
        )
        .with_link(crate::cluster::LinkSpec::new(
            self.link_latency_ms,
            self.link_bandwidth_mbps,
        ))
    }

    pub fn sim_params(&self) -> crate::cluster::SimParams {
        crate::cluster::SimParams {
            time_scale: self.time_scale,
            page_factor: self.page_factor,
            runtime_overhead_mb: self.runtime_overhead_mb,
        }
    }

    /// The agent-side virtual node this spec describes.
    pub fn virtual_node(&self) -> crate::cluster::VirtualNode {
        crate::cluster::VirtualNode::new(
            self.node_id as usize,
            self.node_spec(),
            self.sim_params(),
        )
    }
}

/// Deployment order for one real-artifact stage: the agent loads blocks
/// `[block_start, block_end)` of the manifest under its local
/// `artifacts_dir` into an executor on a virtual node built from the
/// same fields as [`SimStageSpec`].
#[derive(Debug, Clone, PartialEq)]
pub struct BlockStageSpec {
    pub stage: u32,
    pub node_id: u32,
    pub name: String,
    pub cpu_fraction: f64,
    pub mem_limit_mb: f64,
    pub link_latency_ms: f64,
    pub link_bandwidth_mbps: f64,
    pub time_scale: f64,
    pub page_factor: f64,
    pub runtime_overhead_mb: f64,
    /// Agent-local artifacts directory holding `manifest.json`.
    pub artifacts_dir: String,
    pub block_start: u32,
    pub block_end: u32,
    pub batch: u32,
    /// Working-set bytes to reserve on the agent's node.
    pub mem_reserve: u64,
}

impl BlockStageSpec {
    pub fn node_spec(&self) -> crate::cluster::NodeSpec {
        crate::cluster::NodeSpec::new(
            &self.name,
            self.cpu_fraction,
            self.mem_limit_mb,
        )
        .with_link(crate::cluster::LinkSpec::new(
            self.link_latency_ms,
            self.link_bandwidth_mbps,
        ))
    }

    pub fn sim_params(&self) -> crate::cluster::SimParams {
        crate::cluster::SimParams {
            time_scale: self.time_scale,
            page_factor: self.page_factor,
            runtime_overhead_mb: self.runtime_overhead_mb,
        }
    }

    pub fn virtual_node(&self) -> crate::cluster::VirtualNode {
        crate::cluster::VirtualNode::new(
            self.node_id as usize,
            self.node_spec(),
            self.sim_params(),
        )
    }
}

/// What a stage deployment ships: a synthetic stage or a real block
/// range.
#[derive(Debug, Clone, PartialEq)]
pub enum DeploySpec {
    Sim(SimStageSpec),
    Blocks(BlockStageSpec),
}

impl DeploySpec {
    pub fn stage(&self) -> u32 {
        match self {
            DeploySpec::Sim(s) => s.stage,
            DeploySpec::Blocks(s) => s.stage,
        }
    }

    pub fn node_id(&self) -> u32 {
        match self {
            DeploySpec::Sim(s) => s.node_id,
            DeploySpec::Blocks(s) => s.node_id,
        }
    }

    /// Coordinator-side mirror node: reproduces the stage's link model
    /// (the pure `LinkSpec::transfer_ms` formula) for `comm_in` /
    /// `comm_out` accounting identical to the in-process chain.
    pub fn virtual_node(&self) -> crate::cluster::VirtualNode {
        match self {
            DeploySpec::Sim(s) => s.virtual_node(),
            DeploySpec::Blocks(s) => s.virtual_node(),
        }
    }
}

/// One protocol message.
#[derive(Debug)]
pub enum Frame {
    Hello { version: u16 },
    HelloAck { version: u16 },
    DeploySim(SimStageSpec),
    DeployBlocks(BlockStageSpec),
    DeployAck { stage: u32 },
    Execute { seq: u64, tensor: Tensor },
    ExecuteOk { seq: u64, compute_ms: f64, tensor: Tensor },
    ExecuteErr { seq: u64, message: String },
    Shutdown,
}

impl Frame {
    /// Short name for diagnostics ("unexpected frame ...").
    pub fn kind_name(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "Hello",
            Frame::HelloAck { .. } => "HelloAck",
            Frame::DeploySim(_) => "DeploySim",
            Frame::DeployBlocks(_) => "DeployBlocks",
            Frame::DeployAck { .. } => "DeployAck",
            Frame::Execute { .. } => "Execute",
            Frame::ExecuteOk { .. } => "ExecuteOk",
            Frame::ExecuteErr { .. } => "ExecuteErr",
            Frame::Shutdown => "Shutdown",
        }
    }
}

// ---- little-endian scalar helpers ------------------------------------

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) -> Result<()> {
    anyhow::ensure!(
        s.len() <= u16::MAX as usize,
        "string of {} bytes too long for the wire (max {})",
        s.len(),
        u16::MAX
    );
    put_u16(buf, s.len() as u16);
    buf.extend_from_slice(s.as_bytes());
    Ok(())
}

/// Bounds-checked read cursor over one frame body.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Cur<'a> {
        Cur { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .with_context(|| {
                format!(
                    "truncated frame body: need {n} bytes at offset {} of {}",
                    self.pos,
                    self.buf.len()
                )
            })?;
        let out = &self.buf[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u16()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).context("invalid UTF-8 string on the wire")
    }

    fn done(&self) -> Result<()> {
        anyhow::ensure!(
            self.pos == self.buf.len(),
            "{} trailing bytes after frame body",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

// ---- f32 <-> bytes (LE wire order) -----------------------------------

#[cfg(target_endian = "little")]
fn f32s_as_bytes(data: &[f32]) -> &[u8] {
    // Safety: u8 has alignment 1 and every byte pattern is valid; the
    // slice covers exactly the f32 storage.
    unsafe {
        std::slice::from_raw_parts(
            data.as_ptr().cast::<u8>(),
            std::mem::size_of_val(data),
        )
    }
}

#[cfg(target_endian = "little")]
fn encode_f32s(data: &[f32]) -> std::borrow::Cow<'_, [u8]> {
    std::borrow::Cow::Borrowed(f32s_as_bytes(data))
}

#[cfg(not(target_endian = "little"))]
fn encode_f32s(data: &[f32]) -> std::borrow::Cow<'_, [u8]> {
    let mut out = Vec::with_capacity(std::mem::size_of_val(data));
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    std::borrow::Cow::Owned(out)
}

/// Read `n` f32s straight into a pooled buffer, folding the wire bytes
/// into the running frame CRC.
fn read_f32s_pooled(r: &mut impl Read, n: usize, crc: &mut u32) -> Result<Vec<f32>> {
    let mut data = BufferPool::global().take(n);
    data.resize(n, 0.0);
    #[cfg(target_endian = "little")]
    {
        let byte_len = n * std::mem::size_of::<f32>();
        // Safety: same layout argument as `f32s_as_bytes`, mutably.
        let bytes = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr().cast::<u8>(), byte_len)
        };
        r.read_exact(bytes).context("mid-frame EOF in tensor data")?;
        *crc = crc32_update(*crc, bytes);
    }
    #[cfg(not(target_endian = "little"))]
    {
        let mut b = [0u8; 4];
        for v in data.iter_mut() {
            r.read_exact(&mut b).context("mid-frame EOF in tensor data")?;
            *crc = crc32_update(*crc, &b);
            *v = f32::from_le_bytes(b);
        }
    }
    Ok(data)
}

// ---- encode ----------------------------------------------------------

/// Tensor meta (`ndim` + dims) appended to `buf`; returns the data byte
/// count the frame must carry after it.
fn put_tensor_meta(buf: &mut Vec<u8>, t: &Tensor) -> Result<usize> {
    anyhow::ensure!(
        !t.shape.is_empty() && t.shape.len() <= u8::MAX as usize,
        "tensor rank {} not encodable (need 1..=255 dims)",
        t.shape.len()
    );
    buf.push(t.shape.len() as u8);
    for &d in &t.shape {
        anyhow::ensure!(
            d <= u32::MAX as usize,
            "tensor dimension {d} too large for the wire"
        );
        put_u32(buf, d as u32);
    }
    Ok(std::mem::size_of_val(t.data()))
}

/// Write `head` then `tail` with a vectored write where possible; the
/// remainder of a partial vectored write is finished with `write_all`.
fn write_all_vectored(
    w: &mut impl Write,
    mut head: &[u8],
    mut tail: &[u8],
) -> io::Result<()> {
    while !head.is_empty() {
        let n = w.write_vectored(&[IoSlice::new(head), IoSlice::new(tail)])?;
        if n == 0 {
            return Err(io::Error::from(io::ErrorKind::WriteZero));
        }
        if n >= head.len() {
            tail = &tail[n - head.len()..];
            head = &[];
        } else {
            head = &head[n..];
        }
    }
    w.write_all(tail)
}

/// Serialize one frame into `w`. Tensor payloads go out as a header
/// write plus a vectored write of the tensor's view slice (no copy of
/// the activation on little-endian targets). Counts the frame in
/// [`crate::metrics::wire`].
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<()> {
    let t0 = Instant::now();
    // Header: 4-byte length + 4-byte CRC placeholders, kind, then the
    // scalar body.
    let mut head: Vec<u8> = Vec::with_capacity(64);
    head.extend_from_slice(&[0; 8]);
    let mut tensor: Option<&Tensor> = None;
    match frame {
        Frame::Hello { version } => {
            head.push(KIND_HELLO);
            put_u32(&mut head, WIRE_MAGIC);
            put_u16(&mut head, *version);
        }
        Frame::HelloAck { version } => {
            head.push(KIND_HELLO_ACK);
            put_u16(&mut head, *version);
        }
        Frame::DeploySim(s) => {
            head.push(KIND_DEPLOY_SIM);
            put_u32(&mut head, s.stage);
            put_u32(&mut head, s.node_id);
            put_str(&mut head, &s.name)?;
            for v in [
                s.cpu_fraction,
                s.mem_limit_mb,
                s.link_latency_ms,
                s.link_bandwidth_mbps,
                s.time_scale,
                s.page_factor,
                s.runtime_overhead_mb,
                s.nominal_ms,
            ] {
                put_f64(&mut head, v);
            }
        }
        Frame::DeployBlocks(s) => {
            head.push(KIND_DEPLOY_BLOCKS);
            put_u32(&mut head, s.stage);
            put_u32(&mut head, s.node_id);
            put_str(&mut head, &s.name)?;
            for v in [
                s.cpu_fraction,
                s.mem_limit_mb,
                s.link_latency_ms,
                s.link_bandwidth_mbps,
                s.time_scale,
                s.page_factor,
                s.runtime_overhead_mb,
            ] {
                put_f64(&mut head, v);
            }
            put_str(&mut head, &s.artifacts_dir)?;
            put_u32(&mut head, s.block_start);
            put_u32(&mut head, s.block_end);
            put_u32(&mut head, s.batch);
            put_u64(&mut head, s.mem_reserve);
        }
        Frame::DeployAck { stage } => {
            head.push(KIND_DEPLOY_ACK);
            put_u32(&mut head, *stage);
        }
        Frame::Execute { seq, tensor: t } => {
            head.push(KIND_EXECUTE);
            put_u64(&mut head, *seq);
            put_tensor_meta(&mut head, t)?;
            tensor = Some(t);
        }
        Frame::ExecuteOk { seq, compute_ms, tensor: t } => {
            head.push(KIND_EXECUTE_OK);
            put_u64(&mut head, *seq);
            put_f64(&mut head, *compute_ms);
            put_tensor_meta(&mut head, t)?;
            tensor = Some(t);
        }
        Frame::ExecuteErr { seq, message } => {
            head.push(KIND_EXECUTE_ERR);
            put_u64(&mut head, *seq);
            put_str(&mut head, message)?;
        }
        Frame::Shutdown => {
            head.push(KIND_SHUTDOWN);
        }
    }
    let data = match tensor {
        Some(t) => encode_f32s(t.data()),
        None => std::borrow::Cow::Borrowed(&[][..]),
    };
    let body = head.len() - 8 + data.len();
    anyhow::ensure!(
        body <= MAX_FRAME_BYTES as usize,
        "frame of {body} bytes exceeds MAX_FRAME_BYTES ({MAX_FRAME_BYTES})"
    );
    let crc = crc32_finish(crc32_update(
        crc32_update(CRC32_INIT, &head[8..]),
        &data,
    ));
    head[..4].copy_from_slice(&(body as u32).to_le_bytes());
    head[4..8].copy_from_slice(&crc.to_le_bytes());
    if data.is_empty() {
        w.write_all(&head)
    } else {
        write_all_vectored(w, &head, &data)
    }
    .with_context(|| format!("writing {} frame", frame.kind_name()))?;
    crate::metrics::wire::count_tx(
        (8 + body) as u64,
        t0.elapsed().as_nanos() as u64,
    );
    Ok(())
}

// ---- decode ----------------------------------------------------------

/// Decode the streamed body of an Execute / ExecuteOk frame: the scalar
/// prefix and dims are read first, validated against `body_len`, and
/// only then is the (pooled) data buffer sized and filled — a corrupt
/// length can never drive an allocation.
fn read_tensor_body(
    r: &mut impl Read,
    body_len: usize,
    with_ms: bool,
    mut crc: u32,
    want_crc: u32,
) -> Result<(u64, f64, Tensor)> {
    let fixed = 8 + if with_ms { 8 } else { 0 } + 1;
    anyhow::ensure!(
        body_len >= fixed,
        "tensor frame body of {body_len} bytes shorter than its {fixed}-byte prefix"
    );
    let mut prefix = [0u8; 17];
    r.read_exact(&mut prefix[..fixed])
        .context("mid-frame EOF in tensor prefix")?;
    crc = crc32_update(crc, &prefix[..fixed]);
    let mut cur = Cur::new(&prefix[..fixed]);
    let seq = cur.u64()?;
    let compute_ms = if with_ms { cur.f64()? } else { 0.0 };
    let ndim = cur.u8()? as usize;
    anyhow::ensure!(ndim >= 1, "tensor frame with zero dimensions");
    let dims_bytes = ndim * 4;
    anyhow::ensure!(
        body_len >= fixed + dims_bytes,
        "tensor frame body of {body_len} bytes truncates its {ndim} dims"
    );
    let mut dim_buf = vec![0u8; dims_bytes];
    r.read_exact(&mut dim_buf)
        .context("mid-frame EOF in tensor dims")?;
    crc = crc32_update(crc, &dim_buf);
    let mut cur = Cur::new(&dim_buf);
    let mut shape = Vec::with_capacity(ndim);
    let mut elems: usize = 1;
    for _ in 0..ndim {
        let d = cur.u32()? as usize;
        elems = elems
            .checked_mul(d)
            .context("tensor dimension product overflows")?;
        shape.push(d);
    }
    let expected = (fixed + dims_bytes) as u64 + (elems as u64) * 4;
    anyhow::ensure!(
        expected == body_len as u64,
        "tensor frame length mismatch: body is {body_len} bytes but shape \
         {shape:?} needs {expected}"
    );
    let data = read_f32s_pooled(r, elems, &mut crc)?;
    let got = crc32_finish(crc);
    anyhow::ensure!(
        got == want_crc,
        "tensor frame CRC mismatch: computed {got:#010x}, header says \
         {want_crc:#010x}"
    );
    let tensor = Tensor::new(shape, data)?;
    Ok((seq, compute_ms, tensor))
}

/// Read one frame from `r`. Returns an error on malformed or truncated
/// input (including EOF mid-frame); EOF *before* a frame starts also
/// errors — callers treat it as the peer having gone away. Counts the
/// frame in [`crate::metrics::wire`].
pub fn read_frame(r: &mut impl Read) -> Result<Frame> {
    let t0 = Instant::now();
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4).context("reading frame length")?;
    let len = u32::from_le_bytes(len4);
    anyhow::ensure!(len >= 1, "zero-length frame");
    anyhow::ensure!(
        len <= MAX_FRAME_BYTES,
        "frame length {len} exceeds MAX_FRAME_BYTES ({MAX_FRAME_BYTES})"
    );
    let mut crc4 = [0u8; 4];
    r.read_exact(&mut crc4).context("reading frame CRC")?;
    let want_crc = u32::from_le_bytes(crc4);
    let mut kind = [0u8; 1];
    r.read_exact(&mut kind).context("reading frame kind")?;
    let crc0 = crc32_update(CRC32_INIT, &kind);
    let body_len = (len - 1) as usize;
    let frame = match kind[0] {
        KIND_EXECUTE => {
            let (seq, _, tensor) =
                read_tensor_body(r, body_len, false, crc0, want_crc)?;
            Frame::Execute { seq, tensor }
        }
        KIND_EXECUTE_OK => {
            let (seq, compute_ms, tensor) =
                read_tensor_body(r, body_len, true, crc0, want_crc)?;
            Frame::ExecuteOk { seq, compute_ms, tensor }
        }
        k => {
            // Small scalar frames: read the body, check its CRC, then
            // parse it fully.
            let mut body = vec![0u8; body_len];
            r.read_exact(&mut body).context("mid-frame EOF")?;
            let got = crc32_finish(crc32_update(crc0, &body));
            anyhow::ensure!(
                got == want_crc,
                "frame CRC mismatch: computed {got:#010x}, header says \
                 {want_crc:#010x}"
            );
            let mut cur = Cur::new(&body);
            let frame = match k {
                KIND_HELLO => {
                    let magic = cur.u32()?;
                    anyhow::ensure!(
                        magic == WIRE_MAGIC,
                        "bad protocol magic {magic:#010x} (want {WIRE_MAGIC:#010x})"
                    );
                    Frame::Hello { version: cur.u16()? }
                }
                KIND_HELLO_ACK => Frame::HelloAck { version: cur.u16()? },
                KIND_DEPLOY_SIM => {
                    let stage = cur.u32()?;
                    let node_id = cur.u32()?;
                    let name = cur.str()?;
                    Frame::DeploySim(SimStageSpec {
                        stage,
                        node_id,
                        name,
                        cpu_fraction: cur.f64()?,
                        mem_limit_mb: cur.f64()?,
                        link_latency_ms: cur.f64()?,
                        link_bandwidth_mbps: cur.f64()?,
                        time_scale: cur.f64()?,
                        page_factor: cur.f64()?,
                        runtime_overhead_mb: cur.f64()?,
                        nominal_ms: cur.f64()?,
                    })
                }
                KIND_DEPLOY_BLOCKS => {
                    let stage = cur.u32()?;
                    let node_id = cur.u32()?;
                    let name = cur.str()?;
                    let cpu_fraction = cur.f64()?;
                    let mem_limit_mb = cur.f64()?;
                    let link_latency_ms = cur.f64()?;
                    let link_bandwidth_mbps = cur.f64()?;
                    let time_scale = cur.f64()?;
                    let page_factor = cur.f64()?;
                    let runtime_overhead_mb = cur.f64()?;
                    let artifacts_dir = cur.str()?;
                    Frame::DeployBlocks(BlockStageSpec {
                        stage,
                        node_id,
                        name,
                        cpu_fraction,
                        mem_limit_mb,
                        link_latency_ms,
                        link_bandwidth_mbps,
                        time_scale,
                        page_factor,
                        runtime_overhead_mb,
                        artifacts_dir,
                        block_start: cur.u32()?,
                        block_end: cur.u32()?,
                        batch: cur.u32()?,
                        mem_reserve: cur.u64()?,
                    })
                }
                KIND_DEPLOY_ACK => Frame::DeployAck { stage: cur.u32()? },
                KIND_EXECUTE_ERR => Frame::ExecuteErr {
                    seq: cur.u64()?,
                    message: cur.str()?,
                },
                KIND_SHUTDOWN => Frame::Shutdown,
                other => bail!("unknown frame kind {other}"),
            };
            cur.done()?;
            frame
        }
    };
    crate::metrics::wire::count_rx(
        (8 + len) as u64,
        t0.elapsed().as_nanos() as u64,
    );
    Ok(frame)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(frame: &Frame) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, frame).unwrap();
        let mut slice = buf.as_slice();
        let out = read_frame(&mut slice).unwrap();
        assert!(slice.is_empty(), "decoder left {} bytes", slice.len());
        out
    }

    /// Hand-craft a raw v2 frame (`len` + correct CRC + kind + body).
    fn raw_frame(kind: u8, body: &[u8]) -> Vec<u8> {
        let crc = crc32_finish(crc32_update(crc32_update(CRC32_INIT, &[kind]), body));
        let mut raw = ((1 + body.len()) as u32).to_le_bytes().to_vec();
        raw.extend_from_slice(&crc.to_le_bytes());
        raw.push(kind);
        raw.extend_from_slice(body);
        raw
    }

    fn assert_tensor_bits(a: &Tensor, b: &Tensor) {
        assert_eq!(a.shape, b.shape);
        assert_eq!(a.data().len(), b.data().len());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn scalar_frames_roundtrip() {
        match roundtrip(&Frame::Hello { version: 7 }) {
            Frame::Hello { version: 7 } => {}
            f => panic!("got {f:?}"),
        }
        match roundtrip(&Frame::HelloAck { version: WIRE_VERSION }) {
            Frame::HelloAck { version } => assert_eq!(version, WIRE_VERSION),
            f => panic!("got {f:?}"),
        }
        match roundtrip(&Frame::DeployAck { stage: 3 }) {
            Frame::DeployAck { stage: 3 } => {}
            f => panic!("got {f:?}"),
        }
        match roundtrip(&Frame::ExecuteErr { seq: 42, message: "boom: xyz".into() }) {
            Frame::ExecuteErr { seq, message } => {
                assert_eq!(seq, 42);
                assert_eq!(message, "boom: xyz");
            }
            f => panic!("got {f:?}"),
        }
        match roundtrip(&Frame::Shutdown) {
            Frame::Shutdown => {}
            f => panic!("got {f:?}"),
        }
    }

    #[test]
    fn deploy_specs_roundtrip() {
        let sim = SimStageSpec::heterogeneous(&[1.0, 0.6, 0.4], 4.0);
        for spec in &sim {
            match roundtrip(&Frame::DeploySim(spec.clone())) {
                Frame::DeploySim(back) => assert_eq!(&back, spec),
                f => panic!("got {f:?}"),
            }
        }
        let blocks = BlockStageSpec {
            stage: 1,
            node_id: 2,
            name: "edge-med".into(),
            cpu_fraction: 0.6,
            mem_limit_mb: 512.0,
            link_latency_ms: 1.5,
            link_bandwidth_mbps: 800.0,
            time_scale: 1.0,
            page_factor: 4.0,
            runtime_overhead_mb: 384.0,
            artifacts_dir: "artifacts".into(),
            block_start: 3,
            block_end: 7,
            batch: 4,
            mem_reserve: 12_345_678,
        };
        match roundtrip(&Frame::DeployBlocks(blocks.clone())) {
            Frame::DeployBlocks(back) => assert_eq!(back, blocks),
            f => panic!("got {f:?}"),
        }
    }

    #[test]
    fn tensor_frames_roundtrip_randomized() {
        // Random shapes, including views at non-zero base offsets,
        // 1-row tail chunks, and single-element tensors.
        let mut rng = Rng::new(0xC0DEC);
        for case in 0..60 {
            let ndim = rng.range(1, 4);
            let shape: Vec<usize> =
                (0..ndim).map(|_| rng.range(1, 9)).collect();
            let n: usize = shape.iter().product();
            let data: Vec<f32> =
                (0..n).map(|_| rng.f32_range(-100.0, 100.0)).collect();
            let full = Tensor::new(shape.clone(), data).unwrap();
            // Alternate between the full tensor and a row view of it
            // (views get non-zero buffer bases and tail chunks).
            let t = if case % 3 == 0 && shape[0] > 1 {
                let start = rng.below(shape[0] - 1);
                let end = rng.range(start + 1, shape[0]);
                full.view_rows(start..end).unwrap()
            } else {
                full.clone()
            };
            let seq = rng.next_u64();
            match roundtrip(&Frame::Execute { seq, tensor: t.clone() }) {
                Frame::Execute { seq: s, tensor: back } => {
                    assert_eq!(s, seq);
                    assert_tensor_bits(&t, &back);
                }
                f => panic!("got {f:?}"),
            }
            match roundtrip(&Frame::ExecuteOk {
                seq,
                compute_ms: 12.625,
                tensor: t.clone(),
            }) {
                Frame::ExecuteOk { seq: s, compute_ms, tensor: back } => {
                    assert_eq!(s, seq);
                    assert_eq!(compute_ms, 12.625);
                    assert_tensor_bits(&t, &back);
                }
                f => panic!("got {f:?}"),
            }
        }
    }

    #[test]
    fn nonzero_view_base_encodes_view_contents_only() {
        let full = Tensor::new(
            vec![4, 3],
            (0..12).map(|i| i as f32).collect(),
        )
        .unwrap();
        let view = full.view_rows(2..4).unwrap();
        assert_eq!(view.offset(), 6);
        match roundtrip(&Frame::Execute { seq: 1, tensor: view.clone() }) {
            Frame::Execute { tensor: back, .. } => {
                assert_eq!(back.shape, vec![2, 3]);
                assert_eq!(back.data(), &[6.0, 7.0, 8.0, 9.0, 10.0, 11.0]);
                // The decoded tensor owns its own full buffer.
                assert_eq!(back.offset(), 0);
            }
            f => panic!("got {f:?}"),
        }
    }

    #[test]
    fn truncated_inputs_error_not_panic() {
        // Build one valid Execute frame, then feed every proper prefix
        // of it: all must error (mid-frame EOF at any point), none may
        // panic.
        let t = Tensor::new(vec![2, 3], vec![1.0; 6]).unwrap();
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Execute { seq: 9, tensor: t }).unwrap();
        for cut in 0..buf.len() {
            let mut slice = &buf[..cut];
            assert!(
                read_frame(&mut slice).is_err(),
                "prefix of {cut}/{} bytes decoded",
                buf.len()
            );
        }
        // And the full frame still decodes.
        let mut slice = buf.as_slice();
        assert!(read_frame(&mut slice).is_ok());
    }

    #[test]
    fn oversized_and_malformed_lengths_error() {
        // Oversized length prefix: rejected before any allocation.
        let mut raw = u32::MAX.to_le_bytes().to_vec();
        raw.extend_from_slice(&0u32.to_le_bytes());
        raw.push(KIND_SHUTDOWN);
        assert!(read_frame(&mut raw.as_slice()).is_err());
        // Zero-length frame.
        let raw = 0u32.to_le_bytes().to_vec();
        assert!(read_frame(&mut raw.as_slice()).is_err());
        // Unknown kind (with a correct CRC so the kind check is what
        // fires).
        let raw = raw_frame(200, &[]);
        assert!(read_frame(&mut raw.as_slice()).is_err());
        // Declared length larger than the actual body (EOF mid-body).
        let mut raw = 64u32.to_le_bytes().to_vec();
        raw.extend_from_slice(&0u32.to_le_bytes());
        raw.push(KIND_DEPLOY_ACK);
        raw.extend_from_slice(&3u32.to_le_bytes());
        assert!(read_frame(&mut raw.as_slice()).is_err());
        // Trailing garbage after a well-formed body.
        let mut body = 3u32.to_le_bytes().to_vec();
        body.push(0xFF);
        let raw = raw_frame(KIND_DEPLOY_ACK, &body);
        assert!(read_frame(&mut raw.as_slice()).is_err());
    }

    #[test]
    fn tensor_dim_overflow_errors() {
        // Hand-craft an Execute frame whose dims multiply past usize:
        // 4 dims of u32::MAX each. The decoder must reject it before
        // sizing any buffer.
        let mut body = 1u64.to_le_bytes().to_vec();
        body.push(4);
        for _ in 0..4 {
            body.extend_from_slice(&u32::MAX.to_le_bytes());
        }
        let raw = raw_frame(KIND_EXECUTE, &body);
        assert!(read_frame(&mut raw.as_slice()).is_err());
        // A shape/length mismatch (valid dims, missing data) also errors.
        let mut body = 1u64.to_le_bytes().to_vec();
        body.push(1);
        body.extend_from_slice(&100u32.to_le_bytes());
        body.push(0);
        let raw = raw_frame(KIND_EXECUTE, &body);
        assert!(read_frame(&mut raw.as_slice()).is_err());
        // Zero-rank tensor frames are malformed.
        let mut body = 1u64.to_le_bytes().to_vec();
        body.push(0);
        let raw = raw_frame(KIND_EXECUTE, &body);
        assert!(read_frame(&mut raw.as_slice()).is_err());
    }

    #[test]
    fn hello_rejects_bad_magic() {
        let mut body = 0xDEAD_BEEFu32.to_le_bytes().to_vec();
        body.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        let raw = raw_frame(KIND_HELLO, &body);
        assert!(read_frame(&mut raw.as_slice()).is_err());
    }

    #[test]
    fn wire_counters_move() {
        let before = crate::metrics::wire::snapshot();
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Shutdown).unwrap();
        read_frame(&mut buf.as_slice()).unwrap();
        let delta = crate::metrics::wire::snapshot().since(&before);
        assert!(delta.frames_tx >= 1);
        assert!(delta.frames_rx >= 1);
        assert!(delta.bytes_tx >= 9);
        assert_eq!(delta.bytes_tx, delta.bytes_rx);
    }

    #[test]
    fn crc_known_vector() {
        // The standard CRC32 (IEEE) check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        // Incremental folding matches the one-shot result.
        let s = crc32_update(CRC32_INIT, b"1234");
        let s = crc32_update(s, b"56789");
        assert_eq!(crc32_finish(s), 0xCBF4_3926);
    }

    /// Reader that hands back bytes in a fixed schedule of chunk sizes
    /// (cycling), modelling adversarial short reads from the kernel.
    struct ChunkedReader<'a> {
        buf: &'a [u8],
        pos: usize,
        chunks: Vec<usize>,
        i: usize,
    }

    impl Read for ChunkedReader<'_> {
        fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
            let remaining = self.buf.len() - self.pos;
            if remaining == 0 {
                return Ok(0);
            }
            let want = self.chunks[self.i % self.chunks.len()].max(1);
            self.i += 1;
            let n = want.min(out.len()).min(remaining);
            out[..n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    /// Writer that accepts at most a scheduled number of bytes per
    /// call, modelling adversarial partial writes (including partial
    /// vectored writes through `write_all_vectored`).
    struct TrickleWriter {
        out: Vec<u8>,
        caps: Vec<usize>,
        i: usize,
    }

    impl Write for TrickleWriter {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            let cap = self.caps[self.i % self.caps.len()].max(1);
            self.i += 1;
            let n = cap.min(buf.len());
            self.out.extend_from_slice(&buf[..n]);
            Ok(n)
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn fragmented_reads_reassemble_bit_identically() {
        // A stream of mixed frames, re-read under adversarial
        // fragmentation schedules: 1-byte reads, tiny primes, and a
        // split at every byte boundary. Every schedule must reassemble
        // the exact same frames.
        let t = Tensor::new(vec![3, 5], (0..15).map(|i| i as f32 * 1.25).collect())
            .unwrap();
        let frames = vec![
            Frame::Hello { version: WIRE_VERSION },
            Frame::Execute { seq: 11, tensor: t.clone() },
            Frame::ExecuteOk { seq: 11, compute_ms: 3.5, tensor: t.clone() },
            Frame::ExecuteErr { seq: 12, message: "slow".into() },
            Frame::Shutdown,
        ];
        let mut buf = Vec::new();
        for f in &frames {
            write_frame(&mut buf, f).unwrap();
        }
        let mut schedules: Vec<Vec<usize>> =
            vec![vec![1], vec![2], vec![3, 1, 7], vec![13, 5, 2, 1]];
        for cut in 1..buf.len() {
            schedules.push(vec![cut, usize::MAX]);
        }
        for chunks in schedules {
            let mut r = ChunkedReader { buf: &buf, pos: 0, chunks, i: 0 };
            for want in &frames {
                let got = read_frame(&mut r).unwrap();
                assert_eq!(got.kind_name(), want.kind_name());
                match (&got, want) {
                    (
                        Frame::Execute { seq: gs, tensor: gt },
                        Frame::Execute { seq: ws, tensor: wt },
                    ) => {
                        assert_eq!(gs, ws);
                        assert_tensor_bits(gt, wt);
                    }
                    (
                        Frame::ExecuteOk { seq: gs, compute_ms: gm, tensor: gt },
                        Frame::ExecuteOk { seq: ws, compute_ms: wm, tensor: wt },
                    ) => {
                        assert_eq!(gs, ws);
                        assert_eq!(gm, wm);
                        assert_tensor_bits(gt, wt);
                    }
                    _ => {}
                }
            }
            assert_eq!(r.pos, buf.len(), "bytes left after last frame");
        }
    }

    #[test]
    fn partial_writes_encode_identically() {
        let t = Tensor::new(vec![4, 3], (0..12).map(|i| i as f32).collect())
            .unwrap();
        let frame = Frame::ExecuteOk { seq: 99, compute_ms: 1.5, tensor: t };
        let mut clean = Vec::new();
        write_frame(&mut clean, &frame).unwrap();
        for caps in [vec![1], vec![3, 1], vec![7, 2, 5], vec![64, 1]] {
            let mut w = TrickleWriter { out: Vec::new(), caps, i: 0 };
            write_frame(&mut w, &frame).unwrap();
            assert_eq!(w.out, clean, "partial-write bytes diverge");
        }
    }

    #[test]
    fn single_bit_flips_always_detected() {
        // Flip every bit of every byte of several encoded frames; the
        // reader must error on each (CRC mismatch, length violation, or
        // EOF) — never panic, never return a frame.
        let t = Tensor::new(vec![2, 4], (0..8).map(|i| i as f32 - 3.5).collect())
            .unwrap();
        let frames = vec![
            Frame::Shutdown,
            Frame::DeployAck { stage: 3 },
            Frame::Execute { seq: 7, tensor: t.clone() },
            Frame::ExecuteOk { seq: 7, compute_ms: 2.25, tensor: t },
        ];
        for f in &frames {
            let mut buf = Vec::new();
            write_frame(&mut buf, f).unwrap();
            for byte in 0..buf.len() {
                for bit in 0..8 {
                    let mut corrupt = buf.clone();
                    corrupt[byte] ^= 1 << bit;
                    assert!(
                        read_frame(&mut corrupt.as_slice()).is_err(),
                        "{}: flip of byte {byte} bit {bit} decoded",
                        f.kind_name()
                    );
                }
            }
        }
    }
}
