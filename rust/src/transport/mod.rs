//! Pluggable transport layer: coordinator <-> node-agent plumbing.
//!
//! The pipeline engine drives stages through the [`StageExec`] seam and
//! never cares where a stage runs. This module supplies the two ends of
//! that seam for distributed deployments:
//!
//! * [`InprocTransport`] — the default: pure delegation to any local
//!   [`StageExec`] chain, zero added copies, bit-identical to calling
//!   the chain directly.
//! * [`WireStages`] — each stage is hosted by a remote node agent
//!   ([`agent::NodeAgent`], the `amp4ec node` subcommand) and driven
//!   over a length-prefixed binary protocol ([`frame`]) on a Unix
//!   domain socket or TCP connection.
//!
//! The engine runs one driver thread per (stage, replica), so
//! `WireStages` keeps one connection per *replica* (agents are assigned
//! round-robin when there are fewer agents than connections). Each
//! connection pipelines: the writer lock is held only while a frame
//! goes onto the wire, and a dedicated reader thread matches replies to
//! callers by sequence number — concurrent `execute_on` calls on one
//! connection overlap on the socket instead of serializing a full
//! round-trip under one lock. A dropped connection fails everything in
//! flight on it (the engine maps those to per-batch failures) and marks
//! that replica dead so later micro-batches route around it or fail
//! fast instead of hanging.

pub mod agent;
pub mod chaos;
pub mod frame;

use std::collections::HashMap;
use std::fmt;
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, SyncSender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::cluster::{SimParams, VirtualNode};
use crate::deployer::Deployment;
use crate::pipeline::engine::{node_comm_in, node_comm_out, StageExec};
use crate::runtime::Tensor;

use frame::{BlockStageSpec, DeploySpec, Frame, SimStageSpec, WIRE_VERSION};

/// Which transport carries stage traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// Stages run in the coordinator process (the default).
    Inproc,
    /// Stages run in node agents reached over Unix domain sockets.
    Uds,
    /// Stages run in node agents reached over TCP.
    Tcp,
}

impl TransportKind {
    pub fn parse(s: &str) -> Result<TransportKind> {
        match s {
            "inproc" => Ok(TransportKind::Inproc),
            "uds" | "unix" => Ok(TransportKind::Uds),
            "tcp" => Ok(TransportKind::Tcp),
            other => bail!(
                "unknown transport `{other}` (expected `inproc`, `uds`, or `tcp`)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Inproc => "inproc",
            TransportKind::Uds => "uds",
            TransportKind::Tcp => "tcp",
        }
    }
}

impl fmt::Display for TransportKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Where one node agent listens.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AgentAddr {
    Uds(PathBuf),
    Tcp(String),
}

impl AgentAddr {
    /// Parse an address for the given transport kind, with actionable
    /// errors (e.g. a TCP address missing its port).
    pub fn parse(kind: TransportKind, s: &str) -> Result<AgentAddr> {
        let s = s.trim();
        anyhow::ensure!(!s.is_empty(), "empty agent address");
        match kind {
            TransportKind::Inproc => bail!(
                "transport `inproc` takes no agent addresses; drop `agents` \
                 or set the transport to uds/tcp"
            ),
            TransportKind::Uds => Ok(AgentAddr::Uds(PathBuf::from(s))),
            TransportKind::Tcp => {
                anyhow::ensure!(
                    s.contains(':'),
                    "tcp agent address `{s}` must be host:port"
                );
                Ok(AgentAddr::Tcp(s.to_string()))
            }
        }
    }

    /// One connection attempt.
    pub fn connect(&self) -> Result<WireStream> {
        match self {
            AgentAddr::Uds(path) => {
                let s = UnixStream::connect(path).with_context(|| {
                    format!("connecting to agent at uds:{}", path.display())
                })?;
                Ok(WireStream::Unix(s))
            }
            AgentAddr::Tcp(addr) => {
                let s = TcpStream::connect(addr.as_str())
                    .with_context(|| format!("connecting to agent at tcp:{addr}"))?;
                // Activation frames are latency-sensitive round-trips.
                let _ = s.set_nodelay(true);
                Ok(WireStream::Tcp(s))
            }
        }
    }

    /// Poll-connect until `timeout` elapses — agents may still be
    /// binding their listener when the coordinator starts dialing.
    ///
    /// Retries use jittered exponential backoff (5 ms doubling to a
    /// 200 ms cap, scaled by a deterministic per-address jitter) so a
    /// heal pass re-dialing many agents doesn't hammer them in
    /// lockstep, while the schedule stays reproducible for tests.
    pub fn connect_retry(&self, timeout: Duration) -> Result<WireStream> {
        let start = Instant::now();
        let mut rng = crate::util::rng::Rng::new(addr_seed(&self.to_string()));
        let mut backoff_ms = 5.0f64;
        loop {
            match self.connect() {
                Ok(s) => return Ok(s),
                Err(e) if start.elapsed() >= timeout => {
                    return Err(e.context(format!(
                        "agent at {self} not reachable within {timeout:?}"
                    )));
                }
                Err(_) => {
                    let jittered = backoff_ms * (0.5 + rng.f64());
                    let remaining = timeout.saturating_sub(start.elapsed());
                    std::thread::sleep(
                        Duration::from_secs_f64(jittered / 1000.0).min(remaining),
                    );
                    backoff_ms = (backoff_ms * 2.0).min(200.0);
                }
            }
        }
    }
}

/// FNV-1a over the address text: a stable per-address backoff seed.
fn addr_seed(addr: &str) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for b in addr.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

impl fmt::Display for AgentAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AgentAddr::Uds(p) => write!(f, "uds:{}", p.display()),
            AgentAddr::Tcp(a) => write!(f, "tcp:{a}"),
        }
    }
}

/// One connected socket of either flavor.
#[derive(Debug)]
pub enum WireStream {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl WireStream {
    pub fn try_clone(&self) -> io::Result<WireStream> {
        match self {
            WireStream::Unix(s) => s.try_clone().map(WireStream::Unix),
            WireStream::Tcp(s) => s.try_clone().map(WireStream::Tcp),
        }
    }

    /// Bound how long one `read` call may block (None = block forever).
    /// Reads that hit the bound fail with `WouldBlock`/`TimedOut`;
    /// callers that poll (the agent's connection handlers) retry at the
    /// `read()` level so `read_exact`'s progress is preserved.
    pub fn set_read_timeout(&self, dur: Option<Duration>) -> io::Result<()> {
        match self {
            WireStream::Unix(s) => s.set_read_timeout(dur),
            WireStream::Tcp(s) => s.set_read_timeout(dur),
        }
    }

    /// Shut down both directions; errors (already-closed peers) are
    /// ignored — this is only ever a best-effort unblock/teardown.
    pub fn shutdown(&self) {
        match self {
            WireStream::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            WireStream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Read for WireStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            WireStream::Unix(s) => s.read(buf),
            WireStream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for WireStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            WireStream::Unix(s) => s.write(buf),
            WireStream::Tcp(s) => s.write(buf),
        }
    }

    fn write_vectored(&mut self, bufs: &[io::IoSlice<'_>]) -> io::Result<usize> {
        match self {
            WireStream::Unix(s) => s.write_vectored(bufs),
            WireStream::Tcp(s) => s.write_vectored(bufs),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            WireStream::Unix(s) => s.flush(),
            WireStream::Tcp(s) => s.flush(),
        }
    }
}

/// A [`StageExec`] whose stages may live behind a transport. The engine
/// only sees `StageExec`; this trait adds the introspection the server
/// and CLI report need.
pub trait Transport: StageExec {
    fn kind(&self) -> TransportKind;
    /// Human-readable endpoint hosting `stage` (e.g. `inproc`,
    /// `uds:/tmp/a.sock`).
    fn endpoint(&self, stage: usize) -> String;
}

/// The default transport: pure delegation to a local chain. No added
/// copies, no added locks — bit-identical to driving `inner` directly.
pub struct InprocTransport<S: StageExec> {
    inner: S,
}

impl<S: StageExec> InprocTransport<S> {
    pub fn new(inner: S) -> InprocTransport<S> {
        InprocTransport { inner }
    }

    pub fn inner(&self) -> &S {
        &self.inner
    }
}

impl<S: StageExec> StageExec for InprocTransport<S> {
    fn num_stages(&self) -> usize {
        self.inner.num_stages()
    }

    fn node_id(&self, stage: usize) -> usize {
        self.inner.node_id(stage)
    }

    fn backlog(&self, stage: usize) -> usize {
        self.inner.backlog(stage)
    }

    fn comm_in(&self, stage: usize, bytes: u64) -> f64 {
        self.inner.comm_in(stage, bytes)
    }

    fn comm_out(&self, bytes: u64) -> f64 {
        self.inner.comm_out(bytes)
    }

    fn execute(&self, stage: usize, input: Tensor) -> Result<(Tensor, f64)> {
        self.inner.execute(stage, input)
    }

    // Replica methods forward too — relying on the trait defaults here
    // would hide an inner chain's replication behind the wrapper.
    fn replicas(&self, stage: usize) -> usize {
        self.inner.replicas(stage)
    }

    fn replica_node_id(&self, stage: usize, replica: usize) -> usize {
        self.inner.replica_node_id(stage, replica)
    }

    fn replica_alive(&self, stage: usize, replica: usize) -> bool {
        self.inner.replica_alive(stage, replica)
    }

    fn comm_in_on(&self, stage: usize, replica: usize, bytes: u64) -> f64 {
        self.inner.comm_in_on(stage, replica, bytes)
    }

    fn execute_on(
        &self,
        stage: usize,
        replica: usize,
        input: Tensor,
    ) -> Result<(Tensor, f64)> {
        self.inner.execute_on(stage, replica, input)
    }
}

impl<S: StageExec> Transport for InprocTransport<S> {
    fn kind(&self) -> TransportKind {
        TransportKind::Inproc
    }

    fn endpoint(&self, _stage: usize) -> String {
        "inproc".to_string()
    }
}

/// Reply slots for requests in flight on one connection, keyed by seq.
type PendingMap = Mutex<HashMap<u64, SyncSender<Result<(Tensor, f64)>>>>;

fn pending_lock(
    p: &PendingMap,
) -> MutexGuard<'_, HashMap<u64, SyncSender<Result<(Tensor, f64)>>>> {
    // Holders only insert/remove; a poisoned map is still consistent
    // enough to drain during teardown.
    match p.lock() {
        Ok(g) => g,
        Err(e) => e.into_inner(),
    }
}

/// Mark a connection dead and fail every request still in flight on it.
/// `dead` is flipped *before* the drain: a sender that inserts its slot
/// after the drain is guaranteed to observe the flag (the pending-map
/// mutex orders the two) and reclaims the slot instead of waiting on a
/// reply that will never come.
fn fail_conn(dead: &AtomicBool, pending: &PendingMap, why: &str) {
    dead.store(true, Ordering::Release);
    for (_, tx) in pending_lock(pending).drain() {
        let _ = tx.send(Err(anyhow::anyhow!("{why}")));
    }
}

/// Per-connection reply pump: reads frames off the socket and routes
/// each to the caller waiting on its seq. A stage-level `ExecuteErr`
/// fails only that batch (the connection stays healthy); any protocol
/// violation or socket error kills the connection and fails everything
/// still in flight.
fn reader_loop(
    mut stream: WireStream,
    pending: Arc<PendingMap>,
    dead: Arc<AtomicBool>,
    stage: usize,
    endpoint: String,
) {
    loop {
        match frame::read_frame(&mut stream) {
            Ok(Frame::ExecuteOk { seq, compute_ms, tensor }) => {
                match pending_lock(&pending).remove(&seq) {
                    Some(tx) => {
                        let _ = tx.send(Ok((tensor, compute_ms)));
                    }
                    None => {
                        tensor.recycle();
                        fail_conn(
                            &dead,
                            &pending,
                            &format!(
                                "stage {stage}: agent at {endpoint} answered \
                                 unknown seq {seq}"
                            ),
                        );
                        stream.shutdown();
                        return;
                    }
                }
            }
            Ok(Frame::ExecuteErr { seq, message }) => {
                match pending_lock(&pending).remove(&seq) {
                    Some(tx) => {
                        let _ = tx.send(Err(anyhow::anyhow!(
                            "stage {stage} ({endpoint}): {message}"
                        )));
                    }
                    None => {
                        fail_conn(
                            &dead,
                            &pending,
                            &format!(
                                "stage {stage}: agent at {endpoint} errored \
                                 unknown seq {seq}"
                            ),
                        );
                        stream.shutdown();
                        return;
                    }
                }
            }
            Ok(other) => {
                fail_conn(
                    &dead,
                    &pending,
                    &format!(
                        "stage {stage}: unexpected {} frame from {endpoint}",
                        other.kind_name()
                    ),
                );
                stream.shutdown();
                return;
            }
            Err(e) => {
                fail_conn(
                    &dead,
                    &pending,
                    &format!(
                        "stage {stage}: agent at {endpoint} disconnected \
                         mid-batch: {e:#}"
                    ),
                );
                stream.shutdown();
                return;
            }
        }
    }
}

/// One coordinator-side replica connection.
///
/// The writer lock is held only while a frame is being written; replies
/// are matched to callers by seq via the [`reader_loop`] thread, so
/// concurrent `execute_on` calls pipeline on the socket instead of
/// serializing a full round-trip under one lock.
struct ReplicaConn {
    writer: Mutex<WireStream>,
    pending: Arc<PendingMap>,
    seq: AtomicU64,
    /// Set on any protocol/socket failure: later calls fail fast and
    /// every in-flight request is failed by [`fail_conn`].
    dead: Arc<AtomicBool>,
    node_id: usize,
    endpoint: String,
    /// Where this replica's agent listens and what was shipped to it —
    /// retained so [`WireStages::reconnect_dead`] can re-dial a
    /// returned agent and replay the identical deployment.
    addr: AgentAddr,
    spec: DeploySpec,
    reader: Option<JoinHandle<()>>,
}

impl ReplicaConn {
    fn start(
        stream: WireStream,
        spec: DeploySpec,
        stage: usize,
        replica: usize,
        addr: AgentAddr,
    ) -> Result<ReplicaConn> {
        let endpoint = addr.to_string();
        let reader_stream = stream.try_clone().with_context(|| {
            format!("cloning stage {stage} connection to {endpoint}")
        })?;
        let pending: Arc<PendingMap> = Arc::new(Mutex::new(HashMap::new()));
        let dead = Arc::new(AtomicBool::new(false));
        let reader = {
            let pending = Arc::clone(&pending);
            let dead = Arc::clone(&dead);
            let endpoint = endpoint.clone();
            std::thread::Builder::new()
                .name(format!("wire-read-{stage}.{replica}"))
                .spawn(move || {
                    reader_loop(reader_stream, pending, dead, stage, endpoint)
                })
                .context("spawning wire reader thread")?
        };
        Ok(ReplicaConn {
            writer: Mutex::new(stream),
            pending,
            seq: AtomicU64::new(0),
            dead,
            node_id: spec.node_id() as usize,
            endpoint,
            addr,
            spec,
            reader: Some(reader),
        })
    }

    fn writer_lock(&self) -> MutexGuard<'_, WireStream> {
        // A poisoned lock means a previous write panicked; the
        // connection is already marked dead, so the guard is safe to
        // reuse for teardown.
        match self.writer.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }
}

/// Handshake with the agent at `addr` and ship one stage's deployment.
/// Fails (with the agent's address in the error) if the agent is
/// unreachable, speaks the wrong protocol version, or rejects the
/// deployment.
fn dial_stage(
    addr: &AgentAddr,
    spec: &DeploySpec,
    stage: usize,
    timeout: Duration,
) -> Result<WireStream> {
    let mut stream = addr.connect_retry(timeout)?;
    frame::write_frame(&mut stream, &Frame::Hello { version: WIRE_VERSION })
        .with_context(|| format!("handshake with {addr}"))?;
    match frame::read_frame(&mut stream)
        .with_context(|| format!("handshake with {addr}"))?
    {
        Frame::HelloAck { version } if version == WIRE_VERSION => {}
        Frame::HelloAck { version } => bail!(
            "agent at {addr} speaks protocol v{version}, \
             coordinator needs v{WIRE_VERSION}"
        ),
        other => bail!(
            "agent at {addr} answered Hello with {}",
            other.kind_name()
        ),
    }
    let deploy = match spec {
        DeploySpec::Sim(s) => Frame::DeploySim(s.clone()),
        DeploySpec::Blocks(s) => Frame::DeployBlocks(s.clone()),
    };
    frame::write_frame(&mut stream, &deploy)
        .with_context(|| format!("deploying stage {stage} to {addr}"))?;
    match frame::read_frame(&mut stream)
        .with_context(|| format!("deploying stage {stage} to {addr}"))?
    {
        Frame::DeployAck { stage: acked } if acked == spec.stage() => {}
        Frame::DeployAck { stage: acked } => bail!(
            "agent at {addr} acked stage {acked}, expected {}",
            spec.stage()
        ),
        Frame::ExecuteErr { message, .. } => bail!(
            "agent at {addr} rejected stage {stage}: {message}"
        ),
        other => bail!(
            "agent at {addr} answered deploy with {}",
            other.kind_name()
        ),
    }
    Ok(stream)
}

/// Remote stage chain: each (stage, replica) is hosted by its own agent
/// connection (assigned round-robin over `addrs` in flattened order),
/// driven over the [`frame`] protocol.
///
/// `comm_in`/`comm_out` run against coordinator-side *mirror* nodes
/// built from the same specs the agents deployed, so the simulated link
/// accounting (and its paced sleeps) is identical to the in-process
/// chain — the wire replaces the compute hop, not the link model.
pub struct WireStages {
    kind: TransportKind,
    /// `conns[stage][replica]`; replica 0 is the stage's primary.
    conns: Vec<Vec<ReplicaConn>>,
    mirrors: Vec<VirtualNode>,
    /// Per-execute round-trip deadline. None (the default) blocks
    /// forever — bit-identical to the pre-deadline wire behavior. With
    /// a budget set, a round-trip that exceeds it marks the replica
    /// suspect (dead + socket severed) and fails that micro-batch so
    /// the engine can retry or route around it instead of hanging on a
    /// stalled-but-connected agent.
    execute_timeout: Option<Duration>,
}

impl WireStages {
    /// Dial agents and deploy a synthetic (sim) chain mirroring
    /// `SimStages::heterogeneous(cpu_shares, nominal_ms)`.
    pub fn connect_sim(
        addrs: &[AgentAddr],
        cpu_shares: &[f64],
        nominal_ms: f64,
        timeout: Duration,
    ) -> Result<WireStages> {
        WireStages::connect_sim_replicated(
            addrs,
            cpu_shares,
            nominal_ms,
            &vec![1; cpu_shares.len()],
            timeout,
        )
    }

    /// Replicated sim chain: stage `k` gets `replicas[k]` connections,
    /// each hosting the same transform on its own fresh virtual node
    /// (primaries keep node ids `0..n`, extras take sequential ids from
    /// `n` — the wire twin of `SimStages::with_replicas`).
    pub fn connect_sim_replicated(
        addrs: &[AgentAddr],
        cpu_shares: &[f64],
        nominal_ms: f64,
        replicas: &[usize],
        timeout: Duration,
    ) -> Result<WireStages> {
        anyhow::ensure!(
            replicas.len() == cpu_shares.len(),
            "need one replica count per stage ({} != {})",
            replicas.len(),
            cpu_shares.len()
        );
        let primaries = SimStageSpec::heterogeneous(cpu_shares, nominal_ms);
        let mut next_id = primaries.len() as u32;
        let mut specs = Vec::with_capacity(primaries.len());
        for (p, &r) in primaries.into_iter().zip(replicas) {
            anyhow::ensure!(r >= 1, "stage {} needs >= 1 replica", p.stage);
            let mut group = Vec::with_capacity(r);
            for _ in 1..r {
                let mut extra = p.clone();
                extra.node_id = next_id;
                extra.name = format!("sim-{next_id}");
                next_id += 1;
                group.push(DeploySpec::Sim(extra));
            }
            group.insert(0, DeploySpec::Sim(p));
            specs.push(group);
        }
        WireStages::connect_replicated(addrs, specs, timeout)
    }

    /// Dial agents and deploy real block-range stages.
    pub fn connect_blocks(
        addrs: &[AgentAddr],
        specs: Vec<BlockStageSpec>,
        timeout: Duration,
    ) -> Result<WireStages> {
        WireStages::connect(
            addrs,
            specs.into_iter().map(DeploySpec::Blocks).collect(),
            timeout,
        )
    }

    /// Dial one connection per stage (no replication), handshake, and
    /// ship each stage's deployment.
    pub fn connect(
        addrs: &[AgentAddr],
        specs: Vec<DeploySpec>,
        timeout: Duration,
    ) -> Result<WireStages> {
        WireStages::connect_replicated(
            addrs,
            specs.into_iter().map(|s| vec![s]).collect(),
            timeout,
        )
    }

    /// Dial one connection per (stage, replica) — `specs[k]` lists the
    /// per-replica deployments for stage `k`, replica 0 first — and
    /// start each connection's reply reader.
    pub fn connect_replicated(
        addrs: &[AgentAddr],
        specs: Vec<Vec<DeploySpec>>,
        timeout: Duration,
    ) -> Result<WireStages> {
        anyhow::ensure!(!addrs.is_empty(), "no agent addresses to connect to");
        anyhow::ensure!(!specs.is_empty(), "no stages to deploy");
        anyhow::ensure!(
            specs.iter().all(|g| !g.is_empty()),
            "every stage needs at least one replica spec"
        );
        let kind = match &addrs[0] {
            AgentAddr::Uds(_) => TransportKind::Uds,
            AgentAddr::Tcp(_) => TransportKind::Tcp,
        };
        let mut conns = Vec::with_capacity(specs.len());
        let mut mirrors = Vec::with_capacity(specs.len());
        let mut dialed = 0usize;
        for (i, group) in specs.into_iter().enumerate() {
            mirrors.push(group[0].virtual_node());
            let mut stage_conns = Vec::with_capacity(group.len());
            for (r, spec) in group.into_iter().enumerate() {
                let addr = &addrs[dialed % addrs.len()];
                dialed += 1;
                let stream = dial_stage(addr, &spec, i, timeout)?;
                stage_conns.push(ReplicaConn::start(
                    stream,
                    spec,
                    i,
                    r,
                    addr.clone(),
                )?);
            }
            conns.push(stage_conns);
        }
        Ok(WireStages { kind, conns, mirrors, execute_timeout: None })
    }

    /// Builder: bound every execute round-trip by `timeout` (None keeps
    /// the unbounded default).
    pub fn with_execute_timeout(mut self, timeout: Option<Duration>) -> WireStages {
        self.execute_timeout = timeout;
        self
    }

    /// The configured per-execute deadline, if any.
    pub fn execute_timeout(&self) -> Option<Duration> {
        self.execute_timeout
    }

    /// True if any replica connection has failed.
    pub fn any_dead(&self) -> bool {
        self.conns
            .iter()
            .flatten()
            .any(|c| c.dead.load(Ordering::Relaxed))
    }

    /// Endpoints hosting each replica of `stage` (replica 0 first).
    pub fn replica_endpoints(&self, stage: usize) -> Vec<String> {
        self.conns[stage].iter().map(|c| c.endpoint.clone()).collect()
    }

    /// Warm re-admission over the wire: re-dial every dead replica
    /// connection — an agent coming back is how a returned node
    /// re-enters the serving chain — and re-ship its original
    /// deployment, so a restarted agent hosts the identical stage.
    /// Returns how many connections were revived; an agent still
    /// unreachable leaves its connection dead (with a warning) so the
    /// caller can try again later.
    pub fn reconnect_dead(&mut self, timeout: Duration) -> usize {
        let dead_idx: Vec<(usize, usize)> = self
            .conns
            .iter()
            .enumerate()
            .flat_map(|(k, g)| {
                g.iter().enumerate().filter_map(move |(r, c)| {
                    c.dead.load(Ordering::Acquire).then_some((k, r))
                })
            })
            .collect();
        if dead_idx.is_empty() {
            return 0;
        }
        // Dial every dead agent concurrently: N dead agents cost the
        // heal watchdog one connect timeout, not N stacked timeouts.
        let fresh: Vec<Result<ReplicaConn>> = std::thread::scope(|scope| {
            let handles: Vec<_> = dead_idx
                .iter()
                .map(|&(k, r)| {
                    let conn = &self.conns[k][r];
                    scope.spawn(move || {
                        dial_stage(&conn.addr, &conn.spec, k, timeout).and_then(
                            |stream| {
                                ReplicaConn::start(
                                    stream,
                                    conn.spec.clone(),
                                    k,
                                    r,
                                    conn.addr.clone(),
                                )
                            },
                        )
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        Err(anyhow::anyhow!("reconnect dial thread panicked"))
                    })
                })
                .collect()
        });
        let mut revived = 0;
        for (&(k, r), fresh) in dead_idx.iter().zip(fresh) {
            let conn = &mut self.conns[k][r];
            match fresh {
                Ok(fresh) => {
                    let mut old = std::mem::replace(conn, fresh);
                    // The dead connection's reader already returned
                    // (it flips `dead` on its way out); joining just
                    // reaps the thread.
                    old.writer_lock().shutdown();
                    if let Some(reader) = old.reader.take() {
                        let _ = reader.join();
                    }
                    revived += 1;
                }
                Err(e) => crate::log_warn!(
                    "wire",
                    "stage {k} replica {r}: reconnect to {} failed: {e:#}",
                    conn.endpoint
                ),
            }
        }
        revived
    }
}

impl StageExec for WireStages {
    fn num_stages(&self) -> usize {
        self.conns.len()
    }

    fn node_id(&self, stage: usize) -> usize {
        self.conns[stage][0].node_id
    }

    fn comm_in(&self, stage: usize, bytes: u64) -> f64 {
        let prev = stage.checked_sub(1).map(|p| &self.mirrors[p]);
        node_comm_in(prev, &self.mirrors[stage], bytes)
    }

    fn comm_out(&self, bytes: u64) -> f64 {
        node_comm_out(self.mirrors.last(), bytes)
    }

    fn replicas(&self, stage: usize) -> usize {
        self.conns[stage].len()
    }

    fn replica_node_id(&self, stage: usize, replica: usize) -> usize {
        self.conns[stage][replica].node_id
    }

    fn replica_alive(&self, stage: usize, replica: usize) -> bool {
        !self.conns[stage][replica].dead.load(Ordering::Relaxed)
    }

    fn execute(&self, stage: usize, input: Tensor) -> Result<(Tensor, f64)> {
        self.execute_on(stage, 0, input)
    }

    fn execute_on(
        &self,
        stage: usize,
        replica: usize,
        input: Tensor,
    ) -> Result<(Tensor, f64)> {
        let conn = &self.conns[stage][replica];
        if conn.dead.load(Ordering::Acquire) {
            bail!(
                "stage {stage} replica {replica} agent at {} is gone; \
                 failing batch fast",
                conn.endpoint
            );
        }
        let seq = conn.seq.fetch_add(1, Ordering::Relaxed) + 1;
        let (tx, rx) = mpsc::sync_channel(1);
        pending_lock(&conn.pending).insert(seq, tx);
        // The reader may have died between the liveness check and the
        // insert. It drains `pending` after flipping `dead`, so either
        // it failed our slot (the reply is waiting in `rx`) or we
        // inserted after the drain — in which case the flag is visible
        // now and we must reclaim the slot ourselves.
        if conn.dead.load(Ordering::Acquire)
            && pending_lock(&conn.pending).remove(&seq).is_some()
        {
            bail!(
                "stage {stage} replica {replica} agent at {} is gone; \
                 failing batch fast",
                conn.endpoint
            );
        }
        let out = Frame::Execute { seq, tensor: input };
        {
            let mut stream = conn.writer_lock();
            if let Err(e) = frame::write_frame(&mut *stream, &out) {
                pending_lock(&conn.pending).remove(&seq);
                conn.dead.store(true, Ordering::Release);
                stream.shutdown();
                return Err(e.context(format!(
                    "stage {stage}: sending activation to {}",
                    conn.endpoint
                )));
            }
        }
        // The activation made it onto the wire; hand its buffer back to
        // the pool (no-op for views into a shared TensorBuf).
        if let Frame::Execute { tensor, .. } = out {
            tensor.recycle();
        }
        // The reader routes our reply (or the connection's death) here.
        let Some(deadline) = self.execute_timeout else {
            return match rx.recv() {
                Ok(res) => res,
                Err(_) => bail!(
                    "stage {stage} replica {replica}: agent at {} disconnected \
                     mid-batch",
                    conn.endpoint
                ),
            };
        };
        match rx.recv_timeout(deadline) {
            Ok(res) => res,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // The round-trip blew its budget: a stalled-but-connected
                // agent. Reclaim our slot, mark the replica suspect, and
                // sever the socket so the reader fails everything else
                // in flight (reconnect_dead / the heal ladder can revive
                // it later).
                let had_slot = pending_lock(&conn.pending).remove(&seq).is_some();
                if !had_slot {
                    // The reply raced the deadline: the reader already
                    // claimed our slot, so the result is (or is about to
                    // be) in the channel. Take it instead of killing a
                    // healthy connection.
                    if let Ok(res) = rx.recv_timeout(Duration::from_millis(50)) {
                        return res;
                    }
                }
                fail_conn(
                    &conn.dead,
                    &conn.pending,
                    &format!(
                        "stage {stage}: agent at {} exceeded the {deadline:?} \
                         execute deadline",
                        conn.endpoint
                    ),
                );
                conn.writer_lock().shutdown();
                bail!(
                    "stage {stage} replica {replica}: no reply from {} within \
                     {deadline:?}; marking replica suspect",
                    conn.endpoint
                )
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => bail!(
                "stage {stage} replica {replica}: agent at {} disconnected \
                 mid-batch",
                conn.endpoint
            ),
        }
    }
}

impl Transport for WireStages {
    fn kind(&self) -> TransportKind {
        self.kind
    }

    fn endpoint(&self, stage: usize) -> String {
        self.conns[stage][0].endpoint.clone()
    }
}

impl Drop for WireStages {
    /// Tell each agent we're done (so idle agents can exit), drop the
    /// sockets, and reap the reader threads. Dead connections skip the
    /// goodbye but still get their reader joined.
    fn drop(&mut self) {
        for group in &mut self.conns {
            for conn in group.iter_mut() {
                {
                    let mut stream = conn.writer_lock();
                    if !conn.dead.load(Ordering::Relaxed) {
                        let _ = frame::write_frame(&mut *stream, &Frame::Shutdown);
                    }
                    stream.shutdown();
                }
                if let Some(reader) = conn.reader.take() {
                    let _ = reader.join();
                }
            }
        }
    }
}

/// Everything the server needs to (re)build a wire-backed stage chain
/// when a deployment is created or replaced.
#[derive(Debug, Clone)]
pub struct WireConfig {
    pub kind: TransportKind,
    pub addrs: Vec<AgentAddr>,
    pub params: SimParams,
    /// Artifacts directory the *agents* load blocks from (shipped in
    /// each deploy order; agents resolve it locally).
    pub artifacts_dir: PathBuf,
    /// How long to keep dialing an agent before giving up.
    pub connect_timeout: Duration,
    /// Per-execute round-trip deadline applied to every rebuilt chain
    /// (None = wait forever, the pre-deadline behavior).
    pub execute_timeout: Option<Duration>,
}

impl WireConfig {
    pub fn new(
        kind: TransportKind,
        addrs: Vec<AgentAddr>,
        params: SimParams,
        artifacts_dir: PathBuf,
    ) -> WireConfig {
        WireConfig {
            kind,
            addrs,
            params,
            artifacts_dir,
            connect_timeout: Duration::from_secs(10),
            execute_timeout: None,
        }
    }
}

/// Translate a local [`Deployment`] into per-stage deploy orders an
/// agent can replay: same node spec, same block range, same memory
/// reservation — so the agent-side chain is the remote twin of the
/// in-process one.
pub fn block_specs_for(
    dep: &Deployment,
    params: &SimParams,
    artifacts_dir: &Path,
) -> Vec<BlockStageSpec> {
    dep.stages
        .iter()
        .enumerate()
        .map(|(i, stage)| {
            block_spec(i, &stage.node, stage, dep, params, artifacts_dir)
        })
        .collect()
}

/// Per-stage deploy-spec *groups* for a (possibly replicated)
/// deployment: group `k` carries one `DeploySpec::Blocks` per replica
/// of stage `k`, primary first, each on its own node. With singleton
/// stages this is exactly [`block_specs_for`] wrapped per stage — feed
/// the result to [`WireStages::connect_replicated`].
pub fn block_spec_groups_for(
    dep: &Deployment,
    params: &SimParams,
    artifacts_dir: &Path,
) -> Vec<Vec<DeploySpec>> {
    dep.stages
        .iter()
        .enumerate()
        .map(|(i, stage)| {
            (0..stage.replica_count())
                .map(|r| {
                    DeploySpec::Blocks(block_spec(
                        i,
                        stage.replica_node(r),
                        stage,
                        dep,
                        params,
                        artifacts_dir,
                    ))
                })
                .collect()
        })
        .collect()
}

fn block_spec(
    stage_idx: usize,
    node: &crate::cluster::VirtualNode,
    stage: &crate::deployer::Stage,
    dep: &Deployment,
    params: &SimParams,
    artifacts_dir: &Path,
) -> BlockStageSpec {
    let spec = node.spec();
    BlockStageSpec {
        stage: stage_idx as u32,
        node_id: node.id() as u32,
        name: spec.name.clone(),
        cpu_fraction: spec.cpu_fraction,
        mem_limit_mb: spec.mem_limit_mb,
        link_latency_ms: spec.link.latency_ms,
        link_bandwidth_mbps: spec.link.bandwidth_mbps,
        time_scale: params.time_scale,
        page_factor: params.page_factor,
        runtime_overhead_mb: params.runtime_overhead_mb,
        artifacts_dir: artifacts_dir.display().to_string(),
        block_start: stage.block_range.start as u32,
        block_end: stage.block_range.end as u32,
        batch: dep.batch as u32,
        mem_reserve: stage.mem_reserved,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_kind_parses() {
        assert_eq!(TransportKind::parse("inproc").unwrap(), TransportKind::Inproc);
        assert_eq!(TransportKind::parse("uds").unwrap(), TransportKind::Uds);
        assert_eq!(TransportKind::parse("unix").unwrap(), TransportKind::Uds);
        assert_eq!(TransportKind::parse("tcp").unwrap(), TransportKind::Tcp);
        let err = TransportKind::parse("carrier-pigeon").unwrap_err().to_string();
        assert!(err.contains("inproc"), "{err}");
    }

    #[test]
    fn agent_addr_parse_errors_are_actionable() {
        let err = AgentAddr::parse(TransportKind::Inproc, "/tmp/a.sock")
            .unwrap_err()
            .to_string();
        assert!(err.contains("takes no agent addresses"), "{err}");
        let err = AgentAddr::parse(TransportKind::Tcp, "localhost")
            .unwrap_err()
            .to_string();
        assert!(err.contains("host:port"), "{err}");
        assert!(AgentAddr::parse(TransportKind::Uds, "  ").is_err());
        assert_eq!(
            AgentAddr::parse(TransportKind::Uds, "/tmp/a.sock").unwrap(),
            AgentAddr::Uds(PathBuf::from("/tmp/a.sock"))
        );
        assert_eq!(
            AgentAddr::parse(TransportKind::Tcp, "127.0.0.1:7070").unwrap(),
            AgentAddr::Tcp("127.0.0.1:7070".to_string())
        );
    }

    #[test]
    fn connect_retry_times_out_with_address_in_error() {
        let addr = AgentAddr::Uds(PathBuf::from("/tmp/amp4ec-no-such-agent.sock"));
        let err = addr
            .connect_retry(Duration::from_millis(30))
            .unwrap_err()
            .to_string();
        assert!(err.contains("amp4ec-no-such-agent"), "{err}");
    }
}
