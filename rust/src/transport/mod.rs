//! Pluggable transport layer: coordinator <-> node-agent plumbing.
//!
//! The pipeline engine drives stages through the [`StageExec`] seam and
//! never cares where a stage runs. This module supplies the two ends of
//! that seam for distributed deployments:
//!
//! * [`InprocTransport`] — the default: pure delegation to any local
//!   [`StageExec`] chain, zero added copies, bit-identical to calling
//!   the chain directly.
//! * [`WireStages`] — each stage is hosted by a remote node agent
//!   ([`agent::NodeAgent`], the `amp4ec node` subcommand) and driven
//!   over a length-prefixed binary protocol ([`frame`]) on a Unix
//!   domain socket or TCP connection.
//!
//! The engine runs one driver thread per stage, so `WireStages` keeps
//! one connection per stage (agents are assigned round-robin when there
//! are fewer agents than stages) and serializes the blocking
//! request/response round-trip per connection — pipeline parallelism
//! across stages is preserved exactly as in-process. A dropped
//! connection fails the in-flight `execute` (the engine maps that to a
//! per-batch failure) and marks the stage dead so later micro-batches
//! fail fast instead of hanging.

pub mod agent;
pub mod frame;

use std::fmt;
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::cluster::{SimParams, VirtualNode};
use crate::deployer::Deployment;
use crate::pipeline::engine::{node_comm_in, node_comm_out, StageExec};
use crate::runtime::Tensor;

use frame::{BlockStageSpec, DeploySpec, Frame, SimStageSpec, WIRE_VERSION};

/// Which transport carries stage traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// Stages run in the coordinator process (the default).
    Inproc,
    /// Stages run in node agents reached over Unix domain sockets.
    Uds,
    /// Stages run in node agents reached over TCP.
    Tcp,
}

impl TransportKind {
    pub fn parse(s: &str) -> Result<TransportKind> {
        match s {
            "inproc" => Ok(TransportKind::Inproc),
            "uds" | "unix" => Ok(TransportKind::Uds),
            "tcp" => Ok(TransportKind::Tcp),
            other => bail!(
                "unknown transport `{other}` (expected `inproc`, `uds`, or `tcp`)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Inproc => "inproc",
            TransportKind::Uds => "uds",
            TransportKind::Tcp => "tcp",
        }
    }
}

impl fmt::Display for TransportKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Where one node agent listens.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AgentAddr {
    Uds(PathBuf),
    Tcp(String),
}

impl AgentAddr {
    /// Parse an address for the given transport kind, with actionable
    /// errors (e.g. a TCP address missing its port).
    pub fn parse(kind: TransportKind, s: &str) -> Result<AgentAddr> {
        let s = s.trim();
        anyhow::ensure!(!s.is_empty(), "empty agent address");
        match kind {
            TransportKind::Inproc => bail!(
                "transport `inproc` takes no agent addresses; drop `agents` \
                 or set the transport to uds/tcp"
            ),
            TransportKind::Uds => Ok(AgentAddr::Uds(PathBuf::from(s))),
            TransportKind::Tcp => {
                anyhow::ensure!(
                    s.contains(':'),
                    "tcp agent address `{s}` must be host:port"
                );
                Ok(AgentAddr::Tcp(s.to_string()))
            }
        }
    }

    /// One connection attempt.
    pub fn connect(&self) -> Result<WireStream> {
        match self {
            AgentAddr::Uds(path) => {
                let s = UnixStream::connect(path).with_context(|| {
                    format!("connecting to agent at uds:{}", path.display())
                })?;
                Ok(WireStream::Unix(s))
            }
            AgentAddr::Tcp(addr) => {
                let s = TcpStream::connect(addr.as_str())
                    .with_context(|| format!("connecting to agent at tcp:{addr}"))?;
                // Activation frames are latency-sensitive round-trips.
                let _ = s.set_nodelay(true);
                Ok(WireStream::Tcp(s))
            }
        }
    }

    /// Poll-connect until `timeout` elapses — agents may still be
    /// binding their listener when the coordinator starts dialing.
    pub fn connect_retry(&self, timeout: Duration) -> Result<WireStream> {
        let start = Instant::now();
        loop {
            match self.connect() {
                Ok(s) => return Ok(s),
                Err(e) if start.elapsed() >= timeout => {
                    return Err(e.context(format!(
                        "agent at {self} not reachable within {timeout:?}"
                    )));
                }
                Err(_) => std::thread::sleep(Duration::from_millis(10)),
            }
        }
    }
}

impl fmt::Display for AgentAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AgentAddr::Uds(p) => write!(f, "uds:{}", p.display()),
            AgentAddr::Tcp(a) => write!(f, "tcp:{a}"),
        }
    }
}

/// One connected socket of either flavor.
#[derive(Debug)]
pub enum WireStream {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl WireStream {
    pub fn try_clone(&self) -> io::Result<WireStream> {
        match self {
            WireStream::Unix(s) => s.try_clone().map(WireStream::Unix),
            WireStream::Tcp(s) => s.try_clone().map(WireStream::Tcp),
        }
    }

    /// Shut down both directions; errors (already-closed peers) are
    /// ignored — this is only ever a best-effort unblock/teardown.
    pub fn shutdown(&self) {
        match self {
            WireStream::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            WireStream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Read for WireStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            WireStream::Unix(s) => s.read(buf),
            WireStream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for WireStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            WireStream::Unix(s) => s.write(buf),
            WireStream::Tcp(s) => s.write(buf),
        }
    }

    fn write_vectored(&mut self, bufs: &[io::IoSlice<'_>]) -> io::Result<usize> {
        match self {
            WireStream::Unix(s) => s.write_vectored(bufs),
            WireStream::Tcp(s) => s.write_vectored(bufs),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            WireStream::Unix(s) => s.flush(),
            WireStream::Tcp(s) => s.flush(),
        }
    }
}

/// A [`StageExec`] whose stages may live behind a transport. The engine
/// only sees `StageExec`; this trait adds the introspection the server
/// and CLI report need.
pub trait Transport: StageExec {
    fn kind(&self) -> TransportKind;
    /// Human-readable endpoint hosting `stage` (e.g. `inproc`,
    /// `uds:/tmp/a.sock`).
    fn endpoint(&self, stage: usize) -> String;
}

/// The default transport: pure delegation to a local chain. No added
/// copies, no added locks — bit-identical to driving `inner` directly.
pub struct InprocTransport<S: StageExec> {
    inner: S,
}

impl<S: StageExec> InprocTransport<S> {
    pub fn new(inner: S) -> InprocTransport<S> {
        InprocTransport { inner }
    }

    pub fn inner(&self) -> &S {
        &self.inner
    }
}

impl<S: StageExec> StageExec for InprocTransport<S> {
    fn num_stages(&self) -> usize {
        self.inner.num_stages()
    }

    fn node_id(&self, stage: usize) -> usize {
        self.inner.node_id(stage)
    }

    fn backlog(&self, stage: usize) -> usize {
        self.inner.backlog(stage)
    }

    fn comm_in(&self, stage: usize, bytes: u64) -> f64 {
        self.inner.comm_in(stage, bytes)
    }

    fn comm_out(&self, bytes: u64) -> f64 {
        self.inner.comm_out(bytes)
    }

    fn execute(&self, stage: usize, input: Tensor) -> Result<(Tensor, f64)> {
        self.inner.execute(stage, input)
    }
}

impl<S: StageExec> Transport for InprocTransport<S> {
    fn kind(&self) -> TransportKind {
        TransportKind::Inproc
    }

    fn endpoint(&self, _stage: usize) -> String {
        "inproc".to_string()
    }
}

/// One coordinator-side stage connection.
struct StageConn {
    stream: Mutex<WireStream>,
    seq: AtomicU64,
    /// Set on any protocol/socket failure: later `execute` calls fail
    /// fast instead of writing into a broken pipe.
    dead: AtomicBool,
    node_id: usize,
    endpoint: String,
}

impl StageConn {
    fn lock(&self) -> MutexGuard<'_, WireStream> {
        // A poisoned lock means a previous round-trip panicked; the
        // connection is already marked dead, so the guard is safe to
        // reuse for teardown.
        match self.stream.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }
}

/// Remote stage chain: stage `i` is hosted by the agent at
/// `addrs[i % addrs.len()]`, driven over the [`frame`] protocol.
///
/// `comm_in`/`comm_out` run against coordinator-side *mirror* nodes
/// built from the same specs the agents deployed, so the simulated link
/// accounting (and its paced sleeps) is identical to the in-process
/// chain — the wire replaces the compute hop, not the link model.
pub struct WireStages {
    kind: TransportKind,
    conns: Vec<StageConn>,
    mirrors: Vec<VirtualNode>,
}

impl WireStages {
    /// Dial agents and deploy a synthetic (sim) chain mirroring
    /// `SimStages::heterogeneous(cpu_shares, nominal_ms)`.
    pub fn connect_sim(
        addrs: &[AgentAddr],
        cpu_shares: &[f64],
        nominal_ms: f64,
        timeout: Duration,
    ) -> Result<WireStages> {
        let specs = SimStageSpec::heterogeneous(cpu_shares, nominal_ms)
            .into_iter()
            .map(DeploySpec::Sim)
            .collect();
        WireStages::connect(addrs, specs, timeout)
    }

    /// Dial agents and deploy real block-range stages.
    pub fn connect_blocks(
        addrs: &[AgentAddr],
        specs: Vec<BlockStageSpec>,
        timeout: Duration,
    ) -> Result<WireStages> {
        WireStages::connect(
            addrs,
            specs.into_iter().map(DeploySpec::Blocks).collect(),
            timeout,
        )
    }

    /// Dial one connection per stage, handshake, and ship the stage's
    /// deployment. Fails (with the agent's address in the error) if any
    /// agent is unreachable, speaks the wrong protocol version, or
    /// rejects its deployment.
    pub fn connect(
        addrs: &[AgentAddr],
        specs: Vec<DeploySpec>,
        timeout: Duration,
    ) -> Result<WireStages> {
        anyhow::ensure!(!addrs.is_empty(), "no agent addresses to connect to");
        anyhow::ensure!(!specs.is_empty(), "no stages to deploy");
        let kind = match &addrs[0] {
            AgentAddr::Uds(_) => TransportKind::Uds,
            AgentAddr::Tcp(_) => TransportKind::Tcp,
        };
        let mut conns = Vec::with_capacity(specs.len());
        let mut mirrors = Vec::with_capacity(specs.len());
        for (i, spec) in specs.into_iter().enumerate() {
            let addr = &addrs[i % addrs.len()];
            let mut stream = addr.connect_retry(timeout)?;
            frame::write_frame(&mut stream, &Frame::Hello { version: WIRE_VERSION })
                .with_context(|| format!("handshake with {addr}"))?;
            match frame::read_frame(&mut stream)
                .with_context(|| format!("handshake with {addr}"))?
            {
                Frame::HelloAck { version } if version == WIRE_VERSION => {}
                Frame::HelloAck { version } => bail!(
                    "agent at {addr} speaks protocol v{version}, \
                     coordinator needs v{WIRE_VERSION}"
                ),
                other => bail!(
                    "agent at {addr} answered Hello with {}",
                    other.kind_name()
                ),
            }
            let deploy = match &spec {
                DeploySpec::Sim(s) => Frame::DeploySim(s.clone()),
                DeploySpec::Blocks(s) => Frame::DeployBlocks(s.clone()),
            };
            frame::write_frame(&mut stream, &deploy)
                .with_context(|| format!("deploying stage {i} to {addr}"))?;
            match frame::read_frame(&mut stream)
                .with_context(|| format!("deploying stage {i} to {addr}"))?
            {
                Frame::DeployAck { stage } if stage == spec.stage() => {}
                Frame::DeployAck { stage } => bail!(
                    "agent at {addr} acked stage {stage}, expected {}",
                    spec.stage()
                ),
                Frame::ExecuteErr { message, .. } => bail!(
                    "agent at {addr} rejected stage {i}: {message}"
                ),
                other => bail!(
                    "agent at {addr} answered deploy with {}",
                    other.kind_name()
                ),
            }
            mirrors.push(spec.virtual_node());
            conns.push(StageConn {
                stream: Mutex::new(stream),
                seq: AtomicU64::new(0),
                dead: AtomicBool::new(false),
                node_id: spec.node_id() as usize,
                endpoint: addr.to_string(),
            });
        }
        Ok(WireStages { kind, conns, mirrors })
    }

    /// True if any stage connection has failed.
    pub fn any_dead(&self) -> bool {
        self.conns.iter().any(|c| c.dead.load(Ordering::Relaxed))
    }
}

impl StageExec for WireStages {
    fn num_stages(&self) -> usize {
        self.conns.len()
    }

    fn node_id(&self, stage: usize) -> usize {
        self.conns[stage].node_id
    }

    fn comm_in(&self, stage: usize, bytes: u64) -> f64 {
        let prev = stage.checked_sub(1).map(|p| &self.mirrors[p]);
        node_comm_in(prev, &self.mirrors[stage], bytes)
    }

    fn comm_out(&self, bytes: u64) -> f64 {
        node_comm_out(self.mirrors.last(), bytes)
    }

    fn execute(&self, stage: usize, input: Tensor) -> Result<(Tensor, f64)> {
        let conn = &self.conns[stage];
        if conn.dead.load(Ordering::Acquire) {
            bail!(
                "stage {stage} agent at {} is gone; failing batch fast",
                conn.endpoint
            );
        }
        let seq = conn.seq.fetch_add(1, Ordering::Relaxed) + 1;
        let mut stream = conn.lock();
        let out = Frame::Execute { seq, tensor: input };
        if let Err(e) = frame::write_frame(&mut *stream, &out) {
            conn.dead.store(true, Ordering::Release);
            stream.shutdown();
            return Err(e.context(format!(
                "stage {stage}: sending activation to {}",
                conn.endpoint
            )));
        }
        // The activation made it onto the wire; hand its buffer back to
        // the pool (no-op for views into a shared TensorBuf).
        if let Frame::Execute { tensor, .. } = out {
            tensor.recycle();
        }
        match frame::read_frame(&mut *stream) {
            Ok(Frame::ExecuteOk { seq: rseq, compute_ms, tensor }) => {
                if rseq != seq {
                    conn.dead.store(true, Ordering::Release);
                    stream.shutdown();
                    bail!(
                        "stage {stage}: agent at {} answered seq {rseq}, \
                         expected {seq}",
                        conn.endpoint
                    );
                }
                Ok((tensor, compute_ms))
            }
            // A stage-level error is a per-batch failure: the
            // connection stays healthy for subsequent micro-batches.
            Ok(Frame::ExecuteErr { seq: rseq, message }) if rseq == seq => {
                bail!("stage {stage} ({}): {message}", conn.endpoint)
            }
            Ok(other) => {
                conn.dead.store(true, Ordering::Release);
                stream.shutdown();
                bail!(
                    "stage {stage}: unexpected {} frame from {}",
                    other.kind_name(),
                    conn.endpoint
                )
            }
            Err(e) => {
                conn.dead.store(true, Ordering::Release);
                stream.shutdown();
                Err(e.context(format!(
                    "stage {stage}: agent at {} disconnected mid-batch",
                    conn.endpoint
                )))
            }
        }
    }
}

impl Transport for WireStages {
    fn kind(&self) -> TransportKind {
        self.kind
    }

    fn endpoint(&self, stage: usize) -> String {
        self.conns[stage].endpoint.clone()
    }
}

impl Drop for WireStages {
    /// Tell each agent we're done (so idle agents can exit) and drop
    /// the sockets. Dead connections are skipped.
    fn drop(&mut self) {
        for conn in &self.conns {
            if conn.dead.load(Ordering::Relaxed) {
                continue;
            }
            let mut stream = conn.lock();
            let _ = frame::write_frame(&mut *stream, &Frame::Shutdown);
            stream.shutdown();
        }
    }
}

/// Everything the server needs to (re)build a wire-backed stage chain
/// when a deployment is created or replaced.
#[derive(Debug, Clone)]
pub struct WireConfig {
    pub kind: TransportKind,
    pub addrs: Vec<AgentAddr>,
    pub params: SimParams,
    /// Artifacts directory the *agents* load blocks from (shipped in
    /// each deploy order; agents resolve it locally).
    pub artifacts_dir: PathBuf,
    /// How long to keep dialing an agent before giving up.
    pub connect_timeout: Duration,
}

impl WireConfig {
    pub fn new(
        kind: TransportKind,
        addrs: Vec<AgentAddr>,
        params: SimParams,
        artifacts_dir: PathBuf,
    ) -> WireConfig {
        WireConfig {
            kind,
            addrs,
            params,
            artifacts_dir,
            connect_timeout: Duration::from_secs(10),
        }
    }
}

/// Translate a local [`Deployment`] into per-stage deploy orders an
/// agent can replay: same node spec, same block range, same memory
/// reservation — so the agent-side chain is the remote twin of the
/// in-process one.
pub fn block_specs_for(
    dep: &Deployment,
    params: &SimParams,
    artifacts_dir: &Path,
) -> Vec<BlockStageSpec> {
    dep.stages
        .iter()
        .enumerate()
        .map(|(i, stage)| {
            let spec = stage.node.spec();
            BlockStageSpec {
                stage: i as u32,
                node_id: stage.node.id() as u32,
                name: spec.name.clone(),
                cpu_fraction: spec.cpu_fraction,
                mem_limit_mb: spec.mem_limit_mb,
                link_latency_ms: spec.link.latency_ms,
                link_bandwidth_mbps: spec.link.bandwidth_mbps,
                time_scale: params.time_scale,
                page_factor: params.page_factor,
                runtime_overhead_mb: params.runtime_overhead_mb,
                artifacts_dir: artifacts_dir.display().to_string(),
                block_start: stage.block_range.start as u32,
                block_end: stage.block_range.end as u32,
                batch: dep.batch as u32,
                mem_reserve: stage.mem_reserved,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_kind_parses() {
        assert_eq!(TransportKind::parse("inproc").unwrap(), TransportKind::Inproc);
        assert_eq!(TransportKind::parse("uds").unwrap(), TransportKind::Uds);
        assert_eq!(TransportKind::parse("unix").unwrap(), TransportKind::Uds);
        assert_eq!(TransportKind::parse("tcp").unwrap(), TransportKind::Tcp);
        let err = TransportKind::parse("carrier-pigeon").unwrap_err().to_string();
        assert!(err.contains("inproc"), "{err}");
    }

    #[test]
    fn agent_addr_parse_errors_are_actionable() {
        let err = AgentAddr::parse(TransportKind::Inproc, "/tmp/a.sock")
            .unwrap_err()
            .to_string();
        assert!(err.contains("takes no agent addresses"), "{err}");
        let err = AgentAddr::parse(TransportKind::Tcp, "localhost")
            .unwrap_err()
            .to_string();
        assert!(err.contains("host:port"), "{err}");
        assert!(AgentAddr::parse(TransportKind::Uds, "  ").is_err());
        assert_eq!(
            AgentAddr::parse(TransportKind::Uds, "/tmp/a.sock").unwrap(),
            AgentAddr::Uds(PathBuf::from("/tmp/a.sock"))
        );
        assert_eq!(
            AgentAddr::parse(TransportKind::Tcp, "127.0.0.1:7070").unwrap(),
            AgentAddr::Tcp("127.0.0.1:7070".to_string())
        );
    }

    #[test]
    fn connect_retry_times_out_with_address_in_error() {
        let addr = AgentAddr::Uds(PathBuf::from("/tmp/amp4ec-no-such-agent.sock"));
        let err = addr
            .connect_retry(Duration::from_millis(30))
            .unwrap_err()
            .to_string();
        assert!(err.contains("amp4ec-no-such-agent"), "{err}");
    }
}
