//! Network link model: latency + bandwidth with rx/tx accounting.
//!
//! Each virtual node has one link to the edge LAN (the Docker bridge
//! analogue). Transfers between the leader and a node — activations moving
//! through the partition pipeline, weight payloads during deployment —
//! sleep out `latency + bytes/bandwidth` and are counted in the node's
//! `network I/O` stats, mirroring Docker's `rx_bytes`/`tx_bytes`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Link characteristics.
#[derive(Debug, Clone)]
pub struct LinkSpec {
    pub latency_ms: f64,
    pub bandwidth_mbps: f64,
}

impl Default for LinkSpec {
    fn default() -> Self {
        // A realistic edge LAN: 1 ms, 1 Gbps.
        LinkSpec { latency_ms: 1.0, bandwidth_mbps: 1000.0 }
    }
}

impl LinkSpec {
    pub fn new(latency_ms: f64, bandwidth_mbps: f64) -> LinkSpec {
        LinkSpec { latency_ms, bandwidth_mbps }
    }

    /// Pure model: transfer time for `bytes`, in ms.
    pub fn transfer_ms(&self, bytes: u64) -> f64 {
        let bits = bytes as f64 * 8.0;
        self.latency_ms + bits / (self.bandwidth_mbps * 1e3)
    }
}

/// A live link with traffic counters.
pub struct NetworkLink {
    spec: LinkSpec,
    rx_bytes: AtomicU64,
    tx_bytes: AtomicU64,
}

impl NetworkLink {
    pub fn new(spec: LinkSpec) -> NetworkLink {
        NetworkLink {
            spec,
            rx_bytes: AtomicU64::new(0),
            tx_bytes: AtomicU64::new(0),
        }
    }

    pub fn spec(&self) -> &LinkSpec {
        &self.spec
    }

    /// Simulate receiving `bytes` into this node; sleeps the model time.
    /// Returns the delay in ms.
    pub fn receive(&self, bytes: u64) -> f64 {
        let ms = self.spec.transfer_ms(bytes);
        std::thread::sleep(Duration::from_secs_f64(ms / 1e3));
        self.rx_bytes.fetch_add(bytes, Ordering::SeqCst);
        ms
    }

    /// Simulate sending `bytes` from this node; sleeps the model time.
    pub fn send(&self, bytes: u64) -> f64 {
        let ms = self.spec.transfer_ms(bytes);
        std::thread::sleep(Duration::from_secs_f64(ms / 1e3));
        self.tx_bytes.fetch_add(bytes, Ordering::SeqCst);
        ms
    }

    /// Account traffic without sleeping (used when the caller aggregates
    /// delay itself, e.g. batched deployment transfers).
    pub fn account(&self, rx: u64, tx: u64) {
        self.rx_bytes.fetch_add(rx, Ordering::SeqCst);
        self.tx_bytes.fetch_add(tx, Ordering::SeqCst);
    }

    pub fn totals(&self) -> (u64, u64) {
        (
            self.rx_bytes.load(Ordering::SeqCst),
            self.tx_bytes.load(Ordering::SeqCst),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_model() {
        let l = LinkSpec::new(2.0, 100.0); // 100 Mbps
        // 1 MB = 8e6 bits -> 80 ms + 2 ms latency.
        let ms = l.transfer_ms(1_000_000);
        assert!((ms - 82.0).abs() < 1e-9, "{ms}");
        // Zero bytes still pays latency.
        assert_eq!(l.transfer_ms(0), 2.0);
    }

    #[test]
    fn counters_accumulate() {
        let link = NetworkLink::new(LinkSpec::new(0.0, 1e9));
        link.receive(100);
        link.send(50);
        link.account(7, 3);
        assert_eq!(link.totals(), (107, 53));
    }

    #[test]
    fn receive_sleeps_model_time() {
        let link = NetworkLink::new(LinkSpec::new(10.0, 1e9));
        let t = std::time::Instant::now();
        let ms = link.receive(0);
        assert!(ms >= 10.0);
        assert!(t.elapsed().as_millis() >= 9);
    }

    #[test]
    fn default_is_fast_lan() {
        let l = LinkSpec::default();
        assert!(l.transfer_ms(4 * 96 * 96 * 4) < 2.5); // one activation ~1.3ms
    }
}
