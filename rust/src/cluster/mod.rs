//! Virtual edge cluster — the Docker-container substitute (DESIGN.md
//! "Substitutions").
//!
//! The paper evaluates AMP4EC on Docker containers with cgroup CPU quotas
//! (`--cpu-quota`/`--cpu-period`) and memory limits (`--memory`), bridged
//! networks with controlled latency. This module reproduces those resource
//! semantics in-process:
//!
//!  * **CPU quota** — a [`node::VirtualNode`] executes work serially (one
//!    device) and stretches measured host compute time by `1/cpu_fraction`
//!    (a 0.4-CPU node takes 2.5x as long as the host), exactly what a
//!    cgroup quota does to a CPU-bound container over time scales larger
//!    than the period;
//!  * **memory limit** — a working-set accountant; exceeding the limit
//!    applies a paging penalty multiplier (the container analogue is the
//!    kernel reclaiming/major-faulting, which degrades rather than kills
//!    until the OOM threshold);
//!  * **network** — per-node [`link::NetworkLink`] with latency and
//!    bandwidth; transfers sleep `latency + bytes/bandwidth` and count
//!    rx/tx bytes (the Docker stats `network I/O` metric).
//!
//! All of the paper's resource ratios (1.0/0.6/0.4 CPU; 1GB/512MB) are
//! expressed through these knobs, so scheduler and partitioner behaviour
//! is preserved while runs stay deterministic and laptop-sized.

pub mod energy;
pub mod link;
pub mod node;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

pub use energy::{EnergyMeter, EnergyReading, PowerModel};
pub use link::{LinkSpec, NetworkLink};
pub use node::{ExecOutcome, NodeSnapshot, NodeSpec, VirtualNode};

/// Cluster-wide simulation parameters.
#[derive(Debug, Clone)]
pub struct SimParams {
    /// Multiplier applied to all simulated compute time. 1.0 = host speed;
    /// larger values emulate weaker edge silicon than the build host.
    pub time_scale: f64,
    /// Paging penalty slope: effective time *= 1 + page_factor * overflow
    /// where overflow = (working_set - limit) / limit, when over the limit.
    pub page_factor: f64,
    /// Fixed per-node runtime footprint (the PyTorch-container analogue;
    /// the paper's 512MB nodes were mostly full of framework overhead).
    pub runtime_overhead_mb: f64,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            time_scale: 1.0,
            page_factor: 4.0,
            runtime_overhead_mb: 384.0,
        }
    }
}

/// Stable node identifier (survives add/remove cycles).
pub type NodeId = usize;

/// A dynamic collection of virtual edge nodes.
///
/// Nodes are added/removed at runtime (the paper's "new device added" /
/// "device offline" scenarios); removal marks the node offline so inflight
/// bookkeeping stays valid, and the monitor stops reporting it.
pub struct Cluster {
    params: SimParams,
    nodes: RwLock<Vec<Arc<VirtualNode>>>,
    next_id: AtomicUsize,
    /// Bumped on every membership *change* (add, offline, re-admission).
    /// Watchers compare epochs instead of online counts: an equal-count
    /// leave+join changes membership without changing the count, and
    /// only the epoch sees it.
    epoch: AtomicU64,
}

impl Cluster {
    pub fn new(params: SimParams) -> Cluster {
        Cluster {
            params,
            nodes: RwLock::new(Vec::new()),
            next_id: AtomicUsize::new(0),
            epoch: AtomicU64::new(0),
        }
    }

    pub fn params(&self) -> &SimParams {
        &self.params
    }

    /// Add a node; returns its id.
    pub fn add_node(&self, spec: NodeSpec) -> NodeId {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let node = Arc::new(VirtualNode::new(id, spec, self.params.clone()));
        self.nodes.write().unwrap().push(node);
        self.epoch.fetch_add(1, Ordering::SeqCst);
        id
    }

    /// Mark a node offline (the "device offline" event). Returns false if
    /// the id is unknown.
    pub fn remove_node(&self, id: NodeId) -> bool {
        let nodes = self.nodes.read().unwrap();
        match nodes.iter().find(|n| n.id() == id) {
            Some(n) => {
                if n.is_online() {
                    n.set_online(false);
                    self.epoch.fetch_add(1, Ordering::SeqCst);
                }
                true
            }
            None => false,
        }
    }

    /// Warm re-admission: bring a previously removed node back online
    /// (the "device returns" event). The node keeps its id, loaded
    /// blocks, and working set, so the next heal/retune can hand it a
    /// replica without a cold deploy. Returns false if the id is
    /// unknown; re-admitting an already-online node is a no-op.
    pub fn readmit_node(&self, id: NodeId) -> bool {
        let nodes = self.nodes.read().unwrap();
        match nodes.iter().find(|n| n.id() == id) {
            Some(n) => {
                if !n.is_online() {
                    n.set_online(true);
                    self.epoch.fetch_add(1, Ordering::SeqCst);
                }
                true
            }
            None => false,
        }
    }

    /// Membership epoch: increments on every add, offline transition,
    /// and re-admission. Equal epochs guarantee an unchanged member
    /// set; an equal *online count* does not.
    pub fn membership_epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    pub fn get(&self, id: NodeId) -> Option<Arc<VirtualNode>> {
        self.nodes
            .read()
            .unwrap()
            .iter()
            .find(|n| n.id() == id)
            .cloned()
    }

    /// All nodes ever added (including offline ones).
    pub fn all_nodes(&self) -> Vec<Arc<VirtualNode>> {
        self.nodes.read().unwrap().clone()
    }

    /// Currently-online nodes, the scheduler's candidate set.
    pub fn online_nodes(&self) -> Vec<Arc<VirtualNode>> {
        self.nodes
            .read()
            .unwrap()
            .iter()
            .filter(|n| n.is_online())
            .cloned()
            .collect()
    }

    pub fn online_count(&self) -> usize {
        self.nodes
            .read()
            .unwrap()
            .iter()
            .filter(|n| n.is_online())
            .count()
    }
}

/// The paper's three resource profiles (§IV-A Table II).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Profile {
    High,   // 1.0 CPU, 1 GB
    Medium, // 0.6 CPU, 512 MB
    Low,    // 0.4 CPU, 512 MB
}

impl Profile {
    pub fn spec(&self) -> NodeSpec {
        match self {
            Profile::High => NodeSpec::new("high", 1.0, 1024.0),
            Profile::Medium => NodeSpec::new("medium", 0.6, 512.0),
            Profile::Low => NodeSpec::new("low", 0.4, 512.0),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Profile::High => "High",
            Profile::Medium => "Medium",
            Profile::Low => "Low",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_remove_nodes() {
        let c = Cluster::new(SimParams::default());
        let a = c.add_node(NodeSpec::new("a", 1.0, 1024.0));
        let b = c.add_node(NodeSpec::new("b", 0.5, 512.0));
        assert_eq!(c.online_count(), 2);
        assert!(c.remove_node(a));
        assert_eq!(c.online_count(), 1);
        assert_eq!(c.online_nodes()[0].id(), b);
        assert!(!c.remove_node(99));
        // removed node still reachable for bookkeeping
        assert!(c.get(a).is_some());
        assert!(!c.get(a).unwrap().is_online());
    }

    #[test]
    fn membership_epoch_sees_equal_count_leave_plus_join() {
        // The auto-rebalance watchdog regression: a simultaneous
        // leave+join keeps online_count() constant but changes the
        // member set — only the epoch notices.
        let c = Cluster::new(SimParams::default());
        let a = c.add_node(NodeSpec::new("a", 1.0, 1024.0));
        c.add_node(NodeSpec::new("b", 0.5, 512.0));
        let count_before = c.online_count();
        let epoch_before = c.membership_epoch();
        assert!(c.remove_node(a));
        c.add_node(NodeSpec::new("c", 0.5, 512.0));
        assert_eq!(c.online_count(), count_before, "count is blind");
        assert!(
            c.membership_epoch() > epoch_before,
            "epoch must advance on an equal-count membership change"
        );
        // Idempotent transitions don't churn the epoch.
        let e = c.membership_epoch();
        assert!(c.remove_node(a)); // already offline
        assert_eq!(c.membership_epoch(), e);
    }

    #[test]
    fn readmit_restores_node_and_bumps_epoch() {
        let c = Cluster::new(SimParams::default());
        let a = c.add_node(NodeSpec::new("a", 1.0, 1024.0));
        c.remove_node(a);
        assert_eq!(c.online_count(), 0);
        let e = c.membership_epoch();
        assert!(c.readmit_node(a));
        assert_eq!(c.online_count(), 1);
        assert!(c.get(a).unwrap().is_online());
        assert!(c.membership_epoch() > e);
        // Re-admitting an online node is a no-op; unknown ids are false.
        let e2 = c.membership_epoch();
        assert!(c.readmit_node(a));
        assert_eq!(c.membership_epoch(), e2);
        assert!(!c.readmit_node(99));
    }

    #[test]
    fn ids_are_stable_and_unique() {
        let c = Cluster::new(SimParams::default());
        let a = c.add_node(NodeSpec::new("a", 1.0, 512.0));
        c.remove_node(a);
        let b = c.add_node(NodeSpec::new("b", 1.0, 512.0));
        assert_ne!(a, b);
    }

    #[test]
    fn profiles_match_paper() {
        let h = Profile::High.spec();
        assert_eq!(h.cpu_fraction, 1.0);
        assert_eq!(h.mem_limit_mb, 1024.0);
        let l = Profile::Low.spec();
        assert_eq!(l.cpu_fraction, 0.4);
        assert_eq!(l.mem_limit_mb, 512.0);
    }
}
