//! Energy model (paper §V future work: "energy-aware resource
//! allocation").
//!
//! Per-node energy accounting over a simple but standard two-state model:
//!
//! ```text
//! E = P_idle * T_total + (P_busy - P_idle) * T_busy * cpu_fraction
//! ```
//!
//! with per-byte network energy added for rx/tx traffic. Powers default to
//! representative edge-SBC numbers (Raspberry Pi 4 class: ~2.7 W idle,
//! ~6.4 W loaded; ~20 nJ/byte for the NIC path). The energy-aware
//! scheduler extension scores candidates by predicted energy cost, and
//! `benches/ablation.rs` compares placements under latency-optimal vs
//! energy-optimal weights.

use std::sync::Mutex;
use std::time::Instant;

/// Static power characteristics of a node.
#[derive(Debug, Clone, Copy)]
pub struct PowerModel {
    pub idle_watts: f64,
    pub busy_watts: f64,
    /// Joules per byte moved through the NIC (rx or tx).
    pub net_joules_per_byte: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel {
            idle_watts: 2.7,
            busy_watts: 6.4,
            net_joules_per_byte: 20e-9,
        }
    }
}

impl PowerModel {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.idle_watts >= 0.0, "idle watts must be >= 0");
        anyhow::ensure!(
            self.busy_watts >= self.idle_watts,
            "busy watts must be >= idle watts"
        );
        anyhow::ensure!(
            self.net_joules_per_byte >= 0.0,
            "net energy must be >= 0"
        );
        Ok(())
    }

    /// Marginal energy (J) of `busy_ms` of compute at `cpu_fraction`.
    pub fn compute_joules(&self, busy_ms: f64, cpu_fraction: f64) -> f64 {
        (self.busy_watts - self.idle_watts) * (busy_ms / 1e3)
            * cpu_fraction.min(1.0)
    }

    pub fn network_joules(&self, bytes: u64) -> f64 {
        bytes as f64 * self.net_joules_per_byte
    }
}

/// Running energy account for one node.
pub struct EnergyMeter {
    model: PowerModel,
    cpu_fraction: f64,
    state: Mutex<MeterState>,
}

struct MeterState {
    started: Instant,
    busy_ms: f64,
    net_bytes: u64,
}

/// Snapshot of accumulated energy.
#[derive(Debug, Clone, Copy)]
pub struct EnergyReading {
    /// Total joules including idle floor.
    pub total_j: f64,
    /// Marginal joules attributable to compute.
    pub compute_j: f64,
    /// Marginal joules attributable to network traffic.
    pub network_j: f64,
    pub busy_ms: f64,
}

impl EnergyMeter {
    pub fn new(model: PowerModel, cpu_fraction: f64) -> EnergyMeter {
        EnergyMeter {
            model,
            cpu_fraction,
            state: Mutex::new(MeterState {
                started: Instant::now(),
                busy_ms: 0.0,
                net_bytes: 0,
            }),
        }
    }

    pub fn model(&self) -> &PowerModel {
        &self.model
    }

    pub fn note_busy(&self, busy_ms: f64) {
        self.state.lock().unwrap().busy_ms += busy_ms;
    }

    pub fn note_network(&self, bytes: u64) {
        self.state.lock().unwrap().net_bytes += bytes;
    }

    pub fn reading(&self) -> EnergyReading {
        self.reading_with_net(0) // internal counter only
    }

    /// Reading with externally-tracked network bytes (the virtual node
    /// reuses its link counters instead of double-counting).
    pub fn reading_with_net(&self, net_bytes: u64) -> EnergyReading {
        let s = self.state.lock().unwrap();
        let wall_s = s.started.elapsed().as_secs_f64();
        let compute_j =
            self.model.compute_joules(s.busy_ms, self.cpu_fraction);
        let network_j =
            self.model.network_joules(net_bytes + s.net_bytes);
        EnergyReading {
            total_j: self.model.idle_watts * wall_s + compute_j + network_j,
            compute_j,
            network_j,
            busy_ms: s.busy_ms,
        }
    }

    /// Predicted marginal energy (J) of running `est_ms` of compute plus
    /// `bytes` of traffic on this node — the energy-aware scheduler's
    /// scoring input.
    pub fn predict_task_joules(&self, est_ms: f64, bytes: u64) -> f64 {
        self.model.compute_joules(est_ms, self.cpu_fraction)
            + self.model.network_joules(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_model_sane() {
        let m = PowerModel::default();
        m.validate().unwrap();
        assert!(m.busy_watts > m.idle_watts);
    }

    #[test]
    fn compute_energy_scales_with_time_and_cpu() {
        let m = PowerModel { idle_watts: 2.0, busy_watts: 6.0,
                             net_joules_per_byte: 0.0 };
        // 1 s busy at full core: 4 J marginal.
        assert!((m.compute_joules(1000.0, 1.0) - 4.0).abs() < 1e-9);
        // Quota'd node burns proportionally less.
        assert!((m.compute_joules(1000.0, 0.4) - 1.6).abs() < 1e-9);
    }

    #[test]
    fn meter_accumulates() {
        let meter = EnergyMeter::new(
            PowerModel { idle_watts: 0.0, busy_watts: 5.0,
                         net_joules_per_byte: 1e-6 },
            1.0,
        );
        meter.note_busy(2000.0);
        meter.note_network(1_000_000);
        let r = meter.reading();
        assert!((r.compute_j - 10.0).abs() < 1e-9);
        assert!((r.network_j - 1.0).abs() < 1e-9);
        assert!(r.total_j >= r.compute_j + r.network_j);
    }

    #[test]
    fn prediction_matches_model() {
        let meter = EnergyMeter::new(PowerModel::default(), 0.6);
        let j = meter.predict_task_joules(500.0, 10_000);
        let expect = PowerModel::default().compute_joules(500.0, 0.6)
            + PowerModel::default().network_joules(10_000);
        assert!((j - expect).abs() < 1e-12);
    }

    #[test]
    fn invalid_models_rejected() {
        assert!(PowerModel { idle_watts: 5.0, busy_watts: 2.0,
                             net_joules_per_byte: 0.0 }
            .validate()
            .is_err());
        assert!(PowerModel { idle_watts: -1.0, busy_watts: 2.0,
                             net_joules_per_byte: 0.0 }
            .validate()
            .is_err());
    }
}
