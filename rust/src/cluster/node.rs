//! A virtual edge node: serial execution, CPU-quota time dilation, memory
//! accounting with paging penalty, load/stability tracking.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::energy::{EnergyMeter, EnergyReading, PowerModel};
use super::link::{LinkSpec, NetworkLink};
use super::SimParams;

/// Static description of a node's resources (the `docker run` flags).
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub name: String,
    /// CPU share, (0, 1]: 0.4 == `--cpu-quota 40000 --cpu-period 100000`.
    pub cpu_fraction: f64,
    /// Memory limit in MB (`--memory`).
    pub mem_limit_mb: f64,
    /// Network link to the edge LAN.
    pub link: LinkSpec,
    /// Probability an execution fails (failure injection for robustness
    /// tests); 0 by default.
    pub fail_rate: f64,
    /// Power characteristics for the energy meter (§V energy-aware
    /// extension).
    pub power: PowerModel,
}

impl NodeSpec {
    pub fn new(name: &str, cpu_fraction: f64, mem_limit_mb: f64) -> NodeSpec {
        NodeSpec {
            name: name.to_string(),
            cpu_fraction,
            mem_limit_mb,
            link: LinkSpec::default(),
            fail_rate: 0.0,
            power: PowerModel::default(),
        }
    }

    pub fn with_link(mut self, link: LinkSpec) -> NodeSpec {
        self.link = link;
        self
    }

    pub fn with_fail_rate(mut self, p: f64) -> NodeSpec {
        self.fail_rate = p;
        self
    }

    pub fn with_power(mut self, power: PowerModel) -> NodeSpec {
        self.power = power;
        self
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.cpu_fraction > 0.0 && self.cpu_fraction <= 8.0,
            "cpu_fraction {} out of range (0, 8]",
            self.cpu_fraction
        );
        anyhow::ensure!(self.mem_limit_mb > 0.0, "mem_limit_mb must be > 0");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.fail_rate),
            "fail_rate must be in [0, 1]"
        );
        self.power.validate()?;
        Ok(())
    }
}

/// Timing breakdown of one execution on a node.
#[derive(Debug, Clone, Copy)]
pub struct ExecOutcome {
    /// Host wall time actually spent computing.
    pub host_ms: f64,
    /// Simulated edge time (host * 1/cpu * time_scale * mem penalty),
    /// which is also the real wall time the call took (we sleep the gap).
    pub sim_ms: f64,
    /// The memory-paging multiplier that was in effect ( >= 1 ).
    pub mem_penalty: f64,
}

/// Point-in-time resource reading (the Docker stats API analogue).
#[derive(Debug, Clone)]
pub struct NodeSnapshot {
    pub id: usize,
    pub name: String,
    pub online: bool,
    pub cpu_fraction: f64,
    pub mem_limit_mb: f64,
    /// Fraction of recent wall time the node was busy, in [0, 1].
    pub current_load: f64,
    pub mem_used_mb: f64,
    pub mem_pct: f64,
    pub rx_bytes: u64,
    pub tx_bytes: u64,
    pub tasks_completed: u64,
    pub tasks_failed: u64,
    /// 1.0 = perfectly stable; decays with failures.
    pub stability: f64,
    pub link_latency_ms: f64,
}

/// Mutable interior state guarded by a mutex (cold path only).
struct Inner {
    /// EWMA of busy fraction.
    load: f64,
    last_update: Instant,
    busy_since_update_ms: f64,
}

/// A simulated edge device. Execution is serialized (one inference device
/// per node, like one container running one model server).
pub struct VirtualNode {
    id: usize,
    spec: NodeSpec,
    params: SimParams,
    online: AtomicBool,
    /// Memory working set currently reserved, in bytes.
    mem_used: AtomicU64,
    tasks_completed: AtomicU64,
    tasks_failed: AtomicU64,
    /// Serialized execution (the single "device").
    exec_lock: Mutex<()>,
    inner: Mutex<Inner>,
    link: NetworkLink,
    energy: EnergyMeter,
    /// Deterministic failure-injection stream.
    fail_stream: Mutex<crate::util::rng::Rng>,
}

impl VirtualNode {
    pub fn new(id: usize, spec: NodeSpec, params: SimParams) -> VirtualNode {
        let link = NetworkLink::new(spec.link.clone());
        let energy = EnergyMeter::new(spec.power, spec.cpu_fraction);
        let seed = 0x5EED ^ (id as u64).wrapping_mul(0x9E3779B97F4A7C15);
        VirtualNode {
            id,
            spec,
            params,
            online: AtomicBool::new(true),
            mem_used: AtomicU64::new(0),
            tasks_completed: AtomicU64::new(0),
            tasks_failed: AtomicU64::new(0),
            exec_lock: Mutex::new(()),
            inner: Mutex::new(Inner {
                load: 0.0,
                last_update: Instant::now(),
                busy_since_update_ms: 0.0,
            }),
            link,
            energy,
            fail_stream: Mutex::new(crate::util::rng::Rng::new(seed)),
        }
    }

    pub fn id(&self) -> usize {
        self.id
    }

    pub fn name(&self) -> &str {
        &self.spec.name
    }

    pub fn spec(&self) -> &NodeSpec {
        &self.spec
    }

    pub fn link(&self) -> &NetworkLink {
        &self.link
    }

    /// Accumulated energy (compute + idle floor + NIC traffic).
    pub fn energy(&self) -> EnergyReading {
        let (rx, tx) = self.link.totals();
        self.energy.reading_with_net(rx + tx)
    }

    /// Predicted marginal joules of a prospective task on this node.
    pub fn predict_task_joules(&self, est_ms: f64, bytes: u64) -> f64 {
        self.energy.predict_task_joules(est_ms, bytes)
    }

    pub fn is_online(&self) -> bool {
        self.online.load(Ordering::SeqCst)
    }

    pub fn set_online(&self, v: bool) {
        self.online.store(v, Ordering::SeqCst);
    }

    // -- memory accounting ---------------------------------------------

    /// Reserve working-set bytes (weights, activations). Never rejects —
    /// like a cgroup, exceeding the limit *degrades* (paging penalty)
    /// rather than failing outright; the deployer checks capacity before
    /// placing partitions.
    pub fn mem_reserve(&self, bytes: u64) {
        self.mem_used.fetch_add(bytes, Ordering::SeqCst);
    }

    pub fn mem_release(&self, bytes: u64) {
        // Saturating: double-release is a bug but must not wrap.
        let mut cur = self.mem_used.load(Ordering::SeqCst);
        loop {
            let next = cur.saturating_sub(bytes);
            match self.mem_used.compare_exchange(
                cur,
                next,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => break,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Working set including the fixed runtime overhead, in MB.
    pub fn mem_working_set_mb(&self) -> f64 {
        self.mem_used.load(Ordering::SeqCst) as f64 / (1024.0 * 1024.0)
            + self.params.runtime_overhead_mb
    }

    /// Current paging-penalty multiplier (1.0 when under the limit).
    pub fn mem_penalty(&self) -> f64 {
        let ws = self.mem_working_set_mb();
        let limit = self.spec.mem_limit_mb;
        if ws <= limit {
            1.0
        } else {
            1.0 + self.params.page_factor * (ws - limit) / limit
        }
    }

    /// Headroom check used by the scheduler's `has_sufficient_resources`.
    pub fn mem_available_mb(&self) -> f64 {
        (self.spec.mem_limit_mb - self.mem_working_set_mb()).max(0.0)
    }

    // -- execution -------------------------------------------------------

    /// Run `work` on this node's (single) device, applying the CPU-quota
    /// time dilation and the current memory penalty. Returns the work's
    /// output plus the timing breakdown, or an injected failure.
    ///
    /// The dilation basis is the *wall* time of `work`; when the caller
    /// can measure a contention-free compute cost (thread CPU time of an
    /// executor thread), prefer [`VirtualNode::execute_costed`].
    pub fn execute<T>(
        &self,
        work: impl FnOnce() -> anyhow::Result<T>,
    ) -> anyhow::Result<(T, ExecOutcome)> {
        self.execute_costed(|| {
            let t0 = Instant::now();
            let out = work()?;
            Ok((out, t0.elapsed().as_secs_f64() * 1e3))
        })
    }

    /// Like [`VirtualNode::execute`], but `work` reports its own nominal
    /// compute cost in ms (e.g. executor-thread CPU time). The simulated
    /// edge time is `cost / cpu_fraction * time_scale * mem_penalty`; the
    /// call sleeps out whatever wall time that exceeds, so concurrent
    /// stages on a contended build host are not double-penalized.
    pub fn execute_costed<T>(
        &self,
        work: impl FnOnce() -> anyhow::Result<(T, f64)>,
    ) -> anyhow::Result<(T, ExecOutcome)> {
        anyhow::ensure!(self.is_online(), "node {} is offline", self.spec.name);
        let _guard = self.exec_lock.lock().unwrap();
        // Failure injection (deterministic per node).
        if self.spec.fail_rate > 0.0
            && self.fail_stream.lock().unwrap().chance(self.spec.fail_rate)
        {
            self.tasks_failed.fetch_add(1, Ordering::SeqCst);
            anyhow::bail!("injected failure on node {}", self.spec.name);
        }

        let start = Instant::now();
        let out = work();
        let wall_ms = start.elapsed().as_secs_f64() * 1e3;

        let mem_penalty = self.mem_penalty();
        let (result, host_ms) = match out {
            Ok((v, cost)) => (Ok(v), cost),
            Err(e) => (Err(e), wall_ms),
        };
        let sim_ms = host_ms / self.spec.cpu_fraction
            * self.params.time_scale
            * mem_penalty;
        // Sleep out the remainder so wall time == simulated edge time.
        let gap = sim_ms - wall_ms;
        if gap > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(gap / 1e3));
        }

        self.note_busy(sim_ms.max(wall_ms));
        self.energy.note_busy(sim_ms.max(wall_ms));
        match result {
            Ok(v) => {
                self.tasks_completed.fetch_add(1, Ordering::SeqCst);
                Ok((v, ExecOutcome { host_ms, sim_ms, mem_penalty }))
            }
            Err(e) => {
                self.tasks_failed.fetch_add(1, Ordering::SeqCst);
                Err(e)
            }
        }
    }

    /// Record busy time into the load EWMA.
    fn note_busy(&self, busy_ms: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.busy_since_update_ms += busy_ms;
        self.refresh_load(&mut inner);
    }

    /// Fold accumulated busy time into the EWMA load. Called on both the
    /// execution path and the monitor's sampling path.
    fn refresh_load(&self, inner: &mut Inner) {
        let elapsed_ms =
            inner.last_update.elapsed().as_secs_f64() * 1e3;
        if elapsed_ms < 1.0 {
            return; // avoid division blowups on tight loops
        }
        let inst = (inner.busy_since_update_ms / elapsed_ms).min(1.0);
        const ALPHA: f64 = 0.4;
        inner.load = ALPHA * inst + (1.0 - ALPHA) * inner.load;
        inner.busy_since_update_ms = 0.0;
        inner.last_update = Instant::now();
    }

    /// EWMA busy fraction in [0, 1] — the scheduler's `current_load`.
    pub fn current_load(&self) -> f64 {
        let mut inner = self.inner.lock().unwrap();
        self.refresh_load(&mut inner);
        inner.load
    }

    /// Stability score: success ratio with full credit when idle.
    pub fn stability(&self) -> f64 {
        let ok = self.tasks_completed.load(Ordering::SeqCst) as f64;
        let bad = self.tasks_failed.load(Ordering::SeqCst) as f64;
        if ok + bad == 0.0 {
            1.0
        } else {
            ok / (ok + bad)
        }
    }

    pub fn snapshot(&self) -> NodeSnapshot {
        let (rx, tx) = self.link.totals();
        NodeSnapshot {
            id: self.id,
            name: self.spec.name.clone(),
            online: self.is_online(),
            cpu_fraction: self.spec.cpu_fraction,
            mem_limit_mb: self.spec.mem_limit_mb,
            current_load: self.current_load(),
            mem_used_mb: self.mem_working_set_mb(),
            mem_pct: (self.mem_working_set_mb() / self.spec.mem_limit_mb
                * 100.0)
                .min(100.0),
            rx_bytes: rx,
            tx_bytes: tx,
            tasks_completed: self.tasks_completed.load(Ordering::SeqCst),
            tasks_failed: self.tasks_failed.load(Ordering::SeqCst),
            stability: self.stability(),
            link_latency_ms: self.spec.link.latency_ms,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node(cpu: f64, mem: f64) -> VirtualNode {
        let params = SimParams {
            time_scale: 1.0,
            page_factor: 4.0,
            runtime_overhead_mb: 0.0,
        };
        VirtualNode::new(0, NodeSpec::new("t", cpu, mem), params)
    }

    fn busy_work(ms: u64) -> anyhow::Result<u64> {
        let t = Instant::now();
        let mut x = 0u64;
        while t.elapsed() < Duration::from_millis(ms) {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        }
        Ok(x)
    }

    #[test]
    fn cpu_quota_dilates_time() {
        let half = node(0.5, 1024.0);
        let t = Instant::now();
        let (_, outcome) = half.execute(|| busy_work(20)).unwrap();
        let wall = t.elapsed().as_secs_f64() * 1e3;
        // 20ms of host work at 0.5 CPU => ~40ms simulated & ~40ms wall.
        assert!(outcome.sim_ms >= 1.9 * outcome.host_ms,
                "sim {} host {}", outcome.sim_ms, outcome.host_ms);
        assert!(wall >= 0.9 * outcome.sim_ms);
    }

    #[test]
    fn full_cpu_adds_no_dilation() {
        let full = node(1.0, 1024.0);
        let (_, outcome) = full.execute(|| busy_work(5)).unwrap();
        assert!((outcome.sim_ms - outcome.host_ms).abs() < 1.0);
        assert_eq!(outcome.mem_penalty, 1.0);
    }

    #[test]
    fn memory_penalty_applies_over_limit() {
        let n = node(1.0, 100.0);
        n.mem_reserve(150 * 1024 * 1024);
        assert!(n.mem_penalty() > 1.0);
        let (_, outcome) = n.execute(|| busy_work(5)).unwrap();
        assert!(outcome.mem_penalty > 1.0);
        assert!(outcome.sim_ms > outcome.host_ms * 1.5);
        n.mem_release(150 * 1024 * 1024);
        assert_eq!(n.mem_penalty(), 1.0);
    }

    #[test]
    fn mem_release_saturates() {
        let n = node(1.0, 100.0);
        n.mem_release(10);
        assert_eq!(n.mem_working_set_mb(), 0.0);
    }

    #[test]
    fn offline_node_rejects_work() {
        let n = node(1.0, 100.0);
        n.set_online(false);
        assert!(n.execute(|| Ok(())).is_err());
    }

    #[test]
    fn load_rises_under_work_and_decays_idle() {
        let n = node(1.0, 1024.0);
        for _ in 0..5 {
            n.execute(|| busy_work(10)).unwrap();
        }
        let busy_load = n.current_load();
        assert!(busy_load > 0.2, "load {busy_load}");
        std::thread::sleep(Duration::from_millis(120));
        let idle_load = n.current_load();
        assert!(idle_load < busy_load);
    }

    #[test]
    fn failure_injection_counts() {
        let params = SimParams::default();
        let spec = NodeSpec::new("f", 1.0, 1024.0).with_fail_rate(1.0);
        let n = VirtualNode::new(1, spec, params);
        assert!(n.execute(|| Ok(())).is_err());
        assert_eq!(n.snapshot().tasks_failed, 1);
        assert_eq!(n.stability(), 0.0);
    }

    #[test]
    fn stability_reflects_success_ratio() {
        let n = node(1.0, 1024.0);
        assert_eq!(n.stability(), 1.0);
        n.execute(|| Ok(())).unwrap();
        assert_eq!(n.stability(), 1.0);
    }

    #[test]
    fn snapshot_fields() {
        let n = node(0.6, 512.0);
        let s = n.snapshot();
        assert_eq!(s.cpu_fraction, 0.6);
        assert_eq!(s.mem_limit_mb, 512.0);
        assert!(s.online);
        assert_eq!(s.tasks_completed, 0);
    }
}
