//! Shared simulated-time accounting for the serial pipeline and the
//! streaming engine.
//!
//! Everything here is expressed in **simulated milliseconds** — the
//! virtual-edge clock produced by CPU-quota dilation
//! (`VirtualNode::execute_costed`) and the link transfer model
//! (`LinkSpec::transfer_ms`) — never host wall-clock. Mixing the two was
//! the seed's `total_ms` bug: a total measured with `Instant::elapsed()`
//! is machine-dependent and can even undercut its own simulated
//! components on a fast build host.
//!
//! The core is the classic pipeline critical-path recurrence. For
//! micro-batch *i* entering stage *k*:
//!
//! ```text
//! arrive[i, k] = ready[i, k-1] + comm[i, k]
//! start[i, k]  = max(arrive[i, k], stage_free[k])
//! ready[i, k]  = start[i, k] + compute[i, k]
//! ```
//!
//! where `stage_free[k]` is when stage *k*'s node finished its previous
//! micro-batch (each virtual node executes serially). A serial,
//! one-chunk traversal degenerates to `total = Σ comm + Σ compute`; a
//! streamed run's makespan is the true overlapped end-to-end time.

use crate::metrics::{ReplicaCounter, StageCounter};

/// Timing breakdown for one pipeline traversal (serial or streamed).
/// All fields are simulated milliseconds.
#[derive(Debug, Clone, Default)]
pub struct PipelineTiming {
    /// Simulated end-to-end critical-path time: when the last output row
    /// is back at the leader. For serial runs this equals
    /// `compute_ms + comm_ms` (pinned by a regression test); for
    /// streamed runs it is strictly less than that sum whenever stages
    /// overlap.
    pub total_ms: f64,
    /// Total simulated compute across all stages and micro-batches.
    pub compute_ms: f64,
    /// Total simulated communication (stage ingress + final hop back to
    /// the leader).
    pub comm_ms: f64,
    /// Per-stage aggregates (summed over micro-batches).
    pub stages: Vec<StageTiming>,
    /// Activation bytes moved between leader/nodes.
    pub activation_bytes: u64,
}

#[derive(Debug, Clone)]
pub struct StageTiming {
    pub stage: usize,
    pub node: usize,
    /// Simulated compute ms on this stage (summed over micro-batches).
    pub compute_ms: f64,
    /// Simulated ingress communication ms into this stage.
    pub comm_ms: f64,
}

/// Per-stage accumulator for the recurrence above.
#[derive(Debug, Clone, Default)]
struct Lane {
    /// When this stage's node finishes its current micro-batch.
    free_ms: f64,
    /// Σ compute over micro-batches.
    busy_ms: f64,
    /// Idle gaps between consecutive micro-batches (excludes the initial
    /// pipeline-fill wait before the first arrival).
    bubble_ms: f64,
    /// Σ ingress comm over micro-batches.
    comm_ms: f64,
    micro_batches: u64,
    /// Whether the stage has seen its first micro-batch (gates bubble
    /// accounting so pipeline fill is not counted as a bubble).
    fed: bool,
}

/// Per-step outcome of [`CriticalPath::step_detail`].
#[derive(Debug, Clone, Copy)]
pub struct StepDetail {
    /// Simulated time the stage began computing this micro-batch
    /// (`max(arrive, stage/node free)`).
    pub start_ms: f64,
    /// Simulated time the stage's output is ready.
    pub done_ms: f64,
    /// Idle gap this step opened at the stage (0 during pipeline fill
    /// and when the stage was still busy when the activation arrived).
    pub bubble_ms: f64,
}

/// Critical-path clock shared by `pipeline::run` and the streaming
/// engine. One instance accounts one traversal (any number of
/// micro-batches); stage drivers feed it in FIFO per-stage order, which
/// makes the accounting deterministic regardless of thread scheduling
/// when every stage has its own node. Admission gating lives one layer
/// up: the engine's credit windows time-stamp each admitted micro-batch
/// with the simulated instant its window slots freed (the max across
/// per-stage windows), and that value arrives here as stage 0's
/// `ready_in_ms` — the clock itself is window-agnostic. Stages that *share* a node (the
/// deployer's overcommit fallback when partitions outnumber nodes) are
/// additionally serialized on that node's clock — a single device
/// cannot overlap two stages — so the makespan never fabricates
/// overlap the hardware cannot deliver; in that shared case the
/// accounted order follows the node's actual serialization order.
#[derive(Debug, Clone)]
pub struct CriticalPath {
    /// `lanes[k][r]`: stage `k`, replica `r`. Unreplicated stages have a
    /// single lane, so every pre-replication schedule is the `r = 0`
    /// special case and accounts bit-identically.
    lanes: Vec<Vec<Lane>>,
    /// Node hosting each (stage, replica).
    node_of: Vec<Vec<usize>>,
    /// When each distinct node's single device frees up.
    node_free: std::collections::HashMap<usize, f64>,
    makespan_ms: f64,
    final_comm_ms: f64,
    activation_bytes: u64,
}

impl CriticalPath {
    /// `node_ids[k]` is the node hosting stage `k` (duplicates allowed —
    /// shared nodes serialize their stages). One lane per stage.
    pub fn new(node_ids: &[usize]) -> CriticalPath {
        let per_stage: Vec<Vec<usize>> =
            node_ids.iter().map(|&n| vec![n]).collect();
        Self::new_replicated(&per_stage)
    }

    /// Replicated constructor: `node_ids[k]` lists the node hosting each
    /// replica of stage `k` (must be non-empty per stage). Replicas on
    /// distinct nodes get independent device clocks — that independence
    /// is exactly where data-parallel fan-out earns its speedup — while
    /// replicas sharing a node still serialize through `node_free`.
    pub fn new_replicated(node_ids: &[Vec<usize>]) -> CriticalPath {
        assert!(
            node_ids.iter().all(|reps| !reps.is_empty()),
            "every stage needs >= 1 replica"
        );
        CriticalPath {
            lanes: node_ids
                .iter()
                .map(|reps| vec![Lane::default(); reps.len()])
                .collect(),
            node_of: node_ids.to_vec(),
            node_free: std::collections::HashMap::new(),
            makespan_ms: 0.0,
            final_comm_ms: 0.0,
            activation_bytes: 0,
        }
    }

    pub fn n_stages(&self) -> usize {
        self.lanes.len()
    }

    /// Replica count of `stage`.
    pub fn replicas(&self, stage: usize) -> usize {
        self.lanes[stage].len()
    }

    /// Account one micro-batch through `stage`. `ready_in_ms` is the
    /// simulated time the activation left the previous stage (0 for
    /// stage 0: the leader holds all micro-batches at t=0). Returns the
    /// simulated time the stage's output is ready.
    pub fn step(
        &mut self,
        stage: usize,
        ready_in_ms: f64,
        comm_ms: f64,
        compute_ms: f64,
        bytes: u64,
    ) -> f64 {
        self.step_detail(stage, ready_in_ms, comm_ms, compute_ms, bytes)
            .done_ms
    }

    /// Like [`CriticalPath::step`] but also reports the idle gap this
    /// step opened at the stage (0 during pipeline fill). The persistent
    /// engine uses the delta to attribute bubbles to individual batches
    /// while the lanes themselves accumulate across batch boundaries.
    pub fn step_detail(
        &mut self,
        stage: usize,
        ready_in_ms: f64,
        comm_ms: f64,
        compute_ms: f64,
        bytes: u64,
    ) -> StepDetail {
        self.step_detail_on(stage, 0, ready_in_ms, comm_ms, compute_ms, bytes)
    }

    /// [`CriticalPath::step_detail`] against a specific replica lane of
    /// `stage`. Replica 0 of an unreplicated stage is the plain path.
    pub fn step_detail_on(
        &mut self,
        stage: usize,
        replica: usize,
        ready_in_ms: f64,
        comm_ms: f64,
        compute_ms: f64,
        bytes: u64,
    ) -> StepDetail {
        let node = self.node_of[stage][replica];
        let node_free = self.node_free.get(&node).copied().unwrap_or(0.0);
        let lane = &mut self.lanes[stage][replica];
        let arrive = ready_in_ms + comm_ms;
        let floor = lane.free_ms.max(node_free);
        let mut bubble = 0.0;
        let start = if arrive > floor {
            if lane.fed {
                bubble = arrive - floor;
                lane.bubble_ms += bubble;
            }
            arrive
        } else {
            floor
        };
        let done = start + compute_ms;
        lane.free_ms = done;
        lane.busy_ms += compute_ms;
        lane.comm_ms += comm_ms;
        lane.micro_batches += 1;
        lane.fed = true;
        self.node_free.insert(node, done);
        self.activation_bytes += bytes;
        self.makespan_ms = self.makespan_ms.max(done);
        StepDetail { start_ms: start, done_ms: done, bubble_ms: bubble }
    }

    /// Account the final hop of one micro-batch back to the leader.
    /// Returns the simulated delivery time.
    pub fn deliver(&mut self, comm_ms: f64, bytes: u64, ready_ms: f64) -> f64 {
        self.final_comm_ms += comm_ms;
        self.activation_bytes += bytes;
        let done = ready_ms + comm_ms;
        self.makespan_ms = self.makespan_ms.max(done);
        done
    }

    /// Simulated end-to-end time: last delivery back at the leader.
    pub fn makespan_ms(&self) -> f64 {
        self.makespan_ms
    }

    pub fn compute_ms(&self) -> f64 {
        self.lanes.iter().flatten().map(|l| l.busy_ms).sum()
    }

    pub fn comm_ms(&self) -> f64 {
        self.lanes.iter().flatten().map(|l| l.comm_ms).sum::<f64>()
            + self.final_comm_ms
    }

    /// Assemble the traversal's [`PipelineTiming`]. Replicated stages
    /// report one aggregate entry (summed over replicas) attributed to
    /// the primary (replica 0) node.
    pub fn timing(&self) -> PipelineTiming {
        PipelineTiming {
            total_ms: self.makespan_ms,
            compute_ms: self.compute_ms(),
            comm_ms: self.comm_ms(),
            stages: self
                .lanes
                .iter()
                .enumerate()
                .map(|(k, reps)| StageTiming {
                    stage: k,
                    node: self.node_of[k][0],
                    compute_ms: reps.iter().map(|l| l.busy_ms).sum(),
                    comm_ms: reps.iter().map(|l| l.comm_ms).sum(),
                })
                .collect(),
            activation_bytes: self.activation_bytes,
        }
    }

    /// Per-stage occupancy/bubble counters for the metrics layer
    /// (aggregated over replicas; node is the primary's).
    pub fn counters(&self) -> Vec<StageCounter> {
        self.lanes
            .iter()
            .enumerate()
            .map(|(k, reps)| StageCounter {
                stage: k,
                node: self.node_of[k][0],
                busy_ms: reps.iter().map(|l| l.busy_ms).sum(),
                bubble_ms: reps.iter().map(|l| l.bubble_ms).sum(),
                comm_ms: reps.iter().map(|l| l.comm_ms).sum(),
                micro_batches: reps.iter().map(|l| l.micro_batches).sum(),
            })
            .collect()
    }

    /// Per-replica occupancy/bubble counters — the scale-out view the
    /// aggregated [`CriticalPath::counters`] cannot show (a starved
    /// replica hides inside its stage's sum).
    pub fn replica_counters(&self) -> Vec<ReplicaCounter> {
        self.lanes
            .iter()
            .enumerate()
            .flat_map(|(k, reps)| {
                reps.iter().enumerate().map(move |(r, l)| ReplicaCounter {
                    stage: k,
                    replica: r,
                    node: self.node_of[k][r],
                    busy_ms: l.busy_ms,
                    bubble_ms: l.bubble_ms,
                    comm_ms: l.comm_ms,
                    micro_batches: l.micro_batches,
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_total_is_sum_of_components() {
        // One chunk through 3 stages: the recurrence must collapse to the
        // plain serial sum (the total_ms regression pinned by ISSUE 1).
        let mut cp = CriticalPath::new(&[0, 1, 2]);
        let mut ready = 0.0;
        for (k, (comm, compute)) in
            [(1.0, 10.0), (2.0, 5.0), (1.5, 20.0)].into_iter().enumerate()
        {
            ready = cp.step(k, ready, comm, compute, 0);
        }
        let done = cp.deliver(0.5, 64, ready);
        let t = cp.timing();
        assert!((t.total_ms - (t.compute_ms + t.comm_ms)).abs() < 1e-9,
                "total {} vs compute+comm {}", t.total_ms, t.compute_ms + t.comm_ms);
        assert!((done - 40.0).abs() < 1e-9);
        assert_eq!(t.stages.len(), 3);
        assert!((t.compute_ms - 35.0).abs() < 1e-9);
        assert!((t.comm_ms - 5.0).abs() < 1e-9);
    }

    #[test]
    fn pipelined_makespan_overlaps() {
        // 4 micro-batches, 2 stages, equal 10 ms compute, zero comm.
        // Serial would be 4 * 20 = 80 ms; pipelined is 10 (fill) + 4*10
        // = 50 ms.
        let mut cp = CriticalPath::new(&[0, 1]);
        let mut ready0 = Vec::new();
        for _ in 0..4 {
            ready0.push(cp.step(0, 0.0, 0.0, 10.0, 0));
        }
        let mut last = 0.0;
        for r in ready0 {
            last = cp.step(1, r, 0.0, 10.0, 0);
        }
        cp.deliver(0.0, 0, last);
        let t = cp.timing();
        assert!((t.total_ms - 50.0).abs() < 1e-9, "makespan {}", t.total_ms);
        assert!((t.compute_ms - 80.0).abs() < 1e-9);
        assert!(t.total_ms < t.compute_ms);
    }

    #[test]
    fn stages_sharing_a_node_cannot_overlap() {
        // Same schedule as `pipelined_makespan_overlaps`, but both stages
        // live on node 0 (the deployer's overcommit fallback): a single
        // device serializes them, so the makespan must be the full
        // 80 ms, not the pipelined 50 ms.
        let mut cp = CriticalPath::new(&[0, 0]);
        let mut ready0 = Vec::new();
        for _ in 0..4 {
            ready0.push(cp.step(0, 0.0, 0.0, 10.0, 0));
        }
        let mut last = 0.0;
        for r in ready0 {
            last = cp.step(1, r, 0.0, 10.0, 0);
        }
        cp.deliver(0.0, 0, last);
        let t = cp.timing();
        assert!((t.total_ms - 80.0).abs() < 1e-9, "makespan {}", t.total_ms);
        assert!((t.total_ms - t.compute_ms).abs() < 1e-9);
    }

    #[test]
    fn bubbles_exclude_pipeline_fill() {
        let mut cp = CriticalPath::new(&[7]);
        // First micro-batch arrives at t=5: fill, not a bubble.
        let r1 = cp.step(0, 5.0, 0.0, 10.0, 0);
        assert!((r1 - 15.0).abs() < 1e-9);
        // Second arrives at t=30 while the stage freed at 15: 15 ms bubble.
        let r2 = cp.step(0, 30.0, 0.0, 10.0, 0);
        assert!((r2 - 40.0).abs() < 1e-9);
        let c = cp.counters();
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].node, 7);
        assert_eq!(c[0].micro_batches, 2);
        assert!((c[0].bubble_ms - 15.0).abs() < 1e-9);
        assert!((c[0].busy_ms - 20.0).abs() < 1e-9);
    }

    #[test]
    fn step_detail_reports_per_step_bubble() {
        let mut cp = CriticalPath::new(&[0]);
        // Fill: arrives at t=5 on a fresh stage — no bubble reported.
        let d1 = cp.step_detail(0, 5.0, 0.0, 10.0, 0);
        assert!((d1.done_ms - 15.0).abs() < 1e-9);
        assert_eq!(d1.bubble_ms, 0.0);
        // Arrives at t=30 while the stage freed at 15: 15 ms bubble, and
        // the delta matches the lane's cumulative bubble.
        let d2 = cp.step_detail(0, 30.0, 0.0, 10.0, 0);
        assert!((d2.bubble_ms - 15.0).abs() < 1e-9);
        assert!((cp.counters()[0].bubble_ms - 15.0).abs() < 1e-9);
        // Back-to-back arrival while busy: no bubble.
        let d3 = cp.step_detail(0, 0.0, 0.0, 10.0, 0);
        assert_eq!(d3.bubble_ms, 0.0);
    }

    #[test]
    fn replica_lanes_overlap_and_aggregate() {
        // Stage 0 has two replicas on distinct nodes: both micro-batches
        // start at t=0 and finish at t=10 — true overlap a single lane
        // cannot produce.
        let mut cp = CriticalPath::new_replicated(&[vec![0, 1]]);
        let d0 = cp.step_detail_on(0, 0, 0.0, 0.0, 10.0, 0);
        let d1 = cp.step_detail_on(0, 1, 0.0, 0.0, 10.0, 0);
        assert!((d0.done_ms - 10.0).abs() < 1e-9);
        assert!((d1.done_ms - 10.0).abs() < 1e-9);
        assert!((cp.makespan_ms() - 10.0).abs() < 1e-9);
        assert_eq!(cp.replicas(0), 2);
        // Aggregated counters: one stage entry summing both lanes.
        let c = cp.counters();
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].micro_batches, 2);
        assert!((c[0].busy_ms - 20.0).abs() < 1e-9);
        // Per-replica counters expose the individual lanes.
        let rc = cp.replica_counters();
        assert_eq!(rc.len(), 2);
        assert_eq!((rc[0].replica, rc[1].replica), (0, 1));
        assert_eq!((rc[0].node, rc[1].node), (0, 1));
        assert!((rc[0].busy_ms - 10.0).abs() < 1e-9);
        assert_eq!(rc[1].micro_batches, 1);
    }

    #[test]
    fn replicas_sharing_a_node_still_serialize() {
        let mut cp = CriticalPath::new_replicated(&[vec![3, 3]]);
        let d0 = cp.step_detail_on(0, 0, 0.0, 0.0, 10.0, 0);
        let d1 = cp.step_detail_on(0, 1, 0.0, 0.0, 10.0, 0);
        assert!((d0.done_ms - 10.0).abs() < 1e-9);
        assert!((d1.done_ms - 20.0).abs() < 1e-9, "same node must serialize");
    }

    #[test]
    fn single_replica_matches_plain_constructor() {
        let mut a = CriticalPath::new(&[0, 1]);
        let mut b = CriticalPath::new_replicated(&[vec![0], vec![1]]);
        for cp in [&mut a, &mut b] {
            let r = cp.step(0, 0.0, 1.0, 10.0, 8);
            cp.step(1, r, 2.0, 5.0, 8);
        }
        assert_eq!(a.makespan_ms(), b.makespan_ms());
        assert_eq!(a.compute_ms(), b.compute_ms());
        assert_eq!(a.comm_ms(), b.comm_ms());
        let (ca, cb) = (a.counters(), b.counters());
        assert_eq!(ca.len(), cb.len());
        for (x, y) in ca.iter().zip(&cb) {
            assert_eq!(x.busy_ms, y.busy_ms);
            assert_eq!(x.bubble_ms, y.bubble_ms);
            assert_eq!(x.micro_batches, y.micro_batches);
        }
    }

    #[test]
    fn busy_stage_serializes_micro_batches() {
        let mut cp = CriticalPath::new(&[0]);
        // Both micro-batches available immediately; the stage's single
        // device serializes them.
        let r1 = cp.step(0, 0.0, 1.0, 10.0, 8);
        let r2 = cp.step(0, 0.0, 1.0, 10.0, 8);
        assert!((r1 - 11.0).abs() < 1e-9);
        assert!((r2 - 21.0).abs() < 1e-9);
        assert_eq!(cp.counters()[0].bubble_ms, 0.0);
        assert_eq!(cp.timing().activation_bytes, 16);
    }
}
