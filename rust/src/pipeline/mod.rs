//! Distributed inference pipeline: run one (batched) request through the
//! deployed partition chain across virtual nodes.
//!
//! Two execution modes share one simulated-time model ([`timing`]):
//!
//! * [`run`] — serial traversal: the activation visits stage 0..N-1 in
//!   order, one stage computing at a time. Per stage the activation is
//!   transferred over the network model (leader -> node for stage 0,
//!   node -> node between stages, node -> leader at the end), then the
//!   stage's blocks execute serially on the node's device under its
//!   CPU-quota/memory model. Timing is broken into compute vs
//!   communication per stage — the paper's Table I "communication
//!   overhead" column.
//! * [`engine`] — streaming traversal: the batch is split into row-wise
//!   micro-batches driven through per-stage bounded queues so stage *k*
//!   computes while stage *k+1* receives. One-shot via
//!   [`engine::run_streamed`]; cross-batch via
//!   [`engine::PersistentEngine`], whose drivers (and critical-path
//!   clock) live for the whole serve run so successive batches stream
//!   back-to-back with no inter-batch drain, optionally with an
//!   adaptive in-flight window. See the module docs for the micro-batch
//!   and sim-time model.
//!
//! All reported times are **simulated milliseconds**. In particular
//! `PipelineTiming::total_ms` is the simulated critical-path sum — for a
//! serial run exactly `compute_ms + comm_ms` — never host wall-clock
//! (which is machine-dependent and historically undercut its own
//! components on fast hosts).

pub mod engine;
pub mod timing;

use anyhow::Result;

use crate::deployer::Deployment;
use crate::runtime::Tensor;

pub use timing::{PipelineTiming, StageTiming};

/// Execute one already-batched input through the deployment, serially.
///
/// This is the single-chunk degenerate case of the engine's schedule:
/// it delegates to [`engine::run_serial`] with the whole batch as one
/// micro-batch, so serial and streamed runs share one accounting path.
pub fn run(
    deployment: &Deployment,
    input: &Tensor,
) -> Result<(Tensor, PipelineTiming)> {
    let rows = input.shape.first().copied().unwrap_or(1).max(1);
    let run = engine::run_serial(
        &engine::DeploymentStages::new(deployment),
        input,
        rows,
    )?;
    Ok((run.output, run.timing))
}

/// Stack `[1, ...]`-shaped inputs into one `[n, ...]` batch, zero-padding
/// up to `batch` rows.
pub fn stack_batch(inputs: &[&Tensor], batch: usize) -> Result<Tensor> {
    anyhow::ensure!(!inputs.is_empty(), "empty batch");
    anyhow::ensure!(inputs.len() <= batch, "batch overflow");
    let per = &inputs[0].shape;
    anyhow::ensure!(per[0] == 1, "stack_batch expects [1, ...] inputs");
    for t in inputs {
        anyhow::ensure!(t.shape == *per, "mismatched input shapes in batch");
    }
    let row_len: usize = per.iter().skip(1).product();
    let mut data = Vec::with_capacity(batch * row_len);
    for t in inputs {
        data.extend_from_slice(&t.data);
    }
    data.resize(batch * row_len, 0.0);
    let mut shape = per.clone();
    shape[0] = batch;
    Tensor::new(shape, data)
}

/// Split a `[batch, ...]` output back into the first `n` per-request rows.
pub fn split_batch(output: &Tensor, n: usize) -> Result<Vec<Tensor>> {
    anyhow::ensure!(!output.shape.is_empty(), "scalar output");
    let batch = output.shape[0];
    anyhow::ensure!(n <= batch, "asked for more rows than batch");
    let row_len: usize = output.shape.iter().skip(1).product();
    let mut shape = output.shape.clone();
    shape[0] = 1;
    (0..n)
        .map(|i| {
            Tensor::new(
                shape.clone(),
                output.data[i * row_len..(i + 1) * row_len].to_vec(),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stack_and_split_roundtrip() {
        let a = Tensor::new(vec![1, 2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(vec![1, 2], vec![3.0, 4.0]).unwrap();
        let batch = stack_batch(&[&a, &b], 4).unwrap();
        assert_eq!(batch.shape, vec![4, 2]);
        assert_eq!(batch.data, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
        let rows = split_batch(&batch, 2).unwrap();
        assert_eq!(rows[0], a);
        assert_eq!(rows[1], b);
    }

    #[test]
    fn stack_rejects_mismatches() {
        let a = Tensor::new(vec![1, 2], vec![1.0, 2.0]).unwrap();
        let c = Tensor::new(vec![1, 3], vec![1.0, 2.0, 3.0]).unwrap();
        assert!(stack_batch(&[&a, &c], 4).is_err());
        assert!(stack_batch(&[], 4).is_err());
        let batch2 = Tensor::new(vec![2, 2], vec![0.0; 4]).unwrap();
        assert!(stack_batch(&[&batch2], 4).is_err());
        assert!(split_batch(&batch2, 3).is_err());
    }
}
