//! Distributed inference pipeline: run one (batched) request through the
//! deployed partition chain across virtual nodes.
//!
//! Two execution modes share one simulated-time model ([`timing`]):
//!
//! * [`run`] — serial traversal: the activation visits stage 0..N-1 in
//!   order, one stage computing at a time. Per stage the activation is
//!   transferred over the network model (leader -> node for stage 0,
//!   node -> node between stages, node -> leader at the end), then the
//!   stage's blocks execute serially on the node's device under its
//!   CPU-quota/memory model. Timing is broken into compute vs
//!   communication per stage — the paper's Table I "communication
//!   overhead" column.
//! * [`engine`] — streaming traversal: the batch is split into row-wise
//!   micro-batches driven through per-stage bounded queues so stage *k*
//!   computes while stage *k+1* receives. One-shot via
//!   [`engine::run_streamed`]; cross-batch via
//!   [`engine::PersistentEngine`], whose drivers (and critical-path
//!   clock) live for the whole serve run so successive batches stream
//!   back-to-back with no inter-batch drain, optionally with an
//!   adaptive in-flight window. See the module docs for the micro-batch
//!   and sim-time model.
//!
//! All reported times are **simulated milliseconds**. In particular
//! `PipelineTiming::total_ms` is the simulated critical-path sum — for a
//! serial run exactly `compute_ms + comm_ms` — never host wall-clock
//! (which is machine-dependent and historically undercut its own
//! components on fast hosts).

pub mod engine;
pub mod timing;

use anyhow::Result;

use crate::deployer::Deployment;
use crate::runtime::Tensor;

pub use timing::{PipelineTiming, StageTiming};

/// Execute one already-batched input through the deployment, serially.
///
/// This is the single-chunk degenerate case of the engine's schedule:
/// it delegates to [`engine::run_serial`] with the whole batch as one
/// micro-batch, so serial and streamed runs share one accounting path.
pub fn run(
    deployment: &Deployment,
    input: &Tensor,
) -> Result<(Tensor, PipelineTiming)> {
    let rows = input.shape.first().copied().unwrap_or(1).max(1);
    let run = engine::run_serial(
        &engine::DeploymentStages::new(deployment),
        input,
        rows,
    )?;
    Ok((run.output, run.timing))
}

/// Stack `[1, ...]`-shaped inputs into one `[n, ...]` batch, zero-padding
/// up to `batch` rows.
///
/// Zero-copy fast paths: a lone padding-free input is returned as a
/// shared view, and inputs that are already *adjacent views of one
/// backing buffer* (e.g. rows previously split off the same batch, or a
/// cache-warm replay of a pooled workload) re-assemble as a single view
/// over their span. Everything else copies once into a pooled buffer
/// (counted in [`crate::metrics::data_plane`]).
///
/// Inputs of the *same rank but different sizes* are stacked by padding
/// every row to their elementwise-maximum superset shape: each ragged
/// input embeds stride-aligned at the origin of a zero-filled superset
/// row, and [`crop_row`] is the exact inverse. Identical-shape batches
/// never take this path, so uniform workloads are unchanged bit for
/// bit. Rank mismatches remain an error.
pub fn stack_batch(inputs: &[&Tensor], batch: usize) -> Result<Tensor> {
    anyhow::ensure!(!inputs.is_empty(), "empty batch");
    anyhow::ensure!(inputs.len() <= batch, "batch overflow");
    let per = &inputs[0].shape;
    let mut sup = per.clone();
    let mut uniform = true;
    for t in inputs {
        anyhow::ensure!(
            t.shape.len() == per.len() && t.shape[0] == 1,
            "stack_batch expects same-rank [1, ...] inputs"
        );
        uniform &= t.shape == *per;
        for (s, d) in sup.iter_mut().zip(&t.shape) {
            *s = (*s).max(*d);
        }
    }
    if !uniform {
        let row_len: usize = sup.iter().skip(1).product();
        let mut shape = sup.clone();
        shape[0] = batch;
        let mut data =
            crate::util::pool::BufferPool::global().take(batch * row_len);
        data.resize(batch * row_len, 0.0);
        let mut copied = 0usize;
        for (i, t) in inputs.iter().enumerate() {
            embed_block(
                t.data(),
                &t.shape[1..],
                &mut data[i * row_len..(i + 1) * row_len],
                &sup[1..],
            );
            copied += t.data().len();
        }
        crate::metrics::data_plane::count_copy((copied * 4) as u64);
        return Tensor::new(shape, data);
    }
    let row_len: usize = per.iter().skip(1).product();
    let mut shape = per.clone();
    shape[0] = batch;
    if inputs.len() == batch {
        if batch == 1 {
            crate::metrics::data_plane::count_view(inputs[0].byte_len());
            return Ok(inputs[0].clone());
        }
        if inputs.windows(2).all(|p| p[0].abuts(p[1])) {
            crate::metrics::data_plane::count_view(
                (batch * row_len * 4) as u64,
            );
            return Tensor::from_buf(
                shape,
                std::sync::Arc::clone(inputs[0].buf()),
                inputs[0].offset(),
            );
        }
    }
    let mut data =
        crate::util::pool::BufferPool::global().take(batch * row_len);
    for t in inputs {
        data.extend_from_slice(t.data());
    }
    crate::metrics::data_plane::count_copy((data.len() * 4) as u64);
    data.resize(batch * row_len, 0.0);
    Tensor::new(shape, data)
}

/// Copy a dense block of shape `src_dims` into the origin corner of a
/// dense block of shape `dst_dims` (same rank, `src <= dst` per dim),
/// keeping every trailing destination stride — the layout [`crop_row`]
/// inverts exactly.
fn embed_block(
    src: &[f32],
    src_dims: &[usize],
    dst: &mut [f32],
    dst_dims: &[usize],
) {
    if src_dims == dst_dims {
        dst[..src.len()].copy_from_slice(src);
        return;
    }
    let ss: usize = src_dims[1..].iter().product();
    let ds: usize = dst_dims[1..].iter().product();
    for i in 0..src_dims[0] {
        embed_block(
            &src[i * ss..(i + 1) * ss],
            &src_dims[1..],
            &mut dst[i * ds..(i + 1) * ds],
            &dst_dims[1..],
        );
    }
}

/// Inverse of [`embed_block`]: copy the origin block of shape
/// `dst_dims` back out of a superset block of shape `src_dims`.
fn extract_block(
    src: &[f32],
    src_dims: &[usize],
    dst: &mut [f32],
    dst_dims: &[usize],
) {
    if src_dims == dst_dims {
        dst.copy_from_slice(&src[..dst.len()]);
        return;
    }
    let ss: usize = src_dims[1..].iter().product();
    let ds: usize = dst_dims[1..].iter().product();
    for i in 0..dst_dims[0] {
        extract_block(
            &src[i * ss..(i + 1) * ss],
            &src_dims[1..],
            &mut dst[i * ds..(i + 1) * ds],
            &dst_dims[1..],
        );
    }
}

/// Crop a (possibly superset-padded) `[1, ...]` row back to `shape` —
/// the exact inverse of [`stack_batch`]'s pad-to-superset path: bit-
/// identical originals come back out. Zero-copy when the row already
/// has `shape`; otherwise one stride-aligned copy of the origin block.
pub fn crop_row(row: &Tensor, shape: &[usize]) -> Result<Tensor> {
    anyhow::ensure!(
        shape.len() == row.shape.len()
            && shape.first() == Some(&1)
            && row.shape[0] == 1,
        "crop_row needs same-rank [1, ...] shapes"
    );
    anyhow::ensure!(
        shape.iter().zip(&row.shape).all(|(d, s)| d <= s),
        "crop shape {shape:?} exceeds row shape {:?}",
        row.shape
    );
    if row.shape == shape {
        crate::metrics::data_plane::count_view(row.byte_len());
        return Ok(row.clone());
    }
    let n: usize = shape.iter().product();
    let mut data = crate::util::pool::BufferPool::global().take(n);
    data.resize(n, 0.0);
    extract_block(row.data(), &row.shape[1..], &mut data, &shape[1..]);
    crate::metrics::data_plane::count_copy((n * 4) as u64);
    Tensor::new(shape.to_vec(), data)
}

/// Split a `[batch, ...]` output back into the first `n` per-request
/// rows. Each row is a zero-copy view sharing the batch's backing
/// buffer (the buffer stays alive as long as any row does).
pub fn split_batch(output: &Tensor, n: usize) -> Result<Vec<Tensor>> {
    anyhow::ensure!(!output.shape.is_empty(), "scalar output");
    let batch = output.shape[0];
    anyhow::ensure!(n <= batch, "asked for more rows than batch");
    (0..n).map(|i| output.view_rows(i..i + 1)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stack_and_split_roundtrip() {
        let a = Tensor::new(vec![1, 2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(vec![1, 2], vec![3.0, 4.0]).unwrap();
        let batch = stack_batch(&[&a, &b], 4).unwrap();
        assert_eq!(batch.shape, vec![4, 2]);
        assert_eq!(
            batch.data(),
            &[1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0][..]
        );
        let rows = split_batch(&batch, 2).unwrap();
        assert_eq!(rows[0], a);
        assert_eq!(rows[1], b);
    }

    #[test]
    fn stack_rejects_mismatches() {
        let a = Tensor::new(vec![1, 2], vec![1.0, 2.0]).unwrap();
        // Rank mismatches still error; size mismatches now pad instead.
        let r3 = Tensor::new(vec![1, 2, 1], vec![1.0, 2.0]).unwrap();
        assert!(stack_batch(&[&a, &r3], 4).is_err());
        assert!(stack_batch(&[], 4).is_err());
        let batch2 = Tensor::new(vec![2, 2], vec![0.0; 4]).unwrap();
        assert!(stack_batch(&[&batch2], 4).is_err());
        assert!(stack_batch(&[&a, &batch2], 4).is_err());
        assert!(split_batch(&batch2, 3).is_err());
    }

    #[test]
    fn ragged_stack_pads_to_superset_and_crops_back() {
        let a = Tensor::new(
            vec![1, 2, 3],
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        )
        .unwrap();
        let b = Tensor::new(
            vec![1, 3, 2],
            vec![-1.0, -2.0, -3.0, -4.0, -5.0, -6.0],
        )
        .unwrap();
        let batch = stack_batch(&[&a, &b], 3).unwrap();
        assert_eq!(batch.shape, vec![3, 3, 3]);
        let rows = split_batch(&batch, 2).unwrap();
        // a's 2x3 block sits at the origin of a zeroed 3x3 row.
        assert_eq!(
            rows[0].data(),
            &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 0.0, 0.0, 0.0][..]
        );
        // b's 3x2 block lands stride-aligned: two values per 3-wide row.
        assert_eq!(
            rows[1].data(),
            &[-1.0, -2.0, 0.0, -3.0, -4.0, 0.0, -5.0, -6.0, 0.0][..]
        );
        // crop_row is the exact inverse: bit-identical originals.
        assert_eq!(crop_row(&rows[0], &[1, 2, 3]).unwrap(), a);
        assert_eq!(crop_row(&rows[1], &[1, 3, 2]).unwrap(), b);
        // Cropping a row to its own shape is a zero-copy view.
        let same = crop_row(&rows[0], &[1, 3, 3]).unwrap();
        assert!(std::sync::Arc::ptr_eq(same.buf(), batch.buf()));
        // A crop larger than the row, or of a different rank, errors.
        assert!(crop_row(&rows[0], &[1, 4, 3]).is_err());
        assert!(crop_row(&rows[0], &[1, 9]).is_err());
    }
}
