//! Distributed inference pipeline: run one (batched) request through the
//! deployed partition chain across virtual nodes.
//!
//! Per stage: the activation is transferred over the network model
//! (leader -> node for stage 0, node -> node between stages, node ->
//! leader at the end), then the stage's blocks execute serially on the
//! node's device under its CPU-quota/memory model. Timing is broken into
//! compute vs communication per stage — the paper's Table I
//! "communication overhead" column.

use anyhow::Result;

use crate::cluster::VirtualNode;
use crate::deployer::Deployment;
use crate::runtime::Tensor;

/// Timing breakdown for one pipeline traversal.
#[derive(Debug, Clone, Default)]
pub struct PipelineTiming {
    pub total_ms: f64,
    pub compute_ms: f64,
    pub comm_ms: f64,
    /// (stage, node id, compute ms, comm-in ms) per stage.
    pub stages: Vec<StageTiming>,
    /// Activation bytes moved between leader/nodes.
    pub activation_bytes: u64,
}

#[derive(Debug, Clone)]
pub struct StageTiming {
    pub stage: usize,
    pub node: usize,
    pub compute_ms: f64,
    pub comm_ms: f64,
}

/// Model a transfer between two parties (leader treated as a zero-latency
/// infinite-bandwidth endpoint; node links dominate).
fn transfer(from: Option<&VirtualNode>, to: Option<&VirtualNode>, bytes: u64) -> f64 {
    let mut ms = 0.0;
    if let Some(f) = from {
        ms += f.link().send(bytes);
    }
    if let Some(t) = to {
        ms += t.link().receive(bytes);
    }
    ms
}

/// Execute one already-batched input through the deployment.
pub fn run(
    deployment: &Deployment,
    input: &Tensor,
) -> Result<(Tensor, PipelineTiming)> {
    let t0 = std::time::Instant::now();
    let mut timing = PipelineTiming::default();
    let mut activation = input.clone();
    let n_stages = deployment.stages.len();

    for (si, stage) in deployment.stages.iter().enumerate() {
        // ---- communication into this stage ----
        let bytes = activation.byte_len();
        let from: Option<&VirtualNode> = if si == 0 {
            None // leader -> first node
        } else {
            Some(&*deployment.stages[si - 1].node)
        };
        let comm_ms = transfer(from, Some(&stage.node), bytes);
        timing.activation_bytes += bytes;

        // ---- compute on the node (serialized, CPU-quota dilated) ----
        let executor = &stage.executor;
        let blocks = stage.blocks.clone();
        let input_t = activation;
        let (out, outcome) = stage
            .node
            .execute_costed(move || executor.run_chain(blocks, input_t))?;
        activation = out;

        timing.compute_ms += outcome.sim_ms;
        timing.comm_ms += comm_ms;
        timing.stages.push(StageTiming {
            stage: si,
            node: stage.node.id(),
            compute_ms: outcome.sim_ms,
            comm_ms,
        });

        // ---- final hop back to the leader ----
        if si == n_stages - 1 {
            let out_bytes = activation.byte_len();
            let ms = transfer(Some(&stage.node), None, out_bytes);
            timing.comm_ms += ms;
            timing.activation_bytes += out_bytes;
        }
    }

    timing.total_ms = t0.elapsed().as_secs_f64() * 1e3;
    Ok((activation, timing))
}

/// Stack `[1, ...]`-shaped inputs into one `[n, ...]` batch, zero-padding
/// up to `batch` rows.
pub fn stack_batch(inputs: &[&Tensor], batch: usize) -> Result<Tensor> {
    anyhow::ensure!(!inputs.is_empty(), "empty batch");
    anyhow::ensure!(inputs.len() <= batch, "batch overflow");
    let per = &inputs[0].shape;
    anyhow::ensure!(per[0] == 1, "stack_batch expects [1, ...] inputs");
    for t in inputs {
        anyhow::ensure!(t.shape == *per, "mismatched input shapes in batch");
    }
    let row_len: usize = per.iter().skip(1).product();
    let mut data = Vec::with_capacity(batch * row_len);
    for t in inputs {
        data.extend_from_slice(&t.data);
    }
    data.resize(batch * row_len, 0.0);
    let mut shape = per.clone();
    shape[0] = batch;
    Tensor::new(shape, data)
}

/// Split a `[batch, ...]` output back into the first `n` per-request rows.
pub fn split_batch(output: &Tensor, n: usize) -> Result<Vec<Tensor>> {
    anyhow::ensure!(!output.shape.is_empty(), "scalar output");
    let batch = output.shape[0];
    anyhow::ensure!(n <= batch, "asked for more rows than batch");
    let row_len: usize = output.shape.iter().skip(1).product();
    let mut shape = output.shape.clone();
    shape[0] = 1;
    (0..n)
        .map(|i| {
            Tensor::new(
                shape.clone(),
                output.data[i * row_len..(i + 1) * row_len].to_vec(),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stack_and_split_roundtrip() {
        let a = Tensor::new(vec![1, 2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(vec![1, 2], vec![3.0, 4.0]).unwrap();
        let batch = stack_batch(&[&a, &b], 4).unwrap();
        assert_eq!(batch.shape, vec![4, 2]);
        assert_eq!(batch.data, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
        let rows = split_batch(&batch, 2).unwrap();
        assert_eq!(rows[0], a);
        assert_eq!(rows[1], b);
    }

    #[test]
    fn stack_rejects_mismatches() {
        let a = Tensor::new(vec![1, 2], vec![1.0, 2.0]).unwrap();
        let c = Tensor::new(vec![1, 3], vec![1.0, 2.0, 3.0]).unwrap();
        assert!(stack_batch(&[&a, &c], 4).is_err());
        assert!(stack_batch(&[], 4).is_err());
        let batch2 = Tensor::new(vec![2, 2], vec![0.0; 4]).unwrap();
        assert!(stack_batch(&[&batch2], 4).is_err());
        assert!(split_batch(&batch2, 3).is_err());
    }
}
