//! Distributed inference pipeline: run one (batched) request through the
//! deployed partition chain across virtual nodes.
//!
//! Two execution modes share one simulated-time model ([`timing`]):
//!
//! * [`run`] — serial traversal: the activation visits stage 0..N-1 in
//!   order, one stage computing at a time. Per stage the activation is
//!   transferred over the network model (leader -> node for stage 0,
//!   node -> node between stages, node -> leader at the end), then the
//!   stage's blocks execute serially on the node's device under its
//!   CPU-quota/memory model. Timing is broken into compute vs
//!   communication per stage — the paper's Table I "communication
//!   overhead" column.
//! * [`engine`] — streaming traversal: the batch is split into row-wise
//!   micro-batches driven through per-stage bounded queues so stage *k*
//!   computes while stage *k+1* receives. One-shot via
//!   [`engine::run_streamed`]; cross-batch via
//!   [`engine::PersistentEngine`], whose drivers (and critical-path
//!   clock) live for the whole serve run so successive batches stream
//!   back-to-back with no inter-batch drain, optionally with an
//!   adaptive in-flight window. See the module docs for the micro-batch
//!   and sim-time model.
//!
//! All reported times are **simulated milliseconds**. In particular
//! `PipelineTiming::total_ms` is the simulated critical-path sum — for a
//! serial run exactly `compute_ms + comm_ms` — never host wall-clock
//! (which is machine-dependent and historically undercut its own
//! components on fast hosts).

pub mod engine;
pub mod timing;

use anyhow::Result;

use crate::deployer::Deployment;
use crate::runtime::Tensor;

pub use timing::{PipelineTiming, StageTiming};

/// Execute one already-batched input through the deployment, serially.
///
/// This is the single-chunk degenerate case of the engine's schedule:
/// it delegates to [`engine::run_serial`] with the whole batch as one
/// micro-batch, so serial and streamed runs share one accounting path.
pub fn run(
    deployment: &Deployment,
    input: &Tensor,
) -> Result<(Tensor, PipelineTiming)> {
    let rows = input.shape.first().copied().unwrap_or(1).max(1);
    let run = engine::run_serial(
        &engine::DeploymentStages::new(deployment),
        input,
        rows,
    )?;
    Ok((run.output, run.timing))
}

/// Stack `[1, ...]`-shaped inputs into one `[n, ...]` batch, zero-padding
/// up to `batch` rows.
///
/// Zero-copy fast paths: a lone padding-free input is returned as a
/// shared view, and inputs that are already *adjacent views of one
/// backing buffer* (e.g. rows previously split off the same batch, or a
/// cache-warm replay of a pooled workload) re-assemble as a single view
/// over their span. Everything else copies once into a pooled buffer
/// (counted in [`crate::metrics::data_plane`]).
pub fn stack_batch(inputs: &[&Tensor], batch: usize) -> Result<Tensor> {
    anyhow::ensure!(!inputs.is_empty(), "empty batch");
    anyhow::ensure!(inputs.len() <= batch, "batch overflow");
    let per = &inputs[0].shape;
    anyhow::ensure!(per[0] == 1, "stack_batch expects [1, ...] inputs");
    for t in inputs {
        anyhow::ensure!(t.shape == *per, "mismatched input shapes in batch");
    }
    let row_len: usize = per.iter().skip(1).product();
    let mut shape = per.clone();
    shape[0] = batch;
    if inputs.len() == batch {
        if batch == 1 {
            crate::metrics::data_plane::count_view(inputs[0].byte_len());
            return Ok(inputs[0].clone());
        }
        if inputs.windows(2).all(|p| p[0].abuts(p[1])) {
            crate::metrics::data_plane::count_view(
                (batch * row_len * 4) as u64,
            );
            return Tensor::from_buf(
                shape,
                std::sync::Arc::clone(inputs[0].buf()),
                inputs[0].offset(),
            );
        }
    }
    let mut data =
        crate::util::pool::BufferPool::global().take(batch * row_len);
    for t in inputs {
        data.extend_from_slice(t.data());
    }
    crate::metrics::data_plane::count_copy((data.len() * 4) as u64);
    data.resize(batch * row_len, 0.0);
    Tensor::new(shape, data)
}

/// Split a `[batch, ...]` output back into the first `n` per-request
/// rows. Each row is a zero-copy view sharing the batch's backing
/// buffer (the buffer stays alive as long as any row does).
pub fn split_batch(output: &Tensor, n: usize) -> Result<Vec<Tensor>> {
    anyhow::ensure!(!output.shape.is_empty(), "scalar output");
    let batch = output.shape[0];
    anyhow::ensure!(n <= batch, "asked for more rows than batch");
    (0..n).map(|i| output.view_rows(i..i + 1)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stack_and_split_roundtrip() {
        let a = Tensor::new(vec![1, 2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(vec![1, 2], vec![3.0, 4.0]).unwrap();
        let batch = stack_batch(&[&a, &b], 4).unwrap();
        assert_eq!(batch.shape, vec![4, 2]);
        assert_eq!(
            batch.data(),
            &[1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0][..]
        );
        let rows = split_batch(&batch, 2).unwrap();
        assert_eq!(rows[0], a);
        assert_eq!(rows[1], b);
    }

    #[test]
    fn stack_rejects_mismatches() {
        let a = Tensor::new(vec![1, 2], vec![1.0, 2.0]).unwrap();
        let c = Tensor::new(vec![1, 3], vec![1.0, 2.0, 3.0]).unwrap();
        assert!(stack_batch(&[&a, &c], 4).is_err());
        assert!(stack_batch(&[], 4).is_err());
        let batch2 = Tensor::new(vec![2, 2], vec![0.0; 4]).unwrap();
        assert!(stack_batch(&[&batch2], 4).is_err());
        assert!(split_batch(&batch2, 3).is_err());
    }
}
