//! Streaming pipeline-parallel execution engine.
//!
//! `pipeline::run` walks one batch through the partition chain strictly
//! serially: stage *k+1* is idle while stage *k* computes, so a
//! heterogeneous cluster runs at the *sum* of its stage times. This
//! engine instead gives every deployment stage its own bounded work
//! queue and driver thread, splits an admitted batch into row-wise
//! micro-batches, and keeps up to `max_in_flight` micro-batches moving
//! through the chain at once — stage *k* computes micro-batch *i+1*
//! while stage *k+1* receives and computes micro-batch *i*. End-to-end
//! time drops from `Σ_k cost_k` per batch toward
//! `fill + n_micro · max_k cost_k` (the classic pipeline bound), which
//! is where AMP4EC's throughput multiple over serial execution comes
//! from.
//!
//! ## Micro-batch model
//!
//! A micro-batch is a contiguous slice of batch rows
//! ([`split_rows`]/[`concat_rows`]). Every model stage is row-wise
//! (per-sample inference), so streaming is **bit-identical** to serial
//! execution — pinned by tests and `benches/pipeline_engine.rs`. For a
//! real deployment the micro-batch row count must equal the batch the
//! stage artifacts were compiled for (`Deployment::batch`); the
//! router's admission batch is then `micro_batch · max_in_flight` rows
//! (see `DistributedService`).
//!
//! ## Sim-time model
//!
//! All engine accounting is in **simulated milliseconds** end-to-end via
//! the critical-path recurrence in [`super::timing::CriticalPath`]:
//! `ready[k] = max(ready[k-1] + comm, stage_free[k]) + compute`, with
//! leader admission gated by a credit window — micro-batch *i* enters
//! stage 0 at the simulated time micro-batch *i − max_in_flight* was
//! delivered (window 1 therefore reproduces the serial schedule
//! exactly). Wall clock still elapses the same way (nodes sleep out
//! their dilated compute, links sleep out transfers, the feeder waits
//! for delivery credits) so wall-time measurements agree with the
//! simulated makespan, but the *reported* numbers never mix host
//! wall-clock into simulated totals. Per-stage occupancy and bubble
//! (idle-gap) time are exported as [`StageCounter`]s for the metrics
//! layer.

use std::sync::mpsc::{channel, sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::timing::{CriticalPath, PipelineTiming};
use crate::cluster::{NodeSpec, SimParams, VirtualNode};
use crate::deployer::Deployment;
use crate::metrics::StageCounter;
use crate::runtime::Tensor;

/// Streaming engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Rows per micro-batch. For a real [`Deployment`] this must equal
    /// the compiled artifact batch (`Deployment::batch`).
    pub micro_batch_rows: usize,
    /// Admission window: micro-batches allowed between leader admission
    /// and leader delivery at once (credit-based), and the bound on each
    /// stage's queue. 1 degenerates to the serial schedule; larger
    /// windows overlap more stages. Modeled in both wall clock (the
    /// feeder waits for a delivery credit) and the simulated critical
    /// path (an admitted micro-batch's clock starts at the sim time its
    /// window slot freed).
    pub max_in_flight: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { micro_batch_rows: 1, max_in_flight: 4 }
    }
}

/// What one engine traversal produces.
pub struct EngineRun {
    pub output: Tensor,
    /// Simulated critical-path timing (totals plus per-stage split).
    pub timing: PipelineTiming,
    /// Per-stage occupancy/bubble counters for the metrics layer.
    pub stage_counters: Vec<StageCounter>,
}

/// A chain of pipeline stages the engine can drive. Implemented by
/// [`DeploymentStages`] (real deployed partitions) and [`SimStages`]
/// (virtual nodes with synthetic compute, for benches and tests — no
/// PJRT artifacts needed).
///
/// `execute` blocks for the stage's simulated duration (each virtual
/// node serializes its own device), and the comm methods sleep out the
/// link model — wall time tracks sim time, while the engine separately
/// accounts sim-ms via the critical path.
pub trait StageExec: Sync {
    fn num_stages(&self) -> usize;

    /// Id of the node hosting `stage` (for accounting).
    fn node_id(&self, stage: usize) -> usize;

    /// Move `bytes` of activation into `stage` (from the leader for
    /// stage 0, from stage `k-1`'s node otherwise). Returns simulated ms.
    fn comm_in(&self, stage: usize, bytes: u64) -> f64;

    /// Final hop: last stage's node back to the leader. Simulated ms.
    fn comm_out(&self, bytes: u64) -> f64;

    /// Run one micro-batch on `stage`. Returns the output activation and
    /// the simulated compute ms.
    fn execute(&self, stage: usize, input: Tensor) -> Result<(Tensor, f64)>;
}

/// Shared link model for node-hosted stage chains: the leader is a
/// zero-latency infinite-bandwidth endpoint, so a transfer charges the
/// upstream node's send (when there is one) plus the downstream node's
/// receive. Both [`DeploymentStages`] and [`SimStages`] route through
/// these so the synthetic model used by benches/tests can never
/// silently diverge from the real deployment path.
fn node_comm_in(prev: Option<&VirtualNode>, to: &VirtualNode, bytes: u64) -> f64 {
    let mut ms = 0.0;
    if let Some(p) = prev {
        ms += p.link().send(bytes);
    }
    ms + to.link().receive(bytes)
}

fn node_comm_out(last: Option<&VirtualNode>, bytes: u64) -> f64 {
    match last {
        Some(n) => n.link().send(bytes),
        None => 0.0,
    }
}

/// [`StageExec`] over a live [`Deployment`]: real executors on virtual
/// nodes, identical per-stage semantics to `pipeline::run`.
pub struct DeploymentStages<'a> {
    dep: &'a Deployment,
}

impl<'a> DeploymentStages<'a> {
    pub fn new(dep: &'a Deployment) -> DeploymentStages<'a> {
        DeploymentStages { dep }
    }
}

impl StageExec for DeploymentStages<'_> {
    fn num_stages(&self) -> usize {
        self.dep.stages.len()
    }

    fn node_id(&self, stage: usize) -> usize {
        self.dep.stages[stage].node.id()
    }

    fn comm_in(&self, stage: usize, bytes: u64) -> f64 {
        let prev = stage
            .checked_sub(1)
            .map(|p| &*self.dep.stages[p].node);
        node_comm_in(prev, &self.dep.stages[stage].node, bytes)
    }

    fn comm_out(&self, bytes: u64) -> f64 {
        node_comm_out(self.dep.stages.last().map(|s| &*s.node), bytes)
    }

    fn execute(&self, stage: usize, input: Tensor) -> Result<(Tensor, f64)> {
        let st = &self.dep.stages[stage];
        let executor = Arc::clone(&st.executor);
        let blocks = st.blocks.clone();
        let (out, outcome) = st
            .node
            .execute_costed(move || executor.run_chain(blocks, input))?;
        Ok((out, outcome.sim_ms))
    }
}

/// Synthetic [`StageExec`]: each stage applies a fixed row-wise
/// elementwise transform with a fixed nominal compute cost on its
/// virtual node (CPU-quota dilation applies). Lets the engine be
/// exercised, tested, and benchmarked without compiled artifacts.
pub struct SimStages {
    nodes: Vec<Arc<VirtualNode>>,
    nominal_ms: f64,
}

impl SimStages {
    pub fn new(nodes: Vec<Arc<VirtualNode>>, nominal_ms: f64) -> SimStages {
        SimStages { nodes, nominal_ms }
    }

    /// One stage per CPU share (e.g. `&[1.0, 0.6, 0.4]` — the paper's
    /// heterogeneous cluster), default LAN links, no paging.
    pub fn heterogeneous(cpu_shares: &[f64], nominal_ms: f64) -> SimStages {
        let params = SimParams {
            time_scale: 1.0,
            page_factor: 4.0,
            runtime_overhead_mb: 0.0,
        };
        let nodes = cpu_shares
            .iter()
            .enumerate()
            .map(|(i, &cpu)| {
                Arc::new(VirtualNode::new(
                    i,
                    NodeSpec::new(&format!("sim-{i}"), cpu, 1024.0),
                    params.clone(),
                ))
            })
            .collect();
        SimStages::new(nodes, nominal_ms)
    }

    pub fn nodes(&self) -> &[Arc<VirtualNode>] {
        &self.nodes
    }
}

impl StageExec for SimStages {
    fn num_stages(&self) -> usize {
        self.nodes.len()
    }

    fn node_id(&self, stage: usize) -> usize {
        self.nodes[stage].id()
    }

    fn comm_in(&self, stage: usize, bytes: u64) -> f64 {
        let prev = stage.checked_sub(1).map(|p| &*self.nodes[p]);
        node_comm_in(prev, &self.nodes[stage], bytes)
    }

    fn comm_out(&self, bytes: u64) -> f64 {
        node_comm_out(self.nodes.last().map(|n| &**n), bytes)
    }

    fn execute(&self, stage: usize, input: Tensor) -> Result<(Tensor, f64)> {
        let nominal = self.nominal_ms;
        let (out, outcome) = self.nodes[stage].execute_costed(move || {
            // Row-wise elementwise transform: bit-identical under any
            // micro-batch split.
            let data = input.data.iter().map(|v| v * 1.5 + 0.25).collect();
            let t = Tensor::new(input.shape.clone(), data)?;
            Ok((t, nominal))
        })?;
        Ok((out, outcome.sim_ms))
    }
}

/// Split a `[rows, ...]` tensor into row-contiguous chunks of up to
/// `chunk_rows` rows (the last chunk may be short).
pub fn split_rows(t: &Tensor, chunk_rows: usize) -> Result<Vec<Tensor>> {
    anyhow::ensure!(!t.shape.is_empty(), "cannot split a scalar tensor");
    anyhow::ensure!(chunk_rows > 0, "chunk_rows must be > 0");
    let rows = t.shape[0];
    anyhow::ensure!(rows > 0, "empty batch");
    let row_len: usize = t.shape.iter().skip(1).product();
    let mut out = Vec::with_capacity((rows + chunk_rows - 1) / chunk_rows);
    let mut r = 0;
    while r < rows {
        let take = chunk_rows.min(rows - r);
        let mut shape = t.shape.clone();
        shape[0] = take;
        out.push(Tensor::new(
            shape,
            t.data[r * row_len..(r + take) * row_len].to_vec(),
        )?);
        r += take;
    }
    Ok(out)
}

/// Reassemble chunks produced by [`split_rows`] (in order).
pub fn concat_rows(chunks: &[Tensor]) -> Result<Tensor> {
    anyhow::ensure!(!chunks.is_empty(), "no chunks to concatenate");
    let tail: &[usize] = &chunks[0].shape[1..];
    let mut rows = 0;
    let mut data = Vec::new();
    for c in chunks {
        anyhow::ensure!(
            !c.shape.is_empty() && &c.shape[1..] == tail,
            "mismatched chunk shapes"
        );
        rows += c.shape[0];
        data.extend_from_slice(&c.data);
    }
    let mut shape = chunks[0].shape.clone();
    shape[0] = rows;
    Tensor::new(shape, data)
}

/// One micro-batch moving through the stage queues. `ready_ms` is the
/// simulated time it left the previous stage.
struct Msg {
    idx: usize,
    ready_ms: f64,
    tensor: Tensor,
}

type Flow = std::result::Result<Msg, anyhow::Error>;

/// Serial comparator with identical accounting: every micro-batch runs
/// through all stages before the next one starts (chunk-major order).
/// With a single chunk this is exactly `pipeline::run`'s schedule —
/// `pipeline::run` delegates here.
pub fn run_serial<S: StageExec + ?Sized>(
    stages: &S,
    input: &Tensor,
    micro_batch_rows: usize,
) -> Result<EngineRun> {
    let n_stages = stages.num_stages();
    anyhow::ensure!(n_stages > 0, "engine needs >= 1 stage");
    let chunks = split_rows(input, micro_batch_rows)?;
    let node_ids: Vec<usize> = (0..n_stages).map(|k| stages.node_id(k)).collect();
    let mut cp = CriticalPath::new(&node_ids);
    let mut outs = Vec::with_capacity(chunks.len());
    // Serial schedule: chunk i may only enter stage 0 after chunk i-1 is
    // delivered, so `ready` carries across chunks.
    let mut prev_done = 0.0;
    for (idx, chunk) in chunks.into_iter().enumerate() {
        let mut act = chunk;
        let mut ready = prev_done;
        for k in 0..n_stages {
            let bytes = act.byte_len();
            let comm_ms = stages.comm_in(k, bytes);
            let (out, compute_ms) = stages
                .execute(k, act)
                .with_context(|| format!("pipeline stage {k}, micro-batch {idx}"))?;
            ready = cp.step(k, ready, comm_ms, compute_ms, bytes);
            act = out;
        }
        let out_bytes = act.byte_len();
        let hop = stages.comm_out(out_bytes);
        prev_done = cp.deliver(hop, out_bytes, ready);
        outs.push(act);
    }
    Ok(EngineRun {
        output: concat_rows(&outs)?,
        timing: cp.timing(),
        stage_counters: cp.counters(),
    })
}

/// Streamed execution: split `input` into micro-batches and drive them
/// through per-stage bounded queues with one driver thread per stage, up
/// to `cfg.max_in_flight` micro-batches in flight. Output rows are
/// reassembled in request order and are bit-identical to [`run_serial`].
pub fn run_streamed<S: StageExec + ?Sized>(
    stages: &S,
    input: &Tensor,
    cfg: &EngineConfig,
) -> Result<EngineRun> {
    let n_stages = stages.num_stages();
    anyhow::ensure!(n_stages > 0, "engine needs >= 1 stage");
    anyhow::ensure!(cfg.max_in_flight > 0, "max_in_flight must be > 0");
    let chunks = split_rows(input, cfg.micro_batch_rows)?;
    let n_chunks = chunks.len();
    let node_ids: Vec<usize> = (0..n_stages).map(|k| stages.node_id(k)).collect();
    let cp = Mutex::new(CriticalPath::new(&node_ids));

    // Channel k feeds stage k; channel n_stages is the collector. The
    // global in-flight limit is the credit window below; the bounded
    // queues add per-stage back-pressure so a stalled stage blocks its
    // upstream driver instead of buffering unboundedly.
    let mut senders = Vec::with_capacity(n_stages + 1);
    let mut receivers = Vec::with_capacity(n_stages + 1);
    for _ in 0..=n_stages {
        let (tx, rx) = sync_channel::<Flow>(cfg.max_in_flight);
        senders.push(tx);
        receivers.push(rx);
    }
    let mut senders = senders.into_iter();
    let mut receivers = receivers.into_iter();
    let feed_tx = senders.next().expect("feeder sender");

    // Credit-based admission window: the feeder spends one credit per
    // admitted micro-batch; the collector returns a credit (carrying the
    // simulated time the slot freed) per delivery. This is what makes
    // `max_in_flight` real in *both* clocks — the feeder's wall-clock
    // wait and the admitted micro-batch's simulated start time. A
    // window of 1 degenerates to the serial schedule.
    let (credit_tx, credit_rx) = channel::<f64>();
    for _ in 0..cfg.max_in_flight {
        let _ = credit_tx.send(0.0);
    }

    let mut outs: Vec<Option<Tensor>> = (0..n_chunks).map(|_| None).collect();
    let mut first_err: Option<anyhow::Error> = None;

    std::thread::scope(|scope| {
        // One driver thread per stage.
        for k in 0..n_stages {
            let rx: Receiver<Flow> = receivers.next().expect("stage receiver");
            let tx: SyncSender<Flow> = senders.next().expect("stage sender");
            let cp = &cp;
            scope.spawn(move || {
                while let Ok(flow) = rx.recv() {
                    let next: Flow = match flow {
                        Err(e) => Err(e), // forward downstream; no compute
                        Ok(m) => {
                            let bytes = m.tensor.byte_len();
                            let comm_ms = stages.comm_in(k, bytes);
                            match stages.execute(k, m.tensor) {
                                Ok((out, compute_ms)) => {
                                    let ready = cp.lock().unwrap().step(
                                        k, m.ready_ms, comm_ms, compute_ms, bytes,
                                    );
                                    Ok(Msg { idx: m.idx, ready_ms: ready, tensor: out })
                                }
                                Err(e) => Err(e.context(format!(
                                    "pipeline stage {k}, micro-batch {}",
                                    m.idx
                                ))),
                            }
                        }
                    };
                    if tx.send(next).is_err() {
                        break; // downstream gone
                    }
                }
                // rx disconnected: upstream finished; dropping tx cascades
                // shutdown to the next stage.
            });
        }

        let collect_rx = receivers.next().expect("collector receiver");

        // Feeder: micro-batches are admitted as window credits free up;
        // each admitted chunk's simulated clock starts when its slot's
        // previous occupant was delivered.
        scope.spawn(move || {
            for (idx, tensor) in chunks.into_iter().enumerate() {
                let ready_ms = match credit_rx.recv() {
                    Ok(t) => t,
                    Err(_) => break, // collector gone
                };
                if feed_tx.send(Ok(Msg { idx, ready_ms, tensor })).is_err() {
                    break;
                }
            }
        });

        // Collector: every micro-batch yields exactly one terminal
        // message (output or forwarded error) and returns its window
        // credit either way.
        for _ in 0..n_chunks {
            match collect_rx.recv() {
                Ok(Ok(m)) => {
                    let bytes = m.tensor.byte_len();
                    let hop = stages.comm_out(bytes);
                    let done = cp.lock().unwrap().deliver(hop, bytes, m.ready_ms);
                    outs[m.idx] = Some(m.tensor);
                    let _ = credit_tx.send(done);
                }
                Ok(Err(e)) => {
                    let _ = credit_tx.send(cp.lock().unwrap().makespan_ms());
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => break, // a stage driver died
            }
        }
        // Dropping credit_tx here unblocks a feeder still waiting on a
        // credit after an early exit.
        drop(credit_tx);
    });

    if let Some(e) = first_err {
        return Err(e);
    }
    let collected: Vec<Tensor> = outs
        .into_iter()
        .map(|o| o.ok_or_else(|| anyhow::anyhow!("pipeline dropped a micro-batch")))
        .collect::<Result<_>>()?;
    let cp = cp.into_inner().expect("critical path lock");
    Ok(EngineRun {
        output: concat_rows(&collected)?,
        timing: cp.timing(),
        stage_counters: cp.counters(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn input(rows: usize, cols: usize) -> Tensor {
        let data = (0..rows * cols).map(|i| i as f32 * 0.5 - 3.0).collect();
        Tensor::new(vec![rows, cols], data).unwrap()
    }

    #[test]
    fn split_concat_roundtrip() {
        let t = input(5, 3);
        let chunks = split_rows(&t, 2).unwrap();
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0].shape, vec![2, 3]);
        assert_eq!(chunks[2].shape, vec![1, 3]);
        assert_eq!(concat_rows(&chunks).unwrap(), t);
        assert!(split_rows(&t, 0).is_err());
        assert!(concat_rows(&[]).is_err());
    }

    #[test]
    fn streamed_output_is_bit_identical_to_serial() {
        let stages = SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0);
        let t = input(6, 8);
        let serial = run_serial(&stages, &t, 1).unwrap();
        let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: 4 };
        let streamed = run_streamed(&stages, &t, &cfg).unwrap();
        assert_eq!(serial.output, streamed.output);
        // Also identical to a single full-batch traversal (row-wise ops).
        let whole = run_serial(&stages, &t, 6).unwrap();
        assert_eq!(whole.output, streamed.output);
    }

    #[test]
    fn serial_total_equals_compute_plus_comm() {
        // The ISSUE-1 regression at engine level: a serial single-chunk
        // traversal's simulated total must be the sum of its parts.
        let stages = SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0);
        let t = input(2, 4);
        let run = run_serial(&stages, &t, 2).unwrap();
        let tm = &run.timing;
        assert!(
            (tm.total_ms - (tm.compute_ms + tm.comm_ms)).abs() < 1e-6,
            "total {} vs compute {} + comm {}",
            tm.total_ms, tm.compute_ms, tm.comm_ms
        );
        assert_eq!(tm.stages.len(), 3);
        assert!(tm.compute_ms > 0.0 && tm.comm_ms > 0.0);
    }

    #[test]
    fn streaming_beats_serial_sim_time() {
        let stages = SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0);
        let t = input(6, 4);
        let serial = run_serial(&stages, &t, 1).unwrap();
        let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: 4 };
        let streamed = run_streamed(&stages, &t, &cfg).unwrap();
        assert!(
            streamed.timing.total_ms < serial.timing.total_ms,
            "streamed {:.2} ms must beat serial {:.2} ms",
            streamed.timing.total_ms,
            serial.timing.total_ms
        );
        // Same work was done: compute totals match up to dilation noise
        // (nominal costs are fixed, so they match closely).
        assert!(
            (streamed.timing.compute_ms - serial.timing.compute_ms).abs()
                < 0.25 * serial.timing.compute_ms,
            "compute {} vs {}",
            streamed.timing.compute_ms,
            serial.timing.compute_ms
        );
        // The slowest stage stays busy: its bubble time is small relative
        // to the makespan, and every stage saw every micro-batch.
        for c in &streamed.stage_counters {
            assert_eq!(c.micro_batches, 6);
        }
    }

    #[test]
    fn errors_propagate_with_stage_context() {
        struct Failing;
        impl StageExec for Failing {
            fn num_stages(&self) -> usize {
                2
            }
            fn node_id(&self, stage: usize) -> usize {
                stage
            }
            fn comm_in(&self, _stage: usize, _bytes: u64) -> f64 {
                0.0
            }
            fn comm_out(&self, _bytes: u64) -> f64 {
                0.0
            }
            fn execute(&self, stage: usize, input: Tensor) -> Result<(Tensor, f64)> {
                anyhow::ensure!(stage == 0, "boom at stage {stage}");
                Ok((input, 1.0))
            }
        }
        let t = input(4, 2);
        let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: 2 };
        let err = run_streamed(&Failing, &t, &cfg).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("stage 1"), "unexpected error: {msg}");
        assert!(run_serial(&Failing, &t, 1).is_err());
    }

    #[test]
    fn window_of_one_reproduces_serial_schedule() {
        // max_in_flight = 1: each micro-batch is admitted only when the
        // previous one is delivered — the streamed makespan must equal
        // the serial one, and wider windows must strictly beat it.
        let stages = SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0);
        let t = input(4, 4);
        let serial = run_serial(&stages, &t, 1).unwrap();
        let w1 = run_streamed(
            &stages,
            &t,
            &EngineConfig { micro_batch_rows: 1, max_in_flight: 1 },
        )
        .unwrap();
        assert!(
            (w1.timing.total_ms - serial.timing.total_ms).abs() < 1e-9,
            "window-1 streamed {} must equal serial {}",
            w1.timing.total_ms,
            serial.timing.total_ms
        );
        let w4 = run_streamed(
            &stages,
            &t,
            &EngineConfig { micro_batch_rows: 1, max_in_flight: 4 },
        )
        .unwrap();
        assert!(
            w4.timing.total_ms < w1.timing.total_ms,
            "window 4 ({}) must beat window 1 ({})",
            w4.timing.total_ms,
            w1.timing.total_ms
        );
        assert_eq!(w1.output, w4.output);
    }

    #[test]
    fn single_stage_single_chunk_degenerates() {
        let stages = SimStages::heterogeneous(&[1.0], 1.0);
        let t = input(2, 2);
        let cfg = EngineConfig { micro_batch_rows: 2, max_in_flight: 1 };
        let run = run_streamed(&stages, &t, &cfg).unwrap();
        assert_eq!(run.output.shape, vec![2, 2]);
        assert_eq!(run.stage_counters.len(), 1);
        assert_eq!(run.stage_counters[0].micro_batches, 1);
        let tm = &run.timing;
        assert!((tm.total_ms - (tm.compute_ms + tm.comm_ms)).abs() < 1e-6);
    }
}
