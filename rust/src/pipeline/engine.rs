//! Streaming pipeline-parallel execution engine.
//!
//! `pipeline::run` walks one batch through the partition chain strictly
//! serially: stage *k+1* is idle while stage *k* computes, so a
//! heterogeneous cluster runs at the *sum* of its stage times. This
//! engine instead gives every deployment stage its own bounded work
//! queue and driver thread, splits an admitted batch into row-wise
//! micro-batches, and keeps up to `max_in_flight` micro-batches moving
//! through the chain at once — stage *k* computes micro-batch *i+1*
//! while stage *k+1* receives and computes micro-batch *i*. End-to-end
//! time drops from `Σ_k cost_k` per batch toward
//! `fill + n_micro · max_k cost_k` (the classic pipeline bound), which
//! is where AMP4EC's throughput multiple over serial execution comes
//! from.
//!
//! ## Micro-batch model
//!
//! A micro-batch is a contiguous slice of batch rows
//! ([`split_rows`]/[`concat_rows`]). Every model stage is row-wise
//! (per-sample inference), so streaming is **bit-identical** to serial
//! execution — pinned by tests and `benches/pipeline_engine.rs`. For a
//! real deployment the micro-batch row count must equal the batch the
//! stage artifacts were compiled for (`Deployment::batch`); the
//! router's admission batch is then `micro_batch · max_in_flight` rows
//! (see `DistributedService`).
//!
//! ## Sim-time model
//!
//! All engine accounting is in **simulated milliseconds** end-to-end via
//! the critical-path recurrence in [`super::timing::CriticalPath`]:
//! `ready[k] = max(ready[k-1] + comm, stage_free[k]) + compute`, with
//! leader admission gated by a credit window — micro-batch *i* enters
//! stage 0 at the simulated time micro-batch *i − max_in_flight* was
//! delivered (window 1 therefore reproduces the serial schedule
//! exactly). Wall clock still elapses the same way (nodes sleep out
//! their dilated compute, links sleep out transfers, the feeder waits
//! for delivery credits) so wall-time measurements agree with the
//! simulated makespan, but the *reported* numbers never mix host
//! wall-clock into simulated totals. Per-stage occupancy and bubble
//! (idle-gap) time are exported as [`StageCounter`]s for the metrics
//! layer.
//!
//! ## Persistent cross-batch streaming
//!
//! [`run_streamed`] tears its stage drivers down when its one batch
//! drains, so successive batches each pay a fill+drain bubble of
//! ~(stages − 1) micro-batch slots plus thread spawn/join.
//! [`PersistentEngine`] promotes the same drivers into long-lived
//! threads: per-stage bounded queues and the critical-path clock live
//! for the whole serve run, micro-batches from *successive* batches are
//! tagged `(batch, idx)` and flow back-to-back with no inter-batch
//! drain, and per-batch outputs are reassembled by sequence-numbered
//! completion tracking in the collector. The `ready[k]` recurrence and
//! shared-node serialization carry across batch boundaries unchanged —
//! stage `free` times simply keep advancing — so the accounting stays
//! device-honest while the drain bubbles disappear. Both entry points
//! share one driver/feeder/collector core, so the one-shot and
//! persistent schedules can never diverge.
//!
//! ## Per-stage credit windows
//!
//! Admission flows through **per-stage credit windows** rather than a
//! single global window: window *k* bounds the micro-batches admitted
//! but not yet past stage *k* (the last window: not yet delivered). The
//! feeder spends one credit from every window per admission and the
//! admitted micro-batch's simulated clock starts at the max of the
//! credit values; stage *k*'s driver returns its credit at completion,
//! the collector returns the last window's at delivery. Equal budgets
//! make the last window subsume the rest, degenerating *bit-exactly*
//! to the single global window (pinned by equivalence tests), while
//! shaped budgets — small on fast early stages, deep on the delivery
//! window ([`budgets_from_profile`]) — let a skewed chain run at the
//! bottleneck's true rate with the same credit total.
//!
//! ## Batch coalescing
//!
//! With [`PersistentEngineConfig::coalesce`] the feeder merges adjacent
//! small submissions into one *transport* when that strictly reduces
//! the micro-batch count (short tails packing together). Members keep
//! their row ranges; delivery re-splits the transport's output so every
//! waiter receives exactly its own rows, bit-identical to an
//! uncoalesced run. A failure (or stage panic — drivers catch unwinds)
//! anywhere in a transport fails only that transport's members.
//!
//! On top of the persistent credits sits an optional **adaptive window
//! controller** ([`AdaptiveDepthConfig`]): per completed batch it reads
//! the bottleneck stage's bubble fraction from the batch-local
//! [`StageCounter`]s and widens the credit window while bubbles remain
//! (adding a credit), or narrows it after consecutive bubble-free
//! batches (swallowing a returned credit) — converging to the smallest
//! window that saturates the bottleneck stage. In *both* modes,
//! widening is vetoed while the bottleneck node's wall-clock backlog
//! ([`StageExec::backlog`], `Executor::queue_depth`) exceeds its budget
//! — device congestion is not credit starvation (this second signal is
//! the one intentional divergence from the PR-2 controller, which had
//! no backlog input). In per-stage mode
//! ([`PersistentEngineConfig::per_stage`]) budgets additionally resize
//! independently: widening targets the smallest *starved* window
//! instead of the whole chain. To tell window
//! pressure from mere arrival spacing, the feeder marks a batch
//! *credit-starved* (per window) when it held one of its micro-batches
//! while that window was empty: starved batches are observed with their
//! full bubbles (entry gaps included — the window itself delayed them,
//! the only signal a single-chunk batch can produce), while un-starved
//! batches have each stage's entry gap excluded, so light sequential
//! traffic never ratchets the window toward the maximum.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::timing::{CriticalPath, PipelineTiming, StageTiming};
use crate::cluster::{NodeSpec, SimParams, VirtualNode};
use crate::deployer::Deployment;
use crate::metrics::StageCounter;
use crate::runtime::Tensor;

/// Streaming engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Rows per micro-batch. For a real [`Deployment`] this must equal
    /// the compiled artifact batch (`Deployment::batch`).
    pub micro_batch_rows: usize,
    /// Admission window: micro-batches allowed between leader admission
    /// and leader delivery at once (credit-based), and the bound on each
    /// stage's queue. 1 degenerates to the serial schedule; larger
    /// windows overlap more stages. Modeled in both wall clock (the
    /// feeder waits for a delivery credit) and the simulated critical
    /// path (an admitted micro-batch's clock starts at the sim time its
    /// window slot freed).
    pub max_in_flight: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { micro_batch_rows: 1, max_in_flight: 4 }
    }
}

/// What one engine traversal produces.
pub struct EngineRun {
    pub output: Tensor,
    /// Simulated critical-path timing (totals plus per-stage split).
    pub timing: PipelineTiming,
    /// Per-stage occupancy/bubble counters for the metrics layer.
    pub stage_counters: Vec<StageCounter>,
}

/// A chain of pipeline stages the engine can drive. Implemented by
/// [`DeploymentStages`] (real deployed partitions) and [`SimStages`]
/// (virtual nodes with synthetic compute, for benches and tests — no
/// PJRT artifacts needed).
///
/// `execute` blocks for the stage's simulated duration (each virtual
/// node serializes its own device), and the comm methods sleep out the
/// link model — wall time tracks sim time, while the engine separately
/// accounts sim-ms via the critical path.
pub trait StageExec: Sync {
    fn num_stages(&self) -> usize;

    /// Id of the node hosting `stage` (for accounting).
    fn node_id(&self, stage: usize) -> usize;

    /// Wall-clock backlog on the node hosting `stage` (chain runs
    /// submitted but not completed — `Executor::queue_depth` for real
    /// deployments). The adaptive window controller reads this as a
    /// second signal: a stage whose device is already backed up gains
    /// nothing from more credits, so widening is vetoed. Defaults to 0
    /// (no backlog signal).
    fn backlog(&self, stage: usize) -> usize {
        let _ = stage;
        0
    }

    /// Move `bytes` of activation into `stage` (from the leader for
    /// stage 0, from stage `k-1`'s node otherwise). Returns simulated ms.
    fn comm_in(&self, stage: usize, bytes: u64) -> f64;

    /// Final hop: last stage's node back to the leader. Simulated ms.
    fn comm_out(&self, bytes: u64) -> f64;

    /// Run one micro-batch on `stage`. Returns the output activation and
    /// the simulated compute ms.
    fn execute(&self, stage: usize, input: Tensor) -> Result<(Tensor, f64)>;

    /// Number of replicas serving `stage` (>= 1). Replicas run the same
    /// blocks on different nodes; the engine sprays micro-batches across
    /// them with per-replica credit windows. Defaults to 1 — every
    /// unreplicated implementation degenerates to the single-chain
    /// engine bit-exactly.
    fn replicas(&self, stage: usize) -> usize {
        let _ = stage;
        1
    }

    /// Node hosting replica `replica` of `stage` (for accounting).
    /// Replica 0 must equal [`StageExec::node_id`].
    fn replica_node_id(&self, stage: usize, replica: usize) -> usize {
        let _ = replica;
        self.node_id(stage)
    }

    /// Whether replica `replica` of `stage` can currently take work.
    /// Senders route micro-batches round-robin over the alive set, so a
    /// dead replica (e.g. a closed wire connection) fails only what was
    /// already in flight to it. Defaults to always-alive.
    fn replica_alive(&self, stage: usize, replica: usize) -> bool {
        let _ = (stage, replica);
        true
    }

    /// Ingress transfer into a specific replica of `stage`. Defaults to
    /// the stage-level link model (exact for `replicas() == 1`).
    fn comm_in_on(&self, stage: usize, replica: usize, bytes: u64) -> f64 {
        let _ = replica;
        self.comm_in(stage, bytes)
    }

    /// Run one micro-batch on a specific replica of `stage`. Defaults to
    /// the primary path — `replicas() == 1` implementations never see
    /// `replica > 0`.
    fn execute_on(
        &self,
        stage: usize,
        replica: usize,
        input: Tensor,
    ) -> Result<(Tensor, f64)> {
        let _ = replica;
        self.execute(stage, input)
    }
}

/// Shared link model for node-hosted stage chains: the leader is a
/// zero-latency infinite-bandwidth endpoint, so a transfer charges the
/// upstream node's send (when there is one) plus the downstream node's
/// receive. Both [`DeploymentStages`] and [`SimStages`] route through
/// these so the synthetic model used by benches/tests can never
/// silently diverge from the real deployment path.
pub(crate) fn node_comm_in(
    prev: Option<&VirtualNode>,
    to: &VirtualNode,
    bytes: u64,
) -> f64 {
    let mut ms = 0.0;
    if let Some(p) = prev {
        ms += p.link().send(bytes);
    }
    ms + to.link().receive(bytes)
}

pub(crate) fn node_comm_out(last: Option<&VirtualNode>, bytes: u64) -> f64 {
    match last {
        Some(n) => n.link().send(bytes),
        None => 0.0,
    }
}

/// [`StageExec`] over a live [`Deployment`]: real executors on virtual
/// nodes, identical per-stage semantics to `pipeline::run`. Generic
/// over how the deployment is held: `DeploymentStages<&Deployment>`
/// borrows for a one-shot traversal, while
/// `DeploymentStages<Arc<Deployment>>` owns a reference so a
/// [`PersistentEngine`]'s long-lived driver threads can keep executing
/// against it.
pub struct DeploymentStages<D: std::ops::Deref<Target = Deployment>> {
    dep: D,
}

impl<D: std::ops::Deref<Target = Deployment>> DeploymentStages<D> {
    pub fn new(dep: D) -> DeploymentStages<D> {
        DeploymentStages { dep }
    }
}

impl<D: std::ops::Deref<Target = Deployment> + Sync> StageExec for DeploymentStages<D> {
    fn num_stages(&self) -> usize {
        self.dep.stages.len()
    }

    fn node_id(&self, stage: usize) -> usize {
        self.dep.stages[stage].node.id()
    }

    fn comm_in(&self, stage: usize, bytes: u64) -> f64 {
        let prev = stage
            .checked_sub(1)
            .map(|p| &*self.dep.stages[p].node);
        node_comm_in(prev, &self.dep.stages[stage].node, bytes)
    }

    fn comm_out(&self, bytes: u64) -> f64 {
        node_comm_out(self.dep.stages.last().map(|s| &*s.node), bytes)
    }

    fn execute(&self, stage: usize, input: Tensor) -> Result<(Tensor, f64)> {
        self.execute_on(stage, 0, input)
    }

    fn backlog(&self, stage: usize) -> usize {
        self.dep.stages[stage].executor.queue_depth()
    }

    fn replicas(&self, stage: usize) -> usize {
        self.dep.stages[stage].replica_count()
    }

    fn replica_node_id(&self, stage: usize, replica: usize) -> usize {
        self.dep.stages[stage].replica_node(replica).id()
    }

    fn comm_in_on(&self, stage: usize, replica: usize, bytes: u64) -> f64 {
        // The upstream sender is charged at its primary: which replica
        // produced a given micro-batch is a routing detail the link
        // model deliberately ignores (all replicas of a stage share one
        // link class).
        let prev = stage
            .checked_sub(1)
            .map(|p| &*self.dep.stages[p].node);
        node_comm_in(
            prev,
            self.dep.stages[stage].replica_node(replica),
            bytes,
        )
    }

    fn execute_on(
        &self,
        stage: usize,
        replica: usize,
        input: Tensor,
    ) -> Result<(Tensor, f64)> {
        let st = &self.dep.stages[stage];
        let (node, executor, blocks) = if replica == 0 {
            (&st.node, &st.executor, st.blocks.clone())
        } else {
            let r = &st.replicas[replica - 1];
            (&r.node, &r.executor, r.blocks.clone())
        };
        let executor = Arc::clone(executor);
        let (out, outcome) =
            node.execute_costed(move || executor.run_chain(blocks, input))?;
        Ok((out, outcome.sim_ms))
    }
}

/// Synthetic [`StageExec`]: each stage applies a fixed row-wise
/// elementwise transform with a fixed nominal compute cost on its
/// virtual node (CPU-quota dilation applies). Lets the engine be
/// exercised, tested, and benchmarked without compiled artifacts.
pub struct SimStages {
    nodes: Vec<Arc<VirtualNode>>,
    /// Extra replicas per stage: `extra[k][j]` hosts replica `j + 1` of
    /// stage `k` (the primary is `nodes[k]`). Empty for unreplicated
    /// chains, so every pre-existing constructor is the k=1 case.
    extra: Vec<Vec<Arc<VirtualNode>>>,
    nominal_ms: f64,
}

impl SimStages {
    pub fn new(nodes: Vec<Arc<VirtualNode>>, nominal_ms: f64) -> SimStages {
        let extra = nodes.iter().map(|_| Vec::new()).collect();
        SimStages { nodes, extra, nominal_ms }
    }

    /// One stage per CPU share (e.g. `&[1.0, 0.6, 0.4]` — the paper's
    /// heterogeneous cluster), default LAN links, no paging.
    pub fn heterogeneous(cpu_shares: &[f64], nominal_ms: f64) -> SimStages {
        SimStages::with_replicas(
            cpu_shares,
            nominal_ms,
            &vec![1; cpu_shares.len()],
        )
    }

    /// Heterogeneous chain with `replica_counts[k]` replicas of stage
    /// `k`, each replica on its own fresh virtual node with the stage's
    /// CPU share (distinct node ids, so replica device clocks are
    /// independent — the scale-out speedup the critical path can then
    /// actually model). Replica ids follow the primaries (`n ..`).
    pub fn with_replicas(
        cpu_shares: &[f64],
        nominal_ms: f64,
        replica_counts: &[usize],
    ) -> SimStages {
        assert_eq!(
            cpu_shares.len(),
            replica_counts.len(),
            "one replica count per stage"
        );
        assert!(
            replica_counts.iter().all(|&r| r >= 1),
            "every stage needs >= 1 replica"
        );
        let params = SimParams {
            time_scale: 1.0,
            page_factor: 4.0,
            runtime_overhead_mb: 0.0,
        };
        let mk = |id: usize, cpu: f64| {
            Arc::new(VirtualNode::new(
                id,
                NodeSpec::new(&format!("sim-{id}"), cpu, 1024.0),
                params.clone(),
            ))
        };
        let nodes: Vec<_> = cpu_shares
            .iter()
            .enumerate()
            .map(|(i, &cpu)| mk(i, cpu))
            .collect();
        let mut next_id = cpu_shares.len();
        let extra = cpu_shares
            .iter()
            .enumerate()
            .map(|(k, &cpu)| {
                (1..replica_counts[k])
                    .map(|_| {
                        let n = mk(next_id, cpu);
                        next_id += 1;
                        n
                    })
                    .collect()
            })
            .collect();
        SimStages { nodes, extra, nominal_ms }
    }

    pub fn nodes(&self) -> &[Arc<VirtualNode>] {
        &self.nodes
    }

    fn node_for(&self, stage: usize, replica: usize) -> &Arc<VirtualNode> {
        if replica == 0 {
            &self.nodes[stage]
        } else {
            &self.extra[stage][replica - 1]
        }
    }

    fn run_on(
        &self,
        node: &VirtualNode,
        input: Tensor,
    ) -> Result<(Tensor, f64)> {
        let nominal = self.nominal_ms;
        let (out, outcome) = node.execute_costed(move || {
            // Row-wise elementwise transform: bit-identical under any
            // micro-batch split (and on any replica). Output storage
            // comes from the buffer pool (producing values is compute,
            // not a data-plane copy); the consumed input view is
            // recycled.
            let mut data =
                crate::util::pool::BufferPool::global().take(input.len());
            data.extend(input.data().iter().map(|v| v * 1.5 + 0.25));
            let t = Tensor::new(input.shape.clone(), data)?;
            input.recycle();
            Ok((t, nominal))
        })?;
        Ok((out, outcome.sim_ms))
    }
}

impl StageExec for SimStages {
    fn num_stages(&self) -> usize {
        self.nodes.len()
    }

    fn node_id(&self, stage: usize) -> usize {
        self.nodes[stage].id()
    }

    fn comm_in(&self, stage: usize, bytes: u64) -> f64 {
        let prev = stage.checked_sub(1).map(|p| &*self.nodes[p]);
        node_comm_in(prev, &self.nodes[stage], bytes)
    }

    fn comm_out(&self, bytes: u64) -> f64 {
        node_comm_out(self.nodes.last().map(|n| &**n), bytes)
    }

    fn execute(&self, stage: usize, input: Tensor) -> Result<(Tensor, f64)> {
        self.run_on(&self.nodes[stage], input)
    }

    fn replicas(&self, stage: usize) -> usize {
        1 + self.extra[stage].len()
    }

    fn replica_node_id(&self, stage: usize, replica: usize) -> usize {
        self.node_for(stage, replica).id()
    }

    fn comm_in_on(&self, stage: usize, replica: usize, bytes: u64) -> f64 {
        // Upstream sender modeled as the previous stage's primary (the
        // sim link specs are uniform across replicas anyway).
        let prev = stage.checked_sub(1).map(|p| &*self.nodes[p]);
        node_comm_in(prev, self.node_for(stage, replica), bytes)
    }

    fn execute_on(
        &self,
        stage: usize,
        replica: usize,
        input: Tensor,
    ) -> Result<(Tensor, f64)> {
        self.run_on(self.node_for(stage, replica), input)
    }
}

/// Split a `[rows, ...]` tensor into row-contiguous chunks of up to
/// `chunk_rows` rows (the last chunk may be short). Every chunk is a
/// zero-copy view sharing the batch's backing buffer — carving
/// micro-batches out of an admitted batch moves no activation bytes.
pub fn split_rows(t: &Tensor, chunk_rows: usize) -> Result<Vec<Tensor>> {
    anyhow::ensure!(!t.shape.is_empty(), "cannot split a scalar tensor");
    anyhow::ensure!(chunk_rows > 0, "chunk_rows must be > 0");
    let rows = t.shape[0];
    anyhow::ensure!(rows > 0, "empty batch");
    let mut out = Vec::with_capacity(rows.div_ceil(chunk_rows));
    let mut r = 0;
    while r < rows {
        let take = chunk_rows.min(rows - r);
        out.push(t.view_rows(r..r + take)?);
        r += take;
    }
    Ok(out)
}

/// Reassemble chunks produced by [`split_rows`] (in order).
///
/// Zero-copy fast paths: a single chunk is returned as a shared view,
/// and chunks that are still *adjacent views of one backing buffer* (a
/// split that was never scattered) re-merge as a view over their span.
/// Disjoint buffers — the common case for stage outputs arriving at the
/// collector — copy once into a pooled buffer (counted), because the
/// next consumer (an executor upload, a cache insert) needs the rows
/// contiguous.
pub fn concat_rows(chunks: &[Tensor]) -> Result<Tensor> {
    anyhow::ensure!(!chunks.is_empty(), "no chunks to concatenate");
    let tail: &[usize] = &chunks[0].shape[1..];
    let mut rows = 0;
    for c in chunks {
        anyhow::ensure!(
            !c.shape.is_empty() && &c.shape[1..] == tail,
            "mismatched chunk shapes"
        );
        rows += c.shape[0];
    }
    let mut shape = chunks[0].shape.clone();
    shape[0] = rows;
    if chunks.len() == 1 {
        crate::metrics::data_plane::count_view(chunks[0].byte_len());
        return Ok(chunks[0].clone());
    }
    if chunks.windows(2).all(|p| p[0].abuts(&p[1])) {
        crate::metrics::data_plane::count_view(
            chunks.iter().map(Tensor::byte_len).sum(),
        );
        return Tensor::from_buf(
            shape,
            Arc::clone(chunks[0].buf()),
            chunks[0].offset(),
        );
    }
    let row_len: usize = tail.iter().product();
    let mut data =
        crate::util::pool::BufferPool::global().take(rows * row_len);
    for c in chunks {
        data.extend_from_slice(c.data());
    }
    crate::metrics::data_plane::count_copy((data.len() * 4) as u64);
    Tensor::new(shape, data)
}

/// [`concat_rows`] over owned chunks: identical result, but chunks that
/// had to be copied are recycled into the buffer pool afterwards (stage
/// outputs reassembled at the collector are the pool's main supply).
fn concat_rows_owned(chunks: Vec<Tensor>) -> Result<Tensor> {
    let out = concat_rows(&chunks)?;
    // When the fast path produced a view, the chunks share the output's
    // buffer and recycle() is a cheap no-op (refcount > 1).
    for c in chunks {
        c.recycle();
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Shared streaming core: one driver/feeder/collector implementation used by
// both the one-shot `run_streamed` (scoped threads, single batch) and the
// `PersistentEngine` (long-lived threads, batches tagged and interleaved).
// ---------------------------------------------------------------------------

/// One micro-batch moving through the stage queues. `batch` tags which
/// admitted *transport* the rows belong to (always 0 for one-shot
/// runs); `ready_ms` is the simulated time it left the previous stage.
/// `deadline` is the transport's most lenient member deadline (None
/// when any member has none): a failed execution is only worth
/// replaying on a surviving replica while some member can still use
/// the output.
struct PMsg {
    batch: u64,
    idx: usize,
    ready_ms: f64,
    tensor: Tensor,
    deadline: Option<std::time::Instant>,
}

/// Per-stage credit windows (the tentpole of ISSUE 3). Window `k`
/// bounds the number of micro-batches *admitted but not yet past stage
/// `k`* — returned by stage `k`'s driver at completion for `k <
/// S-1`, and by the collector at delivery for the last window. The
/// feeder spends one credit from **every** window per admission, and
/// the admitted micro-batch's simulated clock starts at the max of the
/// credit values, so each window throttles admission in both wall
/// clock and sim time.
///
/// With all budgets equal to `W` the last window's constraint
/// (admitted-but-undelivered <= W) subsumes the earlier ones and its
/// credit value (delivery time of micro `i-W`) dominates the max — the
/// schedule degenerates *bit-exactly* to the PR-2 single global window
/// of `W` (pinned by equivalence tests). Unequal budgets let a
/// heterogeneous chain keep a large in-flight window through the
/// bottleneck while early fast stages run on small ones.
///
/// ## Replicated stages
///
/// A replicated stage gets one credit **slot per replica** (slots are
/// laid out stage-major): micro-batch `idx` of stage `k` always
/// accounts against slot `offsets[k] + idx % reps[k]`, so each
/// congruence class of micro-batches has its own per-replica window.
/// The slot mapping is *static* — decoupled from which replica actually
/// executes the chunk (the alive-set router may steer around a dead
/// replica) — so credit accounting never races replica liveness. With
/// every stage at one replica, slots == stages and the behaviour is
/// bit-exactly the pre-replication windows.
struct CreditWindows {
    txs: Vec<Sender<f64>>,
    /// Pending narrowings per slot: the next returned credit is
    /// absorbed instead of re-issued.
    swallow: Vec<AtomicUsize>,
    /// Live budget per slot (target size, narrowings already
    /// subtracted). Stage-level resizes move all of a stage's slots
    /// together, so replicas of a stage keep equal budgets.
    budgets: Vec<AtomicUsize>,
    /// First slot of each stage.
    offsets: Vec<usize>,
    /// Replica count per stage.
    reps: Vec<usize>,
}

impl CreditWindows {
    /// Build unreplicated windows seeded with `budgets[k]` zero-valued
    /// credits each; returns the feeder-side receivers (index = stage).
    fn new(budgets: &[usize]) -> (CreditWindows, Vec<Receiver<f64>>) {
        CreditWindows::new_replicated(budgets, &vec![1; budgets.len()])
    }

    /// Build windows with `reps[k]` slots for stage `k`, each seeded
    /// with `budgets[k]` zero-valued credits. Receivers are indexed by
    /// *slot* (use [`CreditWindows::slot_of`]).
    fn new_replicated(
        budgets: &[usize],
        reps: &[usize],
    ) -> (CreditWindows, Vec<Receiver<f64>>) {
        assert_eq!(budgets.len(), reps.len(), "one budget per stage");
        let mut txs = Vec::new();
        let mut rxs = Vec::new();
        let mut slot_budgets = Vec::new();
        let mut offsets = Vec::with_capacity(reps.len());
        for (k, &r) in reps.iter().enumerate() {
            assert!(r >= 1, "stage {k} needs >= 1 replica");
            offsets.push(txs.len());
            for _ in 0..r {
                let (tx, rx) = channel::<f64>();
                for _ in 0..budgets[k] {
                    let _ = tx.send(0.0);
                }
                txs.push(tx);
                rxs.push(rx);
                slot_budgets.push(AtomicUsize::new(budgets[k]));
            }
        }
        let n_slots = txs.len();
        let windows = CreditWindows {
            txs,
            swallow: (0..n_slots).map(|_| AtomicUsize::new(0)).collect(),
            budgets: slot_budgets,
            offsets,
            reps: reps.to_vec(),
        };
        (windows, rxs)
    }

    /// Number of stages (not slots).
    fn n(&self) -> usize {
        self.offsets.len()
    }

    /// Credit slot of micro-batch `idx` at stage `k`.
    fn slot_of(&self, k: usize, idx: usize) -> usize {
        self.offsets[k] + idx % self.reps[k]
    }

    /// Return micro-batch `idx`'s credit to stage `k`'s window (value =
    /// the simulated time the slot freed), unless a pending narrowing
    /// absorbs it.
    fn give(&self, k: usize, idx: usize, value: f64) {
        let slot = self.slot_of(k, idx);
        let absorbed = self.swallow[slot]
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |s| {
                s.checked_sub(1)
            })
            .is_ok();
        if !absorbed {
            let _ = self.txs[slot].send(value);
        }
    }

    /// Grow window `k` by one credit per replica slot, valued `now`
    /// (cancels pending narrowings first, so widen/narrow pairs are net
    /// zero). Replica budgets of a stage stay equal.
    fn widen(&self, k: usize, now: f64) {
        for slot in self.offsets[k]..self.offsets[k] + self.reps[k] {
            self.budgets[slot].fetch_add(1, Ordering::SeqCst);
            let cancelled = self.swallow[slot]
                .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |s| {
                    s.checked_sub(1)
                })
                .is_ok();
            if !cancelled {
                let _ = self.txs[slot].send(now);
            }
        }
    }

    /// Shrink window `k` by one per replica slot: the next returned
    /// credit of each slot is swallowed.
    fn narrow(&self, k: usize) {
        for slot in self.offsets[k]..self.offsets[k] + self.reps[k] {
            self.budgets[slot].fetch_sub(1, Ordering::SeqCst);
            self.swallow[slot].fetch_add(1, Ordering::SeqCst);
        }
    }

    /// Per-replica budget of stage `k` (slots of a stage stay equal).
    fn stage_budget(&self, k: usize) -> usize {
        self.budgets[self.offsets[k]].load(Ordering::SeqCst)
    }

    fn budgets_snapshot(&self) -> Vec<usize> {
        (0..self.n()).map(|k| self.stage_budget(k)).collect()
    }

    /// The delivery window (last stage's per-replica budget) — what
    /// `current_depth`/`DepthReport` track, identical to the PR-2
    /// global depth when budgets are uniform.
    fn delivery_budget(&self) -> usize {
        if self.offsets.is_empty() {
            0
        } else {
            self.stage_budget(self.n() - 1)
        }
    }
}

/// What flows through a stage queue: a live micro-batch or a failure
/// being forwarded to the collector so its batch can complete (and its
/// window credits return) without dropping messages. `at_ms` is the
/// simulated makespan when the failure occurred, stamped once at the
/// failing stage — downstream drivers and the collector use it as the
/// returned credit value without touching the shared state lock. `idx`
/// carries the dead micro-batch's sequence number so its credits return
/// to the *same replica slot* they were drawn from.
enum PFlow {
    Item(PMsg),
    Failed { batch: u64, idx: usize, error: anyhow::Error, at_ms: f64 },
}

/// One submitted batch riding inside a transport: where its rows live
/// in the transport's row space, and who is waiting for them. A
/// transport formed without coalescing has exactly one member covering
/// every row.
struct Member {
    rows: std::ops::Range<usize>,
    reply: Sender<Result<EngineRun>>,
}

/// Per-*transport* completion tracking: outputs keyed by micro-batch
/// sequence number plus transport-local timing/counter aggregation. A
/// transport is the unit that flows through the pipeline — one
/// submitted batch, or several adjacent small submissions the feeder
/// coalesced into shared micro-batches (members are re-split by row
/// range at finalization, so results stay batch-addressable). The
/// critical-path lanes accumulate across transports; these aggregates
/// carry the per-transport attribution (step deltas) so each batch
/// reports its own timing.
struct BatchAgg {
    outs: Vec<Option<Tensor>>,
    remaining: usize,
    /// Simulated time the batch began *service*: its first micro-batch's
    /// stage-0 compute start minus that step's ingress comm, set by the
    /// stage-0 driver. Batch `total_ms` is measured from here, so a
    /// batch queued behind earlier batches (e.g. admitted on a stale
    /// leftover credit) reports its own pipeline time, not the queueing
    /// time in front of it. For the first batch this is exactly 0.
    t0_ms: f64,
    last_deliver_ms: f64,
    bytes: u64,
    final_comm_ms: f64,
    counters: Vec<StageCounter>,
    /// Per-stage bubble booked by the batch's *first* micro-batch — the
    /// entry gap since the previous batch left that stage. When the
    /// batch's admission was *not* credit-starved the adaptive
    /// controller subtracts it before observing: an arrival gap is not
    /// credit starvation, and no window width can remove it. Reported
    /// counters keep the full bubble (the stage really was idle).
    lead_bubble_ms: Vec<f64>,
    /// Per-window starvation mask: `starved[k]` is set when the feeder
    /// had one of this transport's micro-batches in hand but found
    /// window `k` empty — that window itself delayed admission. For
    /// such batches entry gaps *are* starvation (the only widening
    /// signal a single-chunk batch can produce), and the mask tells the
    /// per-stage controller *which* budget to grow.
    starved: Vec<bool>,
    error: Option<anyhow::Error>,
    members: Vec<Member>,
    /// Rows fed into stage 0 (member rows plus any feeder padding). A
    /// row-wise stage chain delivers exactly this many rows back; when
    /// the output disagrees (a row-count-changing `StageExec`), member
    /// re-splitting is meaningless and finalization falls back to
    /// whole-output delivery (single member) or an explicit error
    /// (coalesced members).
    expected_rows: usize,
    /// Wall-clock instant the transport was registered (feeder handoff).
    /// The collector folds registration-to-last-delivery into the
    /// engine's service-time EWMA, which the feeder's deadline-aware
    /// coalescing guard consults.
    fed_at: std::time::Instant,
}

impl BatchAgg {
    fn credit_starved(&self) -> bool {
        self.starved.iter().any(|s| *s)
    }
}

/// State shared by drivers, feeder, and collector: the persistent
/// critical-path clock plus the in-flight batch table. The stage→node
/// map is an `Arc<[usize]>` shared with the engine handle and every
/// scheduler-charging call site — one allocation for the engine's
/// lifetime instead of one `to_vec` per batch.
struct EngineState {
    cp: CriticalPath,
    node_ids: Arc<[usize]>,
    batches: HashMap<u64, BatchAgg>,
    /// EWMA of wall-clock transport service time (registration to last
    /// delivery), ms. `None` until the first transport completes.
    service_ewma_ms: Option<f64>,
}

impl EngineState {
    fn new(node_ids: Arc<[usize]>) -> EngineState {
        EngineState {
            cp: CriticalPath::new(&node_ids),
            node_ids,
            batches: HashMap::new(),
            service_ewma_ms: None,
        }
    }

    /// State for a replicated chain: one critical-path lane per replica
    /// (`replica_nodes[k][r]` hosts replica `r` of stage `k`), while
    /// `node_ids` stays the primary map used for scheduler charging and
    /// per-stage counter registration.
    fn new_replicated(
        node_ids: Arc<[usize]>,
        replica_nodes: &[Vec<usize>],
    ) -> EngineState {
        EngineState {
            cp: CriticalPath::new_replicated(replica_nodes),
            node_ids,
            batches: HashMap::new(),
            service_ewma_ms: None,
        }
    }

    /// Register a transport before any of its micro-batches are fed, so
    /// drivers can attribute steps from the first one onward.
    fn register(
        &mut self,
        id: u64,
        n_chunks: usize,
        members: Vec<Member>,
        expected_rows: usize,
    ) {
        let counters = self
            .node_ids
            .iter()
            .enumerate()
            .map(|(k, &node)| StageCounter { stage: k, node, ..StageCounter::default() })
            .collect();
        self.batches.insert(
            id,
            BatchAgg {
                outs: (0..n_chunks).map(|_| None).collect(),
                remaining: n_chunks,
                t0_ms: 0.0,
                last_deliver_ms: 0.0,
                bytes: 0,
                final_comm_ms: 0.0,
                counters,
                lead_bubble_ms: vec![0.0; self.node_ids.len()],
                starved: vec![false; self.node_ids.len()],
                error: None,
                members,
                expected_rows,
                fed_at: std::time::Instant::now(),
            },
        );
    }
}

/// Poison-tolerant state lock: a panicking stage (a bug in a `StageExec`
/// implementation) must degrade to failed batches, not wedge every other
/// driver — and ultimately every `BatchHandle::wait` — behind a poisoned
/// mutex. Sim accounting after a panic is best-effort by design.
fn lock_state(state: &Mutex<EngineState>) -> std::sync::MutexGuard<'_, EngineState> {
    state.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Human-readable payload of a caught stage panic.
fn panic_msg(p: Box<dyn std::any::Any + Send>) -> String {
    p.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "opaque panic payload".into())
}

/// In-flight replay counters (ISSUE 8): micro-batches re-run on a
/// surviving replica after a stage execution failed mid-stream.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReplayStats {
    /// Replay executions attempted (one per surviving replica tried).
    pub attempted: u64,
    /// Replays that produced the micro-batch's output — the batch kept
    /// streaming instead of failing.
    pub succeeded: u64,
}

/// Per-engine healing context shared by every stage driver: whether
/// micro-batch replay is on, plus the counters the serving report
/// surfaces. Replay off (the default) preserves the pre-ISSUE-8
/// fail-fast behaviour bit for bit.
#[derive(Default)]
struct HealCtx {
    replay: bool,
    attempted: AtomicU64,
    succeeded: AtomicU64,
}

impl HealCtx {
    fn new(replay: bool) -> HealCtx {
        HealCtx { replay, ..HealCtx::default() }
    }

    fn stats(&self) -> ReplayStats {
        ReplayStats {
            attempted: self.attempted.load(Ordering::Relaxed),
            succeeded: self.succeeded.load(Ordering::Relaxed),
        }
    }
}

/// Cloneable view onto one engine's replay counters (see
/// [`PersistentEngine::replay_probe`]): outlives the engine, so a
/// deployment swap can read the final drained counts after teardown.
#[derive(Clone)]
pub struct ReplayProbe(Arc<HealCtx>);

impl ReplayProbe {
    pub fn stats(&self) -> ReplayStats {
        self.0.stats()
    }
}

/// Straggler-hedging policy (ISSUE 10): when an armed stage's
/// micro-batch runs past `max(factor * EWMA_k, min_ms)` wall
/// milliseconds, the driver re-issues it on a surviving sibling replica
/// and takes whichever execution finishes first. Off by default —
/// [`PersistentEngineConfig::hedge`] is `None` — which keeps the
/// execute path bit-identical to the unhedged engine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HedgeConfig {
    /// Hedge threshold as a multiple of the stage's execute-latency
    /// EWMA: a micro-batch is a straggler once it runs `factor` times
    /// longer than the stage's typical execution.
    pub factor: f64,
    /// Floor on the threshold, ms — keeps sub-millisecond stages from
    /// hedging on scheduler noise.
    pub min_ms: f64,
    /// Successful executions a stage must complete before its EWMA is
    /// trusted enough to arm hedging (cold stages never hedge).
    pub min_samples: u64,
}

impl Default for HedgeConfig {
    fn default() -> Self {
        HedgeConfig { factor: 4.0, min_ms: 2.0, min_samples: 8 }
    }
}

/// Hedging counters surfaced by [`PersistentEngine::hedge_stats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HedgeStats {
    /// Hedge executions issued (primary ran past its threshold).
    pub issued: u64,
    /// Hedges whose result was used (the primary was still pending or
    /// had failed when the hedge completed).
    pub wins: u64,
    /// Hedges whose result was discarded (the primary delivered first
    /// or the hedge itself failed) — pure duplicated work.
    pub wasted: u64,
}

/// Per-engine hedging state shared by every stage driver: the policy,
/// a per-stage execute-latency EWMA (f64 bits in an `AtomicU64`; the
/// read-modify-write race between sibling drivers only blurs a
/// statistic), and the counters. Mirrored into [`crate::metrics::wire`]
/// so serving reports surface hedging without new plumbing.
struct HedgeCtx {
    cfg: HedgeConfig,
    ewma_bits: Vec<AtomicU64>,
    samples: Vec<AtomicU64>,
    issued: AtomicU64,
    wins: AtomicU64,
    wasted: AtomicU64,
}

impl HedgeCtx {
    fn new(cfg: HedgeConfig, n_stages: usize) -> HedgeCtx {
        HedgeCtx {
            cfg,
            ewma_bits: (0..n_stages).map(|_| AtomicU64::new(0)).collect(),
            samples: (0..n_stages).map(|_| AtomicU64::new(0)).collect(),
            issued: AtomicU64::new(0),
            wins: AtomicU64::new(0),
            wasted: AtomicU64::new(0),
        }
    }

    /// Fold one successful execute's wall time into the stage EWMA.
    fn observe(&self, k: usize, ms: f64) {
        let n = self.samples[k].fetch_add(1, Ordering::Relaxed);
        let next = if n == 0 {
            ms
        } else {
            let prev = f64::from_bits(self.ewma_bits[k].load(Ordering::Relaxed));
            0.8 * prev + 0.2 * ms
        };
        self.ewma_bits[k].store(next.to_bits(), Ordering::Relaxed);
    }

    /// Hedge threshold for stage `k`, or `None` while the stage is
    /// still warming up (fewer than `min_samples` completions).
    fn threshold_ms(&self, k: usize) -> Option<f64> {
        if self.samples[k].load(Ordering::Relaxed) < self.cfg.min_samples {
            return None;
        }
        let ewma = f64::from_bits(self.ewma_bits[k].load(Ordering::Relaxed));
        Some((self.cfg.factor * ewma).max(self.cfg.min_ms))
    }

    fn stats(&self) -> HedgeStats {
        HedgeStats {
            issued: self.issued.load(Ordering::Relaxed),
            wins: self.wins.load(Ordering::Relaxed),
            wasted: self.wasted.load(Ordering::Relaxed),
        }
    }
}

/// What a hedging driver thread carries: the shared policy state plus
/// an owned handle on the stage chain, because a hedged primary runs on
/// a *spawned* (non-scoped) thread — a primary hung inside a broken
/// transport must be abandonable, and a scoped thread would block scope
/// exit for exactly as long as the hang we are hedging against.
struct HedgeRt {
    stages: Arc<dyn StageExec + Send + Sync>,
    ctx: Arc<HedgeCtx>,
}

/// One stage execution with the driver's panic guard: a panic inside a
/// `StageExec` implementation degrades to a failed micro-batch, never a
/// dead driver thread.
fn exec_guarded<S: StageExec + ?Sized>(
    stages: &S,
    k: usize,
    replica: usize,
    input: Tensor,
) -> Result<(Tensor, f64)> {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        stages.execute_on(k, replica, input)
    }))
    .unwrap_or_else(|p| {
        Err(anyhow::anyhow!("stage implementation panicked: {}", panic_msg(p)))
    })
}

/// Execute micro-batch input on `(k, replica)`, hedging onto a sibling
/// replica if the primary runs past the armed threshold. Returns the
/// replica whose output was used and the result. `hedge: None` (or a
/// cold/unreplicated stage) is the plain guarded execute — bit-identical
/// to the unhedged engine. On a hedge the sibling's ingress transfer is
/// real duplicated work, charged into `comm_ms`.
fn execute_hedged<S: StageExec + ?Sized>(
    stages: &S,
    k: usize,
    replica: usize,
    input: Tensor,
    comm_ms: &mut f64,
    hedge: Option<&HedgeRt>,
) -> (usize, Result<(Tensor, f64)>) {
    let Some(rt) = hedge else {
        return (replica, exec_guarded(stages, k, replica, input));
    };
    let spare = (0..stages.replicas(k))
        .find(|&r2| r2 != replica && stages.replica_alive(k, r2));
    let (Some(threshold_ms), Some(r2)) = (rt.ctx.threshold_ms(k), spare) else {
        // Warming up, or no surviving sibling to hedge onto: run
        // directly, feeding the EWMA so the stage can arm.
        let t0 = std::time::Instant::now();
        let res = exec_guarded(stages, k, replica, input);
        if res.is_ok() {
            rt.ctx.observe(k, t0.elapsed().as_secs_f64() * 1e3);
        }
        return (replica, res);
    };

    let bytes = input.byte_len();
    let backup = input.clone(); // Arc view: refcount bump, not a row copy
    let (tx, rx) = channel();
    let primary_stages = Arc::clone(&rt.stages);
    let t0 = std::time::Instant::now();
    let spawned = std::thread::Builder::new()
        .name(format!("pipe-hedge-{k}.{replica}"))
        .spawn(move || {
            // The orphaned case: if the driver already took the hedge's
            // result and dropped `rx`, this send fails and the output is
            // simply dropped here.
            let _ = tx.send(exec_guarded(&*primary_stages, k, replica, input));
        });
    if spawned.is_err() {
        // Could not get a thread — degrade to the unhedged execute.
        let res = exec_guarded(stages, k, replica, backup);
        if res.is_ok() {
            rt.ctx.observe(k, t0.elapsed().as_secs_f64() * 1e3);
        }
        return (replica, res);
    }

    match rx.recv_timeout(std::time::Duration::from_secs_f64(threshold_ms / 1e3)) {
        Ok(res) => {
            if res.is_ok() {
                rt.ctx.observe(k, t0.elapsed().as_secs_f64() * 1e3);
            }
            (replica, res)
        }
        Err(_) => {
            // Primary is a straggler (or its thread died): re-issue on
            // the sibling, first completion wins.
            rt.ctx.issued.fetch_add(1, Ordering::Relaxed);
            crate::metrics::wire::count_hedge_issued();
            *comm_ms += stages.comm_in_on(k, r2, bytes);
            let hedged = exec_guarded(stages, k, r2, backup);
            match rx.try_recv() {
                Ok(primary) if primary.is_ok() => {
                    // Primary landed while the hedge ran: keep it (its
                    // accounting lane is already the routed one) and
                    // write the duplicate off as waste.
                    rt.ctx.wasted.fetch_add(1, Ordering::Relaxed);
                    crate::metrics::wire::count_hedge_wasted();
                    rt.ctx.observe(k, t0.elapsed().as_secs_f64() * 1e3);
                    (replica, primary)
                }
                Ok(_primary_err) => {
                    // Primary failed outright; the hedge is all we have.
                    if hedged.is_ok() {
                        rt.ctx.wins.fetch_add(1, Ordering::Relaxed);
                        crate::metrics::wire::count_hedge_win();
                    } else {
                        rt.ctx.wasted.fetch_add(1, Ordering::Relaxed);
                        crate::metrics::wire::count_hedge_wasted();
                    }
                    (r2, hedged)
                }
                Err(_) if hedged.is_ok() => {
                    // Primary still pending: the hedge wins and the
                    // orphaned primary thread discards its late result.
                    rt.ctx.wins.fetch_add(1, Ordering::Relaxed);
                    crate::metrics::wire::count_hedge_win();
                    (r2, hedged)
                }
                Err(std::sync::mpsc::TryRecvError::Empty) => {
                    // Hedge failed with the primary still in flight:
                    // wait the primary out (a wire-transport primary is
                    // bounded by its execute deadline).
                    rt.ctx.wasted.fetch_add(1, Ordering::Relaxed);
                    crate::metrics::wire::count_hedge_wasted();
                    match rx.recv() {
                        Ok(primary) => {
                            if primary.is_ok() {
                                rt.ctx.observe(
                                    k,
                                    t0.elapsed().as_secs_f64() * 1e3,
                                );
                            }
                            (replica, primary)
                        }
                        Err(_) => (r2, hedged),
                    }
                }
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    // Primary thread gone without a result; report the
                    // hedge's failure.
                    rt.ctx.wasted.fetch_add(1, Ordering::Relaxed);
                    crate::metrics::wire::count_hedge_wasted();
                    (r2, hedged)
                }
            }
        }
    }
}

/// Pick which replica of `stage` should execute micro-batch `idx`.
/// Round-robin by sequence number over the *alive* set: with every
/// replica alive this is plain `idx % n` (matching the static credit
/// slot), and a dead replica is steered around so only its already
/// in-flight work fails. With one replica this is always 0.
fn route_replica<S: StageExec + ?Sized>(
    stages: &S,
    stage: usize,
    idx: usize,
) -> usize {
    let n = stages.replicas(stage);
    if n == 1 {
        return 0;
    }
    let alive = (0..n).filter(|&r| stages.replica_alive(stage, r)).count();
    if alive == 0 || alive == n {
        return idx % n;
    }
    let pick = idx % alive;
    (0..n)
        .filter(|&r| stages.replica_alive(stage, r))
        .nth(pick)
        .unwrap_or(idx % n)
}

/// Stage driver loop for one `(stage, replica)` pair: receive, transfer
/// in, execute on this replica's node, account one step on the shared
/// clock (this replica's lane), return the micro-batch's window credit,
/// forward — routing the output to a replica of stage `k+1` (or the
/// collector). Failures are forwarded (never dropped) so the
/// collector's per-transport completion count stays exact, and a
/// *panicking* stage is caught and converted into a failure of just
/// that transport — the drivers stay alive and unrelated in-flight
/// batches complete.
fn drive_stage<S: StageExec + ?Sized>(
    stages: &S,
    k: usize,
    replica: usize,
    rx: Receiver<PFlow>,
    next: Vec<SyncSender<PFlow>>,
    state: &Mutex<EngineState>,
    windows: &CreditWindows,
    heal: &HealCtx,
    hedge: Option<&HedgeRt>,
) {
    // The last window's credit is returned by the collector at delivery
    // (that is what makes uniform budgets degenerate to the global
    // window); every earlier stage returns its own at completion.
    let returns_credit = k + 1 < windows.n();
    while let Ok(flow) = rx.recv() {
        let (out_idx, msg) = match flow {
            PFlow::Failed { batch, idx, error, at_ms } => {
                if returns_credit {
                    windows.give(k, idx, at_ms);
                }
                (idx, PFlow::Failed { batch, idx, error, at_ms })
            }
            PFlow::Item(m) => {
                let bytes = m.tensor.byte_len();
                let mut comm_ms = stages.comm_in_on(k, replica, bytes);
                // Replay insurance (ISSUE 8): retain a zero-copy clone
                // of the stage input — an Arc view, so this is a
                // refcount bump, not a row copy. The stage-k input *is*
                // the last completed stage boundary, so a surviving
                // replica can recompute this micro-batch from it.
                let retained = (heal.replay && stages.replicas(k) > 1)
                    .then(|| m.tensor.clone());
                // A panic inside a StageExec implementation must degrade
                // to a failed transport, not a dead driver thread (which
                // would tear the whole engine down). Accounting after a
                // panic is best-effort by design (AssertUnwindSafe).
                let (mut exec_replica, mut executed) = execute_hedged(
                    stages, k, replica, m.tensor, &mut comm_ms, hedge,
                );
                if executed.is_err() {
                    if let Some(input) = retained {
                        // Replay is pointless once even the most lenient
                        // member's deadline has passed — shed (fail) as
                        // before instead of burning a surviving replica.
                        let worth_it = m
                            .deadline
                            .is_none_or(|d| std::time::Instant::now() < d);
                        let n = stages.replicas(k);
                        for r2 in (0..n).filter(|&r2| {
                            worth_it
                                && r2 != replica
                                && stages.replica_alive(k, r2)
                        }) {
                            heal.attempted.fetch_add(1, Ordering::Relaxed);
                            // The resend over the surviving replica's
                            // link is real work: charge its ingress on
                            // top of the wasted first hop.
                            comm_ms += stages.comm_in_on(k, r2, bytes);
                            let retry =
                                exec_guarded(stages, k, r2, input.clone());
                            if retry.is_ok() {
                                heal.succeeded
                                    .fetch_add(1, Ordering::Relaxed);
                                exec_replica = r2;
                                executed = retry;
                                break;
                            }
                        }
                    }
                }
                match executed {
                    Ok((out, compute_ms)) => {
                        let mut st = lock_state(state);
                        let d = st.cp.step_detail_on(
                            k, exec_replica, m.ready_ms, comm_ms,
                            compute_ms, bytes,
                        );
                        if let Some(agg) = st.batches.get_mut(&m.batch) {
                            if m.idx == 0 {
                                if k == 0 {
                                    // Service start: when stage 0
                                    // actually began this batch (comm
                                    // backed out so a fresh pipeline
                                    // reports t0 = 0). Always >= the
                                    // admission credit, and > it when the
                                    // batch queued behind earlier work.
                                    agg.t0_ms = d.start_ms - comm_ms;
                                }
                                // Entry gap at this stage (see
                                // BatchAgg::lead_bubble_ms).
                                agg.lead_bubble_ms[k] = d.bubble_ms;
                            }
                            let c = &mut agg.counters[k];
                            c.busy_ms += compute_ms;
                            c.comm_ms += comm_ms;
                            c.bubble_ms += d.bubble_ms;
                            c.micro_batches += 1;
                            agg.bytes += bytes;
                        }
                        drop(st);
                        if returns_credit {
                            windows.give(k, m.idx, d.done_ms);
                        }
                        (
                            m.idx,
                            PFlow::Item(PMsg {
                                batch: m.batch,
                                idx: m.idx,
                                ready_ms: d.done_ms,
                                tensor: out,
                                deadline: m.deadline,
                            }),
                        )
                    }
                    Err(e) => {
                        let now = lock_state(state).cp.makespan_ms();
                        if returns_credit {
                            windows.give(k, m.idx, now);
                        }
                        (
                            m.idx,
                            PFlow::Failed {
                                batch: m.batch,
                                idx: m.idx,
                                error: e.context(format!(
                                    "pipeline stage {k}, micro-batch {}",
                                    m.idx
                                )),
                                at_ms: now,
                            },
                        )
                    }
                }
            }
        };
        // Route to the downstream replica (failures take channel 0 —
        // they carry no tensor, so any live downstream driver works).
        let to = if next.len() <= 1 {
            0
        } else {
            match &msg {
                PFlow::Item(_) => route_replica(stages, k + 1, out_idx),
                PFlow::Failed { .. } => 0,
            }
        };
        if next[to].send(msg).is_err() {
            break; // downstream gone
        }
    }
    // rx disconnected: upstream finished; dropping the senders cascades
    // shutdown to the next stage.
}

/// Feed one transport's micro-batches into stage 0, spending one credit
/// from **every** stage window per admission; the admitted micro-batch's
/// simulated clock starts at the max of the credit values (each value is
/// the simulated time that window's slot freed). An admission that finds
/// window `k` empty marks the transport starved on `k` (work was ready;
/// that window held it back) — the signal that lets the window
/// controller tell credit pressure from mere arrival spacing, and pick
/// *which* budget to grow. Returns false when the engine is tearing
/// down.
fn feed_batch<S: StageExec + ?Sized>(
    stages: &S,
    id: u64,
    chunks: Vec<Tensor>,
    deadline: Option<std::time::Instant>,
    credit_rxs: &[Receiver<f64>],
    feed_txs: &[SyncSender<PFlow>],
    windows: &CreditWindows,
    state: &Mutex<EngineState>,
) -> bool {
    for (idx, tensor) in chunks.into_iter().enumerate() {
        let mut ready_ms = 0.0f64;
        // Micro-batch `idx` spends one credit per stage, each from its
        // static replica slot (`slot_of`), so a replicated stage admits
        // up to `reps[k] * budget` micro-batches at once.
        for k in 0..windows.n() {
            let credit_rx = &credit_rxs[windows.slot_of(k, idx)];
            let v = match credit_rx.try_recv() {
                Ok(t) => t,
                Err(std::sync::mpsc::TryRecvError::Empty) => {
                    if let Some(agg) = lock_state(state).batches.get_mut(&id)
                    {
                        agg.starved[k] = true;
                    }
                    match credit_rx.recv() {
                        Ok(t) => t,
                        Err(_) => return false, // collector gone
                    }
                }
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    return false
                }
            };
            ready_ms = ready_ms.max(v);
        }
        let to =
            if feed_txs.len() <= 1 { 0 } else { route_replica(stages, 0, idx) };
        if feed_txs[to]
            .send(PFlow::Item(PMsg {
                batch: id,
                idx,
                ready_ms,
                tensor,
                deadline,
            }))
            .is_err()
        {
            return false;
        }
    }
    true
}

/// Collector loop: every admitted micro-batch yields exactly one
/// terminal message (delivered output or forwarded failure); each
/// terminal returns the *last* window's credit (unless the window
/// controller is narrowing) and decrements its transport's completion
/// count. A transport whose count reaches zero is finalized and each
/// member's result sent to its waiter.
fn collect_loop<S: StageExec + ?Sized>(
    stages: &S,
    rx: Receiver<PFlow>,
    state: &Mutex<EngineState>,
    ctrl: &mut WindowCtrl,
) {
    // Armed for the whole loop: when the collector exits — orderly
    // shutdown, a driver panic's channel cascade, or a panic on this
    // very thread (e.g. a buggy `comm_out`) — any batch stranded
    // mid-flight is dropped so its reply sender closes and
    // `BatchHandle::wait` reports shutdown instead of hanging forever.
    // On an orderly shutdown every accepted batch has already
    // finalized, so this is a no-op.
    struct StrandedBatchGuard<'a>(&'a Mutex<EngineState>);
    impl Drop for StrandedBatchGuard<'_> {
        fn drop(&mut self) {
            lock_state(self.0).batches.clear();
        }
    }
    let _stranded = StrandedBatchGuard(state);

    while let Ok(flow) = rx.recv() {
        match flow {
            PFlow::Item(m) => {
                let bytes = m.tensor.byte_len();
                let hop = stages.comm_out(bytes);
                let mut st = lock_state(state);
                let done = st.cp.deliver(hop, bytes, m.ready_ms);
                let mut finished = None;
                if let Some(agg) = st.batches.get_mut(&m.batch) {
                    agg.bytes += bytes;
                    agg.final_comm_ms += hop;
                    agg.last_deliver_ms = agg.last_deliver_ms.max(done);
                    agg.outs[m.idx] = Some(m.tensor);
                    agg.remaining -= 1;
                    if agg.remaining == 0 {
                        finished = Some(m.batch);
                    }
                }
                let completed =
                    finished.and_then(|id| st.batches.remove(&id));
                if let Some(agg) = &completed {
                    if agg.error.is_none() {
                        // Fold the transport's wall-clock service time
                        // (registration to last delivery) into the EWMA
                        // the feeder's deadline-aware coalescing guard
                        // reads. Failed transports are noise, not a
                        // service-time signal.
                        let ms = agg.fed_at.elapsed().as_secs_f64() * 1e3;
                        st.service_ewma_ms = Some(match st.service_ewma_ms {
                            Some(e) => 0.7 * e + 0.3 * ms,
                            None => ms,
                        });
                    }
                }
                drop(st);
                ctrl.terminal_credit(m.idx, done);
                if let Some(agg) = completed {
                    // Build the controller's view only when a controller
                    // exists — the fixed-window and one-shot paths skip
                    // the per-batch allocation. Batches that carried a
                    // failure are never observed: their dead micro-batches
                    // open gaps that read as starvation but are failure
                    // noise, not a window signal. For batches whose
                    // admission was never credit-starved, the observed
                    // counters exclude each stage's entry gap (the idle
                    // time before the batch's first micro-batch arrived):
                    // that is request-arrival spacing, which no window
                    // width can remove. A credit-starved batch keeps its
                    // entry gaps — the window itself delayed it, which is
                    // exactly the widening signal (and the only one a
                    // single-chunk batch can produce).
                    let observed = (ctrl.is_adaptive() && agg.error.is_none())
                        .then(|| {
                            let counters = if agg.credit_starved() {
                                agg.counters.clone()
                            } else {
                                agg.counters
                                    .iter()
                                    .zip(&agg.lead_bubble_ms)
                                    .map(|(c, lead)| StageCounter {
                                        bubble_ms: (c.bubble_ms - lead)
                                            .max(0.0),
                                        ..c.clone()
                                    })
                                    .collect::<Vec<_>>()
                            };
                            (counters, agg.starved.clone())
                        });
                    finalize_batch(agg);
                    if let Some((counters, starved)) = observed {
                        ctrl.observe_batch(stages, &counters, &starved, state);
                    }
                }
            }
            PFlow::Failed { batch, idx, error, at_ms } => {
                let mut st = lock_state(state);
                let mut finished = None;
                if let Some(agg) = st.batches.get_mut(&batch) {
                    if agg.error.is_none() {
                        agg.error = Some(error);
                    }
                    agg.remaining -= 1;
                    if agg.remaining == 0 {
                        finished = Some(batch);
                    }
                }
                let completed =
                    finished.and_then(|id| st.batches.remove(&id));
                drop(st);
                ctrl.terminal_credit(idx, at_ms);
                if let Some(agg) = completed {
                    finalize_batch(agg);
                }
            }
        }
    }
    // `_stranded` drops here (and on unwind), failing any unfinalized
    // batches.
}

/// Largest-remainder apportionment of `total` indivisible units across
/// `weights`: shares sum to exactly `total`, proportional to weight.
/// Used to split a coalesced transport's micro-batch counts by member
/// rows, so merging the members' counters reproduces the real count
/// (naive per-member rounding would inflate it by up to the member
/// count).
fn apportion(total: u64, weights: &[usize]) -> Vec<u64> {
    let sum: usize = weights.iter().sum();
    if sum == 0 {
        return vec![0; weights.len()];
    }
    let mut out = Vec::with_capacity(weights.len());
    let mut rems = Vec::with_capacity(weights.len());
    for (i, &w) in weights.iter().enumerate() {
        let exact = total as f64 * w as f64 / sum as f64;
        let base = exact.floor();
        out.push(base as u64);
        rems.push((i, exact - base));
    }
    let assigned: u64 = out.iter().sum();
    let mut left = total.saturating_sub(assigned);
    rems.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    for (i, _) in rems {
        if left == 0 {
            break;
        }
        out[i] += 1;
        left -= 1;
    }
    out
}

/// Fail every member of a transport: the lone member of an uncoalesced
/// transport keeps the original error chain; coalesced members each get
/// the flattened message under `context` (anyhow errors are not Clone).
fn fail_members(mut members: Vec<Member>, error: anyhow::Error, context: &str) {
    if members.len() == 1 {
        let _ = members.pop().expect("one member").reply.send(Err(error));
        return;
    }
    let msg = format!("{error:#}");
    for m in members {
        let _ = m.reply.send(Err(anyhow::anyhow!("{context}: {msg}")));
    }
}

/// Slice a contiguous row range out of a `[rows, ...]` tensor — a
/// zero-copy view (coalesced members share the transport output's
/// backing buffer).
fn slice_rows(t: &Tensor, range: &std::ops::Range<usize>) -> Result<Tensor> {
    anyhow::ensure!(
        !t.shape.is_empty() && range.end <= t.shape[0] && range.start < range.end,
        "member row range {range:?} outside transport output {:?}",
        t.shape
    );
    t.view_rows(range.clone())
}

/// Assemble a completed transport's [`EngineRun`]s from its aggregates
/// and send each member its rows. Timing is transport-local: `total_ms`
/// runs from the transport's first admission to its last delivery,
/// compute/comm are the transport's own sums. Coalesced members share
/// the transport's timing and counters (they shared its micro-batches);
/// their outputs are re-split by row range, so results stay
/// batch-addressable and bit-identical to an uncoalesced run.
fn finalize_batch(agg: BatchAgg) {
    let BatchAgg {
        outs,
        t0_ms,
        last_deliver_ms,
        bytes,
        final_comm_ms,
        counters,
        error,
        mut members,
        expected_rows,
        ..
    } = agg;
    if let Some(e) = error {
        // A failure anywhere in the transport fails every member batch
        // (they shared micro-batches).
        fail_members(members, e, "coalesced transport failed");
        return;
    }
    let assembled = (|| {
        let collected: Vec<Tensor> = outs
            .into_iter()
            .map(|o| {
                o.ok_or_else(|| {
                    anyhow::anyhow!("pipeline dropped a micro-batch")
                })
            })
            .collect::<Result<_>>()?;
        // View concatenation where possible; when the stage outputs live
        // in disjoint buffers this is the data plane's one genuine
        // reassembly copy, and the consumed chunk buffers go back to the
        // pool.
        let output = concat_rows_owned(collected)?;
        let compute_ms: f64 = counters.iter().map(|c| c.busy_ms).sum();
        let stage_comm_ms: f64 = counters.iter().map(|c| c.comm_ms).sum();
        let timing = PipelineTiming {
            total_ms: last_deliver_ms - t0_ms,
            compute_ms,
            comm_ms: stage_comm_ms + final_comm_ms,
            stages: counters
                .iter()
                .map(|c| StageTiming {
                    stage: c.stage,
                    node: c.node,
                    compute_ms: c.busy_ms,
                    comm_ms: c.comm_ms,
                })
                .collect(),
            activation_bytes: bytes,
        };
        Ok::<_, anyhow::Error>((output, timing))
    })();
    match assembled {
        Ok((output, timing)) => {
            let rows_as_fed = output.shape[0] == expected_rows;
            // Whole-output delivery: a padding-free single member, or a
            // row-count-changing stage chain (the trait never promised
            // row preservation) where slicing would be meaningless — the
            // lone waiter gets everything, as in the pre-coalescing
            // engine.
            if members.len() == 1
                && (!rows_as_fed || members[0].rows.len() == output.shape[0])
            {
                let m = members.pop().expect("one member");
                let _ = m.reply.send(Ok(EngineRun {
                    output,
                    timing,
                    stage_counters: counters,
                }));
                return;
            }
            if !rows_as_fed {
                // Coalesced members cannot be re-split out of an output
                // whose rows no longer line up with what was fed: fail
                // loudly rather than hand someone another batch's rows.
                fail_members(
                    members,
                    anyhow::anyhow!(
                        "stage chain changed the row count ({} fed, {} \
                         delivered)",
                        expected_rows,
                        output.shape[0]
                    ),
                    "coalesced transport cannot be re-split",
                );
                return;
            }
            // Split each stage's micro-batch count across members by
            // largest remainder, so merged member counters sum back to
            // the transport's true counts. Fractions are over the
            // members' real rows (padding overhead is shared
            // proportionally too).
            let weights: Vec<usize> =
                members.iter().map(|m| m.rows.len()).collect();
            let member_rows: usize = weights.iter().sum::<usize>().max(1);
            let stage_shares: Vec<Vec<u64>> = counters
                .iter()
                .map(|c| apportion(c.micro_batches, &weights))
                .collect();
            let byte_shares = apportion(timing.activation_bytes, &weights);
            for (mi, m) in members.into_iter().enumerate() {
                // Members share the transport's latency (total_ms) but
                // split its work proportionally by rows: charging every
                // member the full transport compute/occupancy would
                // multiply the scheduler's per-node execution history
                // and the server's merged StageCounterSet by the member
                // count.
                let frac = m.rows.len() as f64 / member_rows as f64;
                let mut t = timing.clone();
                t.compute_ms *= frac;
                t.comm_ms *= frac;
                t.activation_bytes = byte_shares[mi];
                for st in &mut t.stages {
                    st.compute_ms *= frac;
                    st.comm_ms *= frac;
                }
                let member_counters: Vec<StageCounter> = counters
                    .iter()
                    .enumerate()
                    .map(|(k, c)| StageCounter {
                        busy_ms: c.busy_ms * frac,
                        bubble_ms: c.bubble_ms * frac,
                        comm_ms: c.comm_ms * frac,
                        micro_batches: stage_shares[k][mi],
                        ..c.clone()
                    })
                    .collect();
                let result = slice_rows(&output, &m.rows).map(|rows| EngineRun {
                    output: rows,
                    timing: t,
                    stage_counters: member_counters,
                });
                let _ = m.reply.send(result);
            }
        }
        Err(e) => fail_members(members, e, "transport assembly failed"),
    }
}

/// Live depth bookkeeping shared between the controller (collector
/// thread) and [`PersistentEngine`] accessors.
#[derive(Debug)]
struct DepthStats {
    initial: usize,
    current: AtomicUsize,
    min_seen: AtomicUsize,
    max_seen: AtomicUsize,
    widenings: AtomicU64,
    narrowings: AtomicU64,
}

impl DepthStats {
    fn new(initial: usize) -> DepthStats {
        DepthStats {
            initial,
            current: AtomicUsize::new(initial),
            min_seen: AtomicUsize::new(initial),
            max_seen: AtomicUsize::new(initial),
            widenings: AtomicU64::new(0),
            narrowings: AtomicU64::new(0),
        }
    }

    fn set_depth(&self, d: usize) {
        self.current.store(d, Ordering::SeqCst);
        self.min_seen.fetch_min(d, Ordering::SeqCst);
        self.max_seen.fetch_max(d, Ordering::SeqCst);
    }

    fn report(&self) -> DepthReport {
        DepthReport {
            initial_depth: self.initial,
            final_depth: self.current.load(Ordering::SeqCst),
            min_depth: self.min_seen.load(Ordering::SeqCst),
            max_depth: self.max_seen.load(Ordering::SeqCst),
            widenings: self.widenings.load(Ordering::SeqCst),
            narrowings: self.narrowings.load(Ordering::SeqCst),
        }
    }
}

/// The adaptive window controller, run inline on the collector thread.
/// Widening injects an extra credit (valued at the current makespan so
/// the new slot's clock starts "now"); narrowing swallows the next
/// returned credit of the shrunk window. Without an
/// [`AdaptiveDepthConfig`] it only relays the last window's terminal
/// credits — the fixed-window behaviour.
///
/// In **uniform** mode (`per_stage == false`) every stage budget moves
/// together by one, reproducing the PR-2 global depth controller —
/// except for the backlog veto below, which applies in both modes (the
/// `Executor::queue_depth` second signal is new in this engine and
/// intentionally stops a uniform controller from widening into a
/// device-congested bottleneck). In **per-stage** mode each budget
/// resizes independently:
/// widening targets the smallest budget among the windows the feeder
/// reported *starved* (falling back to the global minimum budget), and
/// narrowing shrinks the largest budget — so a slow middle stage grows
/// the windows that actually gate its supply instead of inflating the
/// whole chain.
struct WindowCtrl {
    cfg: Option<AdaptiveDepthConfig>,
    per_stage: bool,
    windows: Arc<CreditWindows>,
    cooldown: u32,
    clean_batches: u32,
    stats: Arc<DepthStats>,
    /// Buffer-pool snapshot at the last memory-pressure check, so each
    /// observation sees only the delta since the previous one.
    last_pool: crate::util::pool::PoolStats,
}

impl WindowCtrl {
    fn new(
        cfg: Option<AdaptiveDepthConfig>,
        per_stage: bool,
        windows: Arc<CreditWindows>,
        stats: Arc<DepthStats>,
    ) -> WindowCtrl {
        WindowCtrl {
            cfg,
            per_stage,
            windows,
            cooldown: 0,
            clean_batches: 0,
            stats,
            last_pool: crate::util::pool::BufferPool::global().stats(),
        }
    }

    /// Whether completed batches are worth observing at all.
    fn is_adaptive(&self) -> bool {
        self.cfg.is_some()
    }

    /// Return micro-batch `idx`'s last-window credit at a terminal
    /// (delivery or drained failure).
    fn terminal_credit(&self, idx: usize, value: f64) {
        let last = self.windows.n() - 1;
        self.windows.give(last, idx, value);
    }

    /// Memory-pressure signal from the shared [`BufferPool`]: true when
    /// the allocation miss rate since the last check exceeds
    /// `pool_miss_budget` (in-flight buffers outrunning the pool's
    /// supply), or the bytes parked in the pool exceed
    /// `pool_bytes_budget`. Either way the window is holding more
    /// activation storage live than the budget allows, and shrinking it
    /// is the lever the controller owns.
    fn memory_pressure(&mut self, cfg: &AdaptiveDepthConfig) -> bool {
        if cfg.pool_miss_budget.is_none() && cfg.pool_bytes_budget.is_none() {
            return false;
        }
        let pool = crate::util::pool::BufferPool::global();
        let now = pool.stats();
        let delta = now.since(&self.last_pool);
        self.last_pool = now;
        let takes = delta.hits + delta.misses;
        let miss_over = cfg.pool_miss_budget.is_some_and(|budget| {
            takes > 0 && delta.misses as f64 / takes as f64 > budget
        });
        let bytes_over = cfg
            .pool_bytes_budget
            .is_some_and(|budget| pool.pooled_bytes() > budget);
        miss_over || bytes_over
    }

    /// One narrowing step (shared by the bubble hysteresis and the
    /// memory-pressure path): per-stage mode shrinks the largest budget
    /// still above the floor (ties toward the latest stage, undoing
    /// widen order); uniform mode shrinks every window above the floor.
    /// Returns false when everything already sits at `min_depth`.
    fn narrow_step(&self, cfg: &AdaptiveDepthConfig) -> bool {
        let budgets = self.windows.budgets_snapshot();
        if self.per_stage {
            match (0..budgets.len())
                .filter(|&k| budgets[k] > cfg.min_depth)
                .max_by_key(|&k| (budgets[k], k))
            {
                Some(k) => {
                    self.windows.narrow(k);
                    true
                }
                None => false,
            }
        } else if budgets.iter().any(|&b| b > cfg.min_depth) {
            // Per-window floor: narrowing a window already at min_depth
            // would drive its budget to 0 and starve the feeder forever
            // (a non-uniform seed can sit at the floor while the
            // delivery window is above it).
            for k in 0..self.windows.n() {
                if budgets[k] > cfg.min_depth {
                    self.windows.narrow(k);
                }
            }
            true
        } else {
            false
        }
    }

    /// Record the delivery budget into the depth stats after a resize.
    fn sync_stats(&self) {
        self.stats.set_depth(self.windows.delivery_budget());
    }

    /// Pick the window to widen: among the starved windows (or all, if
    /// the mask is empty) still below `max_depth`, the smallest budget —
    /// ties broken toward the latest stage, whose window dominates the
    /// admission clock.
    fn widen_target(&self, starved: &[bool], max_depth: usize) -> Option<usize> {
        let budgets = self.windows.budgets_snapshot();
        let pick = |mask: bool| {
            (0..budgets.len())
                .filter(|&k| (!mask || starved[k]) && budgets[k] < max_depth)
                .min_by_key(|&k| (budgets[k], std::cmp::Reverse(k)))
        };
        if starved.iter().any(|s| *s) {
            pick(true).or_else(|| pick(false))
        } else {
            pick(false)
        }
    }

    /// Per completed batch: widen while the bottleneck stage shows
    /// bubbles, narrow after consecutive bubble-free batches. Hysteresis
    /// plus a cooldown keeps the window within one step of the smallest
    /// saturating depth. `Executor::queue_depth` backlog is the second
    /// signal: when the bottleneck's node already has more queued work
    /// than its window, its bubbles are device backlog, not credit
    /// starvation, and widening is vetoed.
    fn observe_batch<S: StageExec + ?Sized>(
        &mut self,
        stages: &S,
        counters: &[StageCounter],
        starved: &[bool],
        state: &Mutex<EngineState>,
    ) {
        let Some(cfg) = self.cfg else { return };
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return;
        }
        // Memory pressure dominates: while the buffer pool is missing or
        // holding beyond its budget, shrink the window (fewer in-flight
        // micro-batches = less live activation storage) and veto any
        // widening this round.
        if self.memory_pressure(&cfg) {
            if self.narrow_step(&cfg) {
                self.sync_stats();
                self.stats.narrowings.fetch_add(1, Ordering::SeqCst);
                self.cooldown = cfg.cooldown_batches;
            }
            self.clean_batches = 0;
            return;
        }
        let Some(bottleneck) = counters
            .iter()
            .max_by(|a, b| a.busy_ms.total_cmp(&b.busy_ms))
        else {
            return;
        };
        if bottleneck.busy_ms + bottleneck.bubble_ms <= 0.0 {
            return;
        }
        let frac = bottleneck.bubble_fraction();
        let budgets = self.windows.budgets_snapshot();
        let b = bottleneck.stage;
        if frac > cfg.widen_bubble_frac {
            if stages.backlog(b) > budgets[b] {
                return; // device backlog, not credit starvation
            }
            let widened = if self.per_stage {
                match self.widen_target(starved, cfg.max_depth) {
                    Some(k) => {
                        let now = lock_state(state).cp.makespan_ms();
                        self.windows.widen(k, now);
                        true
                    }
                    None => false,
                }
            } else if budgets.iter().any(|&b| b < cfg.max_depth) {
                // Uniform mode: move the whole chain one step, but never
                // push an individual window past the cap — a non-uniform
                // seed (carried budgets) must stay within [min, max],
                // and a window still below the cap must keep widening
                // even after the widest one saturates.
                let now = lock_state(state).cp.makespan_ms();
                for k in 0..self.windows.n() {
                    if budgets[k] < cfg.max_depth {
                        self.windows.widen(k, now);
                    }
                }
                true
            } else {
                false
            };
            if widened {
                self.sync_stats();
                self.stats.widenings.fetch_add(1, Ordering::SeqCst);
                self.cooldown = cfg.cooldown_batches;
                self.clean_batches = 0;
            }
        } else if frac < cfg.narrow_bubble_frac {
            self.clean_batches += 1;
            if self.clean_batches >= 2 {
                if self.narrow_step(&cfg) {
                    self.sync_stats();
                    self.stats.narrowings.fetch_add(1, Ordering::SeqCst);
                    self.cooldown = cfg.cooldown_batches;
                }
                self.clean_batches = 0;
            }
        } else {
            self.clean_batches = 0;
        }
    }
}

/// Serial comparator with identical accounting: every micro-batch runs
/// through all stages before the next one starts (chunk-major order).
/// With a single chunk this is exactly `pipeline::run`'s schedule —
/// `pipeline::run` delegates here.
pub fn run_serial<S: StageExec + ?Sized>(
    stages: &S,
    input: &Tensor,
    micro_batch_rows: usize,
) -> Result<EngineRun> {
    let n_stages = stages.num_stages();
    anyhow::ensure!(n_stages > 0, "engine needs >= 1 stage");
    let chunks = split_rows(input, micro_batch_rows)?;
    let node_ids: Vec<usize> = (0..n_stages).map(|k| stages.node_id(k)).collect();
    let mut cp = CriticalPath::new(&node_ids);
    let mut outs = Vec::with_capacity(chunks.len());
    // Serial schedule: chunk i may only enter stage 0 after chunk i-1 is
    // delivered, so `ready` carries across chunks.
    let mut prev_done = 0.0;
    for (idx, chunk) in chunks.into_iter().enumerate() {
        let mut act = chunk;
        let mut ready = prev_done;
        for k in 0..n_stages {
            let bytes = act.byte_len();
            let comm_ms = stages.comm_in(k, bytes);
            let (out, compute_ms) = stages
                .execute(k, act)
                .with_context(|| format!("pipeline stage {k}, micro-batch {idx}"))?;
            ready = cp.step(k, ready, comm_ms, compute_ms, bytes);
            act = out;
        }
        let out_bytes = act.byte_len();
        let hop = stages.comm_out(out_bytes);
        prev_done = cp.deliver(hop, out_bytes, ready);
        outs.push(act);
    }
    Ok(EngineRun {
        output: concat_rows(&outs)?,
        timing: cp.timing(),
        stage_counters: cp.counters(),
    })
}

/// Streamed execution: split `input` into micro-batches and drive them
/// through per-stage bounded queues with one driver thread per stage, up
/// to `cfg.max_in_flight` micro-batches in flight. Output rows are
/// reassembled in request order and are bit-identical to [`run_serial`].
///
/// One-shot wrapper over the shared streaming core: scoped driver
/// threads live for exactly one batch. For back-to-back batches use
/// [`PersistentEngine`], which keeps the same drivers (and the
/// critical-path clock) alive across batches.
pub fn run_streamed<S: StageExec + ?Sized>(
    stages: &S,
    input: &Tensor,
    cfg: &EngineConfig,
) -> Result<EngineRun> {
    let n_stages = stages.num_stages();
    anyhow::ensure!(n_stages > 0, "engine needs >= 1 stage");
    anyhow::ensure!(cfg.max_in_flight > 0, "max_in_flight must be > 0");
    let chunks = split_rows(input, cfg.micro_batch_rows)?;
    let rows = input.shape[0];
    let node_ids: Vec<usize> = (0..n_stages).map(|k| stages.node_id(k)).collect();
    let reps: Vec<usize> =
        (0..n_stages).map(|k| stages.replicas(k)).collect();
    let replica_nodes: Vec<Vec<usize>> = (0..n_stages)
        .map(|k| {
            (0..reps[k]).map(|r| stages.replica_node_id(k, r)).collect()
        })
        .collect();

    let (reply_tx, reply_rx) = channel::<Result<EngineRun>>();
    let state = Mutex::new(EngineState::new_replicated(
        node_ids.into(),
        &replica_nodes,
    ));
    lock_state(&state).register(
        0,
        chunks.len(),
        vec![Member { rows: 0..rows, reply: reply_tx }],
        rows,
    );

    // One bounded queue per (stage, replica) plus the collector's. The
    // in-flight limit is the credit windows below; the bounded queues
    // add per-stage back-pressure so a stalled stage blocks its
    // upstream driver instead of buffering unboundedly.
    let mut stage_txs: Vec<Vec<SyncSender<PFlow>>> =
        Vec::with_capacity(n_stages);
    let mut stage_rxs: Vec<Vec<Receiver<PFlow>>> =
        Vec::with_capacity(n_stages);
    for &r in &reps {
        let mut txs = Vec::with_capacity(r);
        let mut rxs = Vec::with_capacity(r);
        for _ in 0..r {
            let (tx, rx) = sync_channel::<PFlow>(cfg.max_in_flight);
            txs.push(tx);
            rxs.push(rx);
        }
        stage_txs.push(txs);
        stage_rxs.push(rxs);
    }
    let (collect_tx, collect_rx) = sync_channel::<PFlow>(cfg.max_in_flight);

    // Credit-based admission: uniform per-stage windows of
    // `max_in_flight` each, which is exactly the single global window
    // (see CreditWindows). A window of 1 degenerates to the serial
    // schedule.
    let (windows, credit_rxs) = CreditWindows::new_replicated(
        &vec![cfg.max_in_flight; n_stages],
        &reps,
    );
    let windows = Arc::new(windows);

    // One-shot runs keep the pre-ISSUE-8 fail-fast semantics: replay
    // only exists in the persistent engine (where the serving layer
    // turns it on).
    let heal = Arc::new(HealCtx::new(false));

    std::thread::scope(|scope| {
        // One driver thread per (stage, replica).
        for (k, rxs) in stage_rxs.into_iter().enumerate() {
            let next: Vec<SyncSender<PFlow>> = if k + 1 < n_stages {
                stage_txs[k + 1].clone()
            } else {
                vec![collect_tx.clone()]
            };
            for (r, rx) in rxs.into_iter().enumerate() {
                let next = next.clone();
                let state = &state;
                let windows = Arc::clone(&windows);
                let heal = Arc::clone(&heal);
                scope.spawn(move || {
                    drive_stage(
                        stages, k, r, rx, next, state, &windows, &heal,
                        None,
                    )
                });
            }
        }
        // Only the feeder may hold stage-0 senders (and only drivers the
        // rest): otherwise the shutdown cascade never reaches the
        // collector and the scope deadlocks.
        let feed_txs = std::mem::take(&mut stage_txs[0]);
        drop(stage_txs);
        drop(collect_tx);

        // Feeder: micro-batches are admitted as window credits free up.
        {
            let state = &state;
            let windows = Arc::clone(&windows);
            scope.spawn(move || {
                feed_batch(
                    stages, 0, chunks, None, &credit_rxs, &feed_txs,
                    &windows, state,
                );
            });
        }

        // Collector runs inline; it exits when the last driver drops its
        // sender (after the feeder finished and the queues drained).
        let mut ctrl = WindowCtrl::new(
            None,
            false,
            Arc::clone(&windows),
            Arc::new(DepthStats::new(cfg.max_in_flight)),
        );
        collect_loop(stages, collect_rx, &state, &mut ctrl);
    });

    match reply_rx.try_recv() {
        Ok(result) => result,
        Err(_) => Err(anyhow::anyhow!("pipeline engine dropped the batch")),
    }
}

// ---------------------------------------------------------------------------
// Persistent cross-batch engine
// ---------------------------------------------------------------------------

/// Adaptive depth controller knobs (see the module docs). The window is
/// widened while the bottleneck stage's per-batch bubble fraction stays
/// above `widen_bubble_frac`, and narrowed after two consecutive batches
/// below `narrow_bubble_frac` — hysteresis that parks the window within
/// one step of the smallest depth that saturates the bottleneck. Each
/// stage's entry gap (idle before a batch's first micro-batch) is
/// excluded from observations unless the batch's admission was
/// credit-starved: arrival spacing is not credit starvation, but a
/// window that held ready work back is.
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveDepthConfig {
    pub min_depth: usize,
    pub max_depth: usize,
    /// Widen when the bottleneck stage's bubble fraction exceeds this.
    pub widen_bubble_frac: f64,
    /// Narrow (after 2 clean batches) when it stays below this.
    pub narrow_bubble_frac: f64,
    /// Batches to skip after a change so its effect is observed before
    /// the next decision.
    pub cooldown_batches: u32,
    /// Memory-pressure budget on the shared [`crate::util::pool::BufferPool`]'s
    /// allocation miss rate (misses / takes since the last observation,
    /// in `(0, 1]`): while exceeded, the controller narrows instead of
    /// widening — fewer in-flight micro-batches means less live
    /// activation storage. `None` disables the signal.
    pub pool_miss_budget: Option<f64>,
    /// Memory-pressure budget on the bytes parked in the shared buffer
    /// pool ([`crate::util::pool::BufferPool::pooled_bytes`]). `None`
    /// disables the signal.
    pub pool_bytes_budget: Option<u64>,
}

impl Default for AdaptiveDepthConfig {
    fn default() -> Self {
        AdaptiveDepthConfig {
            min_depth: 1,
            max_depth: 8,
            widen_bubble_frac: 0.10,
            narrow_bubble_frac: 0.02,
            cooldown_batches: 1,
            pool_miss_budget: None,
            pool_bytes_budget: None,
        }
    }
}

/// Configuration for a [`PersistentEngine`].
#[derive(Debug, Clone)]
pub struct PersistentEngineConfig {
    /// Rows per micro-batch (the compiled artifact batch for real
    /// deployments).
    pub micro_batch_rows: usize,
    /// Starting credit budget per stage window (micro-batches admitted
    /// but not yet past that stage, across *all* batches at once).
    /// Uniform budgets are exactly the PR-2 global window.
    pub initial_depth: usize,
    /// Explicit starting budgets, one per stage (e.g. carried from a
    /// previous engine across a rebalance, or shaped from a measured
    /// profile via [`budgets_from_profile`]). `None` seeds every window
    /// at `initial_depth`.
    pub stage_budgets: Option<Vec<usize>>,
    /// Let the adaptive controller resize stage budgets independently
    /// (per-stage windows) instead of moving them in lockstep (the PR-2
    /// global behaviour).
    pub per_stage: bool,
    /// Feeder-side batch coalescing: merge adjacent small submissions
    /// into shared micro-batches when that reduces the micro-batch
    /// count (short tails pack together); members are re-split by row
    /// range at delivery.
    pub coalesce: bool,
    /// Enable the adaptive window controller.
    pub adaptive: Option<AdaptiveDepthConfig>,
    /// In-flight replay (ISSUE 8): when a stage execution fails on a
    /// replicated stage, re-run the micro-batch from its retained stage
    /// input on a surviving replica instead of failing the whole
    /// transport (skipped once the transport's most lenient member
    /// deadline has passed). Off (the default) preserves fail-fast
    /// behaviour bit for bit.
    pub replay: bool,
    /// Straggler hedging (ISSUE 10): on a replicated stage, a
    /// micro-batch running past the stage's armed [`HedgeConfig`]
    /// threshold is re-issued on a surviving sibling replica and the
    /// first completion wins. `None` (the default) keeps the execute
    /// path bit-identical to the unhedged engine.
    pub hedge: Option<HedgeConfig>,
}

impl Default for PersistentEngineConfig {
    fn default() -> Self {
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 4,
            stage_budgets: None,
            per_stage: false,
            coalesce: false,
            adaptive: None,
            replay: false,
            hedge: None,
        }
    }
}

impl PersistentEngineConfig {
    /// Queue bound: the widest window the controller may reach.
    fn depth_cap(&self) -> usize {
        let seeded = self
            .stage_budgets
            .as_ref()
            .and_then(|b| b.iter().copied().max())
            .unwrap_or(0)
            .max(self.initial_depth);
        match &self.adaptive {
            Some(a) => a.max_depth.max(seeded),
            None => seeded,
        }
    }
}

/// Snapshot of the adaptive controller's trajectory for reports.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DepthReport {
    pub initial_depth: usize,
    pub final_depth: usize,
    pub min_depth: usize,
    pub max_depth: usize,
    pub widenings: u64,
    pub narrowings: u64,
}

/// A waiter for one submitted batch.
pub struct BatchHandle {
    rx: Receiver<Result<EngineRun>>,
}

impl BatchHandle {
    /// Block until the batch's last micro-batch is delivered (or its
    /// first failure has drained through the pipeline).
    pub fn wait(self) -> Result<EngineRun> {
        match self.rx.recv() {
            Ok(result) => result,
            Err(_) => Err(anyhow::anyhow!(
                "persistent engine shut down before the batch completed"
            )),
        }
    }
}

/// One batch handed to the feeder thread: the waiter's reply sender and
/// the raw rows (chunking happens feeder-side so adjacent submissions
/// can coalesce into shared micro-batches), plus the request-level
/// context the serving ingress threads through — the priority class the
/// feeder orders pending submissions by, and an optional wall-clock
/// deadline checked right before admission.
struct SubmitMsg {
    reply: Sender<Result<EngineRun>>,
    tensor: Tensor,
    /// Priority class (0 = most urgent): when several submissions are
    /// waiting, the feeder admits the lowest class first (FIFO within a
    /// class).
    class: usize,
    /// Absolute deadline: if it has already passed when the feeder is
    /// about to admit the batch, the batch is shed with a
    /// [`DeadlineShed`] error instead of spending engine credits on
    /// output nobody can use.
    deadline: Option<std::time::Instant>,
}

/// Marker error for a batch the engine shed because its deadline
/// expired while it waited in the submission queue. Callers (the
/// serving ingress) downcast to tell a shed from a real failure.
#[derive(Debug, Clone, Copy)]
pub struct DeadlineShed;

impl std::fmt::Display for DeadlineShed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "deadline expired before engine admission; batch shed")
    }
}

impl std::error::Error for DeadlineShed {}

/// Feeder-side coalescing counters (see
/// [`crate::metrics::CoalesceStats`]).
#[derive(Default)]
struct CoalesceCounters {
    transports: AtomicU64,
    coalesced_transports: AtomicU64,
    member_batches: AtomicU64,
    saved_micro_batches: AtomicU64,
}

/// Most member batches one transport may carry: bounds the blast radius
/// of a failure inside a coalesced transport (every member shares its
/// fate) and the per-delivery reassembly work.
const MAX_COALESCE_MEMBERS: usize = 8;

/// Deadline-aware batch formation (ISSUE 9): the feeder skips
/// coalescing entirely when the head submission's remaining slack is
/// below this multiple of the EWMA transport service estimate — a
/// tight-deadline submission must not grow into a larger transport
/// whose extra micro-batches it then waits on.
const COALESCE_SLACK_FACTOR: f64 = 2.0;

/// True when `deadline` leaves less than [`COALESCE_SLACK_FACTOR`] x
/// `est_ms` of slack at `now`. Deadline-free heads and a cold estimate
/// (`est_ms == None`) never veto, so coalescing-off runs and warm-up
/// behave exactly as before.
fn coalesce_too_tight(
    deadline: Option<std::time::Instant>,
    est_ms: Option<f64>,
    now: std::time::Instant,
) -> bool {
    let (Some(d), Some(est)) = (deadline, est_ms) else {
        return false;
    };
    let slack_ms = d.saturating_duration_since(now).as_secs_f64() * 1e3;
    slack_ms < COALESCE_SLACK_FACTOR * est
}

/// Micro-batches needed for `rows` rows at `micro` rows per chunk.
fn chunks_for(rows: usize, micro: usize) -> usize {
    rows.div_ceil(micro)
}

/// Map learned per-stage budgets onto a chain with a different stage
/// count (an engine-aware rebalance after a topology change): nearest
/// rank sampling with pinned endpoints — the first budget (admission
/// pacing) and the last (delivery window) always carry over verbatim
/// (when `n_new == 1` the delivery budget wins), and monotone sources
/// stay monotone.
pub fn carry_stage_budgets(old: &[usize], n_new: usize) -> Vec<usize> {
    assert!(!old.is_empty() && n_new > 0, "carry needs non-empty budgets");
    (0..n_new)
        .map(|i| {
            let j = if i == 0 && n_new > 1 {
                0
            } else {
                ((i + 1) * old.len() / n_new)
                    .saturating_sub(1)
                    .min(old.len() - 1)
            };
            old[j].max(1)
        })
        .collect()
}

/// Shape `total_credits` credits into per-stage budgets from a measured
/// per-stage latency profile (compute + ingress comm, e.g. a probe
/// run's [`StageCounter`]s). Each stage's budget is proportional to the
/// *cumulative* latency through it — the in-flight count needed to keep
/// a stage fed scales with the admission-to-that-stage delay — so fast
/// early stages get small windows and the delivery window absorbs the
/// rest. Result is non-decreasing, every budget >= 1, and sums to
/// `max(total_credits, stages)`.
pub fn budgets_from_profile(
    stage_latency_ms: &[f64],
    total_credits: usize,
) -> Vec<usize> {
    let s = stage_latency_ms.len();
    assert!(s > 0, "profile needs >= 1 stage");
    let mut cum = Vec::with_capacity(s);
    let mut acc = 0.0f64;
    for &ms in stage_latency_ms {
        acc += ms.max(1e-9);
        cum.push(acc);
    }
    let sum_cum: f64 = cum.iter().sum();
    let target = total_credits.max(s);
    let mut w: Vec<usize> = cum
        .iter()
        .map(|c| ((target as f64 * c / sum_cum).round() as usize).max(1))
        .collect();
    for k in 1..s {
        w[k] = w[k].max(w[k - 1]);
    }
    // Fix the rounded sum up/down to the target without breaking
    // monotonicity: trim the earliest shrinkable budget, grow the
    // delivery window.
    loop {
        let sum: usize = w.iter().sum();
        if sum > target {
            let Some(k) = (0..s)
                .find(|&k| w[k] > 1 && (k == 0 || w[k] > w[k - 1]))
            else {
                break;
            };
            w[k] -= 1;
        } else if sum < target {
            w[s - 1] += target - sum;
        } else {
            break;
        }
    }
    w
}

/// Persistent feeder loop: pop submissions, admit the most urgent one
/// (lowest priority class, FIFO within a class — a backlogged
/// submission queue is exactly where request-level priority matters),
/// shed it instead if its deadline already passed, optionally coalesce
/// adjacent small same-class submissions into a single transport (only
/// when merging strictly reduces the micro-batch count — short tails
/// packing together — and tails are shape-compatible), register the
/// transport, and feed its micro-batches through the credit windows. A
/// submission that arrives while the previous one is still acquiring
/// credits queues up and becomes a reordering/coalescing candidate,
/// which is exactly the "window under-filled" condition: saturated
/// pipelines back-pressure the feeder and small miss-sets pile up
/// behind it.
#[allow(clippy::too_many_arguments)]
fn feeder_loop(
    stages: Arc<dyn StageExec + Send + Sync>,
    submit_rx: Receiver<SubmitMsg>,
    feed_txs: Vec<SyncSender<PFlow>>,
    credit_rxs: Vec<Receiver<f64>>,
    windows: Arc<CreditWindows>,
    state: Arc<Mutex<EngineState>>,
    micro: usize,
    coalesce: bool,
    counters: Arc<CoalesceCounters>,
) {
    let mut next_id: u64 = 0;
    let mut next_seq: u64 = 0;
    // Pending submissions, always ascending by arrival seq (drained from
    // the FIFO channel in order). With a single class the head pick
    // below is exactly the old FIFO pop, so default traffic keeps the
    // PR-3 schedule bit-for-bit.
    let mut buf: Vec<(u64, SubmitMsg)> = Vec::new();
    loop {
        if buf.is_empty() {
            match submit_rx.recv() {
                Ok(s) => {
                    buf.push((next_seq, s));
                    next_seq += 1;
                }
                Err(_) => break, // all submit senders dropped
            }
        }
        // Opportunistic drain so priority sees everything waiting.
        while let Ok(s) = submit_rx.try_recv() {
            buf.push((next_seq, s));
            next_seq += 1;
        }
        let head = buf
            .iter()
            .enumerate()
            .min_by_key(|(_, (seq, m))| (m.class, *seq))
            .map(|(i, _)| i)
            .expect("buffer non-empty");
        let (_, first) = buf.remove(head);
        if let Some(d) = first.deadline {
            if std::time::Instant::now() >= d {
                let _ = first
                    .reply
                    .send(Err(anyhow::Error::new(DeadlineShed)));
                continue;
            }
        }
        let cls = first.class;
        let head_deadline = first.deadline;
        let mut group = vec![first];
        // Deadline-aware formation: a head with little slack left rides
        // alone (smallest possible transport) instead of merging.
        if coalesce
            && !coalesce_too_tight(
                head_deadline,
                lock_state(&state).service_ewma_ms,
                std::time::Instant::now(),
            )
        {
            // Scan remaining pending submissions in arrival order,
            // merging same-class neighbours; stop at the first
            // same-class candidate that doesn't merge (the old
            // stop-at-first-non-merging rule).
            let mut i = 0;
            while group.len() < MAX_COALESCE_MEMBERS && i < buf.len() {
                if buf[i].1.class != cls {
                    i += 1;
                    continue;
                }
                let cur_rows: usize =
                    group.iter().map(|s| s.tensor.shape[0]).sum();
                let nrows = buf[i].1.tensor.shape[0];
                let tail_ok =
                    buf[i].1.tensor.shape[1..] == group[0].tensor.shape[1..];
                let saves = chunks_for(cur_rows, micro)
                    + chunks_for(nrows, micro)
                    > chunks_for(cur_rows + nrows, micro);
                if tail_ok && saves {
                    let (_, next) = buf.remove(i);
                    // The head's deadline was checked above; a merged
                    // member gets the same pre-admission check — an
                    // expired candidate is shed here instead of riding
                    // the transport into the pipeline (re-examine the
                    // same index after the removal either way).
                    if let Some(d) = next.deadline {
                        if std::time::Instant::now() >= d {
                            let _ = next
                                .reply
                                .send(Err(anyhow::Error::new(DeadlineShed)));
                            continue;
                        }
                    }
                    group.push(next);
                } else {
                    break;
                }
            }
        }

        let id = next_id;
        next_id += 1;
        let n_members = group.len();
        // Transport deadline for in-flight replay: the most *lenient*
        // member deadline — replay is pointless only once no member can
        // use the output. None (replay always worthwhile) when any
        // member is deadline-free.
        let transport_deadline =
            if group.iter().all(|s| s.deadline.is_some()) {
                group.iter().filter_map(|s| s.deadline).max()
            } else {
                None
            };
        let mut replies = Vec::with_capacity(n_members);
        let mut tensors = Vec::with_capacity(n_members);
        for s in group {
            replies.push(s.reply);
            tensors.push(s.tensor);
        }
        let row_counts: Vec<usize> =
            tensors.iter().map(|t| t.shape[0]).collect();
        let chunks = if tensors.len() == 1 {
            Ok(tensors.pop().expect("one tensor"))
        } else {
            // Coalesced members merge into one backing buffer here; the
            // micro-batch views split off below all share it.
            concat_rows_owned(tensors)
        }
        .and_then(|merged| {
            // Under coalescing, zero-pad the merged tail up to a whole
            // micro-batch: the serving path submits exact-row miss sets
            // (`padded_rows` stops rounding), but real deployments run
            // executables compiled for exactly `micro` rows, so every
            // chunk must be full-size. Members only ever cover their
            // real row ranges, so the padding rows are dropped at
            // reassembly. Without coalescing the tail is fed exactly as
            // submitted — identical to `run_streamed` and the PR-2
            // engine (callers pad to the compiled batch themselves).
            let rows = merged.shape[0];
            let padded =
                if coalesce { chunks_for(rows, micro) * micro } else { rows };
            let merged = if padded == rows {
                merged
            } else {
                // Padding needs fresh contiguous storage — a genuine
                // data-plane copy unless the merged buffer is already
                // exclusively ours (then `into_vec` just resizes it).
                let row_len = merged.row_len();
                let mut shape = merged.shape.clone();
                shape[0] = padded;
                let mut data = merged.into_vec();
                data.resize(padded * row_len, 0.0);
                Tensor::new(shape, data)?
            };
            Ok((padded, split_rows(&merged, micro)?))
        });
        let (padded_rows, chunks) = match chunks {
            Ok(c) => c,
            Err(e) => {
                let msg = format!("{e:#}");
                for r in replies {
                    let _ = r.send(Err(anyhow::anyhow!(
                        "transport formation failed: {msg}"
                    )));
                }
                continue;
            }
        };

        counters.transports.fetch_add(1, Ordering::Relaxed);
        counters
            .member_batches
            .fetch_add(n_members as u64, Ordering::Relaxed);
        if n_members > 1 {
            counters.coalesced_transports.fetch_add(1, Ordering::Relaxed);
            let separate: usize =
                row_counts.iter().map(|&r| chunks_for(r, micro)).sum();
            counters
                .saved_micro_batches
                .fetch_add((separate - chunks.len()) as u64, Ordering::Relaxed);
        }

        let mut members = Vec::with_capacity(n_members);
        let mut start = 0;
        for (reply, rows) in replies.into_iter().zip(row_counts) {
            members.push(Member { rows: start..start + rows, reply });
            start += rows;
        }
        lock_state(&state).register(id, chunks.len(), members, padded_rows);
        if !feed_batch(
            &*stages, id, chunks, transport_deadline, &credit_rxs,
            &feed_txs, &windows, &state,
        ) {
            // The pipeline died under us (panic-driven cascade): fail
            // this transport and every submission still reaching the
            // queue so no waiter hangs on a reply that will never come
            // (dropping a SubmitMsg drops its reply sender). The loop
            // ends only when all submit senders drop.
            lock_state(&state).batches.remove(&id);
            while submit_rx.recv().is_ok() {}
            break;
        }
    }
}

/// Long-lived streaming engine: per-stage driver threads, a feeder, and
/// a collector that all survive across batches, fed through
/// [`PersistentEngine::submit`]. Successive batches stream back-to-back
/// through the same bounded queues — no inter-batch drain, no thread
/// churn — while the shared [`CriticalPath`] keeps device-honest
/// simulated accounting across batch boundaries. Admission flows
/// through per-stage credit windows ([`CreditWindows`]); the feeder may
/// coalesce adjacent small submissions into shared micro-batches when
/// enabled. Dropping the engine drains in-flight batches (their
/// [`BatchHandle`]s still complete) and joins every thread.
pub struct PersistentEngine {
    submit_tx: Option<SyncSender<SubmitMsg>>,
    state: Arc<Mutex<EngineState>>,
    threads: Vec<std::thread::JoinHandle<()>>,
    node_ids: Arc<[usize]>,
    /// `replica_nodes[k][r]` = node hosting replica `r` of stage `k`
    /// (`replica_nodes[k][0] == node_ids[k]`).
    replica_nodes: Vec<Vec<usize>>,
    depth_stats: Arc<DepthStats>,
    windows: Arc<CreditWindows>,
    coalesce: Arc<CoalesceCounters>,
    /// Replay switch + counters shared with every stage driver.
    heal: Arc<HealCtx>,
    /// Hedging policy + counters, present when straggler hedging is on.
    hedge_ctx: Option<Arc<HedgeCtx>>,
    /// `[min_depth, max_depth]` of the adaptive controller, if one is
    /// active — [`PersistentEngine::reshape_budgets`] clamps external
    /// targets into it so a live retune can never fight the controller
    /// out of its configured range.
    budget_bounds: Option<(usize, usize)>,
}

impl PersistentEngine {
    /// Spawn the engine over an owned stage chain.
    pub fn new<S: StageExec + Send + Sync + 'static>(
        stages: Arc<S>,
        cfg: PersistentEngineConfig,
    ) -> Result<PersistentEngine> {
        Self::new_dyn(stages, cfg)
    }

    /// Type-erased constructor (the engine stores `dyn StageExec`).
    pub fn new_dyn(
        stages: Arc<dyn StageExec + Send + Sync>,
        cfg: PersistentEngineConfig,
    ) -> Result<PersistentEngine> {
        let n_stages = stages.num_stages();
        anyhow::ensure!(n_stages > 0, "engine needs >= 1 stage");
        anyhow::ensure!(cfg.micro_batch_rows > 0, "micro_batch_rows must be > 0");
        anyhow::ensure!(cfg.initial_depth > 0, "initial_depth must be > 0");
        if let Some(a) = &cfg.adaptive {
            anyhow::ensure!(a.min_depth >= 1, "min_depth must be >= 1");
            anyhow::ensure!(
                a.min_depth <= a.max_depth,
                "min_depth {} > max_depth {}",
                a.min_depth,
                a.max_depth
            );
            anyhow::ensure!(
                (a.min_depth..=a.max_depth).contains(&cfg.initial_depth),
                "initial_depth {} outside adaptive range [{}, {}]",
                cfg.initial_depth,
                a.min_depth,
                a.max_depth
            );
            // Thresholds: widen must sit at or above narrow, or the
            // controller oscillates +1/-1 forever in the overlap band;
            // NaN would silently disable both comparisons.
            anyhow::ensure!(
                a.widen_bubble_frac.is_finite()
                    && a.narrow_bubble_frac.is_finite()
                    && a.narrow_bubble_frac >= 0.0
                    && a.widen_bubble_frac >= a.narrow_bubble_frac,
                "bubble thresholds must be finite with widen ({}) >= \
                 narrow ({}) >= 0",
                a.widen_bubble_frac,
                a.narrow_bubble_frac
            );
            if let Some(m) = a.pool_miss_budget {
                anyhow::ensure!(
                    m.is_finite() && m > 0.0 && m <= 1.0,
                    "pool_miss_budget {m} must be a rate in (0, 1]"
                );
            }
        }
        if let Some(budgets) = &cfg.stage_budgets {
            anyhow::ensure!(
                budgets.len() == n_stages,
                "stage_budgets has {} entries for {} stages",
                budgets.len(),
                n_stages
            );
            anyhow::ensure!(
                budgets.iter().all(|&b| b >= 1),
                "every stage budget must be >= 1 (got {budgets:?})"
            );
            if let Some(a) = &cfg.adaptive {
                anyhow::ensure!(
                    budgets
                        .iter()
                        .all(|b| (a.min_depth..=a.max_depth).contains(b)),
                    "stage budgets {budgets:?} outside adaptive range \
                     [{}, {}]",
                    a.min_depth,
                    a.max_depth
                );
            }
        }
        if let Some(h) = &cfg.hedge {
            anyhow::ensure!(
                h.factor.is_finite() && h.factor >= 1.0,
                "hedge factor {} must be finite and >= 1",
                h.factor
            );
            anyhow::ensure!(
                h.min_ms.is_finite() && h.min_ms >= 0.0,
                "hedge min_ms {} must be finite and >= 0",
                h.min_ms
            );
            anyhow::ensure!(
                h.min_samples >= 1,
                "hedge min_samples must be >= 1"
            );
        }
        let node_ids: Arc<[usize]> =
            (0..n_stages).map(|k| stages.node_id(k)).collect();
        let reps: Vec<usize> =
            (0..n_stages).map(|k| stages.replicas(k)).collect();
        let replica_nodes: Vec<Vec<usize>> = (0..n_stages)
            .map(|k| {
                (0..reps[k]).map(|r| stages.replica_node_id(k, r)).collect()
            })
            .collect();
        let state = Arc::new(Mutex::new(EngineState::new_replicated(
            Arc::clone(&node_ids),
            &replica_nodes,
        )));
        let cap = cfg.depth_cap();
        let seed_budgets = cfg
            .stage_budgets
            .clone()
            .unwrap_or_else(|| vec![cfg.initial_depth; n_stages]);

        // One bounded queue per (stage, replica) plus the collector's.
        let mut stage_txs: Vec<Vec<SyncSender<PFlow>>> =
            Vec::with_capacity(n_stages);
        let mut stage_rxs: Vec<Vec<Receiver<PFlow>>> =
            Vec::with_capacity(n_stages);
        for &r in &reps {
            let mut txs = Vec::with_capacity(r);
            let mut rxs = Vec::with_capacity(r);
            for _ in 0..r {
                let (tx, rx) = sync_channel::<PFlow>(cap);
                txs.push(tx);
                rxs.push(rx);
            }
            stage_txs.push(txs);
            stage_rxs.push(rxs);
        }
        let (collect_tx, collect_rx) = sync_channel::<PFlow>(cap);

        let (windows, credit_rxs) =
            CreditWindows::new_replicated(&seed_budgets, &reps);
        let windows = Arc::new(windows);
        let depth_stats =
            Arc::new(DepthStats::new(*seed_budgets.last().expect("stages")));
        let coalesce_counters = Arc::new(CoalesceCounters::default());
        let heal = Arc::new(HealCtx::new(cfg.replay));
        let hedge_ctx =
            cfg.hedge.map(|h| Arc::new(HedgeCtx::new(h, n_stages)));

        let n_drivers: usize = reps.iter().sum();
        let mut threads = Vec::with_capacity(n_drivers + 2);
        for (k, rxs) in stage_rxs.into_iter().enumerate() {
            let next: Vec<SyncSender<PFlow>> = if k + 1 < n_stages {
                stage_txs[k + 1].clone()
            } else {
                vec![collect_tx.clone()]
            };
            let replicated = rxs.len() > 1;
            for (r, rx) in rxs.into_iter().enumerate() {
                let next = next.clone();
                let stages = Arc::clone(&stages);
                let state = Arc::clone(&state);
                let windows = Arc::clone(&windows);
                let heal = Arc::clone(&heal);
                let hedge = hedge_ctx.as_ref().map(|ctx| HedgeRt {
                    stages: Arc::clone(&stages),
                    ctx: Arc::clone(ctx),
                });
                let name = if replicated {
                    format!("pipe-stage-{k}.{r}")
                } else {
                    format!("pipe-stage-{k}")
                };
                threads.push(
                    std::thread::Builder::new()
                        .name(name)
                        .spawn(move || {
                            drive_stage(
                                &*stages, k, r, rx, next, &state, &windows,
                                &heal, hedge.as_ref(),
                            )
                        })
                        .context("spawning stage driver")?,
                );
            }
        }
        // Only the feeder may hold stage-0 senders (and only drivers
        // the rest), so dropping the feeder cascades shutdown stage by
        // stage to the collector.
        let feed_txs = std::mem::take(&mut stage_txs[0]);
        drop(stage_txs);
        drop(collect_tx);
        {
            let stages = Arc::clone(&stages);
            let state = Arc::clone(&state);
            let stats = Arc::clone(&depth_stats);
            let windows = Arc::clone(&windows);
            let adaptive = cfg.adaptive;
            let per_stage = cfg.per_stage;
            threads.push(
                std::thread::Builder::new()
                    .name("pipe-collect".into())
                    .spawn(move || {
                        let mut ctrl =
                            WindowCtrl::new(adaptive, per_stage, windows, stats);
                        collect_loop(&*stages, collect_rx, &state, &mut ctrl);
                    })
                    .context("spawning collector")?,
            );
        }
        let (submit_tx, submit_rx) = sync_channel::<SubmitMsg>(cap.max(4));
        {
            let stages = Arc::clone(&stages);
            let state = Arc::clone(&state);
            let windows = Arc::clone(&windows);
            let counters = Arc::clone(&coalesce_counters);
            let micro = cfg.micro_batch_rows;
            let coalesce = cfg.coalesce;
            threads.push(
                std::thread::Builder::new()
                    .name("pipe-feed".into())
                    .spawn(move || {
                        feeder_loop(
                            stages, submit_rx, feed_txs, credit_rxs, windows,
                            state, micro, coalesce, counters,
                        );
                        // Dropping feed_txs cascades shutdown through the
                        // stage drivers to the collector.
                    })
                    .context("spawning feeder")?,
            );
        }

        Ok(PersistentEngine {
            submit_tx: Some(submit_tx),
            state,
            threads,
            node_ids,
            replica_nodes,
            depth_stats,
            windows,
            coalesce: coalesce_counters,
            heal,
            hedge_ctx,
            budget_bounds: cfg.adaptive.map(|a| (a.min_depth, a.max_depth)),
        })
    }

    /// Split `input` into micro-batches and enqueue them behind any
    /// batches already flowing — no drain in between. Returns a
    /// [`BatchHandle`] whose `wait` yields the reassembled, in-order
    /// output (bit-identical to a serial traversal) plus batch-local
    /// timing. Blocks only on submission-queue back-pressure, never on
    /// the batch's execution.
    pub fn submit(&self, input: &Tensor) -> Result<BatchHandle> {
        self.submit_owned(input.clone())
    }

    /// By-value submission: avoids the defensive row copy when the
    /// caller already owns the batch (the ingress streaming path hands
    /// its stacked miss-set straight through). Class 0, no deadline.
    pub fn submit_owned(&self, input: Tensor) -> Result<BatchHandle> {
        self.submit_owned_with(input, 0, None)
    }

    /// Submission with request-level context: `class` orders pending
    /// submissions in the feeder (lowest first, FIFO within a class) and
    /// `deadline` lets the feeder shed the batch with a [`DeadlineShed`]
    /// error if it expires before admission.
    pub fn submit_owned_with(
        &self,
        input: Tensor,
        class: usize,
        deadline: Option<std::time::Instant>,
    ) -> Result<BatchHandle> {
        anyhow::ensure!(!input.shape.is_empty(), "cannot submit a scalar tensor");
        anyhow::ensure!(input.shape[0] > 0, "empty batch");
        let (reply_tx, reply_rx) = channel::<Result<EngineRun>>();
        let submit_tx = self.submit_tx.as_ref().expect("engine running");
        let msg = SubmitMsg { reply: reply_tx, tensor: input, class, deadline };
        if submit_tx.send(msg).is_err() {
            anyhow::bail!("persistent engine is shut down");
        }
        Ok(BatchHandle { rx: reply_rx })
    }

    /// Submit and wait — the synchronous convenience used by
    /// `DistributedService::infer_batch`.
    pub fn run(&self, input: &Tensor) -> Result<EngineRun> {
        self.submit(input)?.wait()
    }

    pub fn n_stages(&self) -> usize {
        self.node_ids.len()
    }

    /// Node hosting each stage of *this engine's* chain. Callers doing
    /// per-node accounting must use these (not a freshly-read
    /// deployment): during a deployment swap a batch submitted to this
    /// engine still executes on this engine's stages.
    pub fn node_ids(&self) -> &[usize] {
        &self.node_ids
    }

    /// Shared handle to the stage→node map: callers that charge the
    /// scheduler per batch clone the `Arc` instead of copying the ids
    /// for every submission.
    pub fn shared_node_ids(&self) -> Arc<[usize]> {
        Arc::clone(&self.node_ids)
    }

    /// Replica map: `replica_nodes()[k][r]` is the node hosting replica
    /// `r` of stage `k` (replica 0 = the primary in [`node_ids`]).
    ///
    /// [`node_ids`]: PersistentEngine::node_ids
    pub fn replica_nodes(&self) -> &[Vec<usize>] {
        &self.replica_nodes
    }

    /// Cumulative per-replica occupancy/bubble counters across every
    /// batch served — one entry per `(stage, replica)` lane.
    pub fn replica_counters(&self) -> Vec<crate::metrics::ReplicaCounter> {
        lock_state(&self.state).cp.replica_counters()
    }

    /// The delivery window right now (== the configured depth unless
    /// the adaptive controller moved it; with uniform budgets this is
    /// exactly the PR-2 global credit window).
    pub fn current_depth(&self) -> usize {
        self.depth_stats.current.load(Ordering::SeqCst)
    }

    /// Live per-stage credit budgets — the learned window shape a
    /// rebalance carries into the rebuilt engine (see
    /// [`carry_stage_budgets`]).
    pub fn stage_budgets(&self) -> Vec<usize> {
        self.windows.budgets_snapshot()
    }

    /// Move the live per-stage budgets toward `target` without draining
    /// the pipeline: each window is widened credit by credit (new
    /// credits valued at the current makespan, so their clocks start
    /// "now") or narrowed by marking returned credits for absorption —
    /// the same primitives the adaptive controller uses, so a retune is
    /// safe while batches are in flight and composes with a concurrent
    /// controller (both paths go through the atomic budget counters).
    /// Targets are clamped to the adaptive `[min, max]` range when a
    /// controller is active, and never below 1. Extra entries in
    /// `target` are ignored; missing ones leave their windows untouched.
    ///
    /// This is how the serving layer re-shapes windows from the
    /// monitor's *live* profile (`budgets_from_profile` over
    /// load-scaled stage latencies) instead of only a startup probe.
    pub fn reshape_budgets(&self, target: &[usize]) {
        let now = lock_state(&self.state).cp.makespan_ms();
        let (lo, hi) = self.budget_bounds.unwrap_or((1, usize::MAX));
        for (k, &t) in target.iter().enumerate().take(self.windows.n()) {
            let want = t.clamp(lo.max(1), hi);
            let cur = self.windows.stage_budget(k);
            if want > cur {
                for _ in cur..want {
                    self.windows.widen(k, now);
                }
            } else {
                for _ in want..cur {
                    self.windows.narrow(k);
                }
            }
        }
        // Keep the reported depth (== delivery budget) in sync.
        self.depth_stats.set_depth(self.windows.delivery_budget());
    }

    /// In-flight replay counters since startup (all zero unless
    /// [`PersistentEngineConfig::replay`] is on and a stage failed
    /// mid-stream).
    pub fn replay_stats(&self) -> ReplayStats {
        self.heal.stats()
    }

    /// Cloneable handle onto this engine's replay counters that stays
    /// readable after the engine itself is torn down — a deployment
    /// swap reads the drained engine's final counts through it *after*
    /// the drop joins the driver threads.
    pub fn replay_probe(&self) -> ReplayProbe {
        ReplayProbe(Arc::clone(&self.heal))
    }

    /// Straggler-hedging counters since startup (all zero when hedging
    /// is off).
    pub fn hedge_stats(&self) -> HedgeStats {
        self.hedge_ctx
            .as_ref()
            .map(|c| c.stats())
            .unwrap_or_default()
    }

    /// Feeder-side coalescing counters since startup.
    pub fn coalesce_stats(&self) -> crate::metrics::CoalesceStats {
        crate::metrics::CoalesceStats {
            transports: self.coalesce.transports.load(Ordering::Relaxed),
            coalesced_transports: self
                .coalesce
                .coalesced_transports
                .load(Ordering::Relaxed),
            member_batches: self.coalesce.member_batches.load(Ordering::Relaxed),
            saved_micro_batches: self
                .coalesce
                .saved_micro_batches
                .load(Ordering::Relaxed),
        }
    }

    /// EWMA of observed registration-to-last-delivery transport service
    /// time, ms (`None` until the first transport completes). The
    /// feeder's deadline-aware coalescing guard consults this.
    pub fn service_estimate_ms(&self) -> Option<f64> {
        lock_state(&self.state).service_ewma_ms
    }

    /// The adaptive controller's trajectory so far.
    pub fn depth_report(&self) -> DepthReport {
        self.depth_stats.report()
    }

    /// Simulated time of the last delivery across *all* batches — the
    /// cross-batch makespan (aggregate throughput = total rows / this).
    pub fn makespan_ms(&self) -> f64 {
        lock_state(&self.state).cp.makespan_ms()
    }

    /// Cumulative per-stage counters across every batch served.
    pub fn total_counters(&self) -> Vec<StageCounter> {
        lock_state(&self.state).cp.counters()
    }
}

impl Drop for PersistentEngine {
    fn drop(&mut self) {
        // Close the submission queue; the feeder drains what was already
        // accepted, then the shutdown cascades stage by stage. In-flight
        // batches complete and their handles resolve before the joins
        // finish.
        drop(self.submit_tx.take());
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn input(rows: usize, cols: usize) -> Tensor {
        let data = (0..rows * cols).map(|i| i as f32 * 0.5 - 3.0).collect();
        Tensor::new(vec![rows, cols], data).unwrap()
    }

    #[test]
    fn coalesce_slack_guard_vetoes_only_tight_deadlines() {
        use std::time::Duration;
        let now = std::time::Instant::now();
        // No deadline, or a cold service estimate: never veto.
        assert!(!coalesce_too_tight(None, Some(5.0), now));
        let soon = now + Duration::from_millis(5);
        assert!(!coalesce_too_tight(Some(soon), None, now));
        // Slack (5 ms) below 2x the 5 ms estimate: veto coalescing.
        assert!(coalesce_too_tight(Some(soon), Some(5.0), now));
        // Generous slack (50 ms >= 2 * 5 ms): coalescing stays on.
        let late = now + Duration::from_millis(50);
        assert!(!coalesce_too_tight(Some(late), Some(5.0), now));
        // An already-expired deadline has zero slack: veto.
        let past = now + Duration::from_millis(1);
        assert!(coalesce_too_tight(Some(now), Some(1.0), past));
    }

    #[test]
    fn split_concat_roundtrip() {
        let t = input(5, 3);
        let chunks = split_rows(&t, 2).unwrap();
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0].shape, vec![2, 3]);
        assert_eq!(chunks[2].shape, vec![1, 3]);
        assert_eq!(concat_rows(&chunks).unwrap(), t);
        assert!(split_rows(&t, 0).is_err());
        assert!(concat_rows(&[]).is_err());
    }

    #[test]
    fn streamed_output_is_bit_identical_to_serial() {
        let stages = SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0);
        let t = input(6, 8);
        let serial = run_serial(&stages, &t, 1).unwrap();
        let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: 4 };
        let streamed = run_streamed(&stages, &t, &cfg).unwrap();
        assert_eq!(serial.output, streamed.output);
        // Also identical to a single full-batch traversal (row-wise ops).
        let whole = run_serial(&stages, &t, 6).unwrap();
        assert_eq!(whole.output, streamed.output);
    }

    #[test]
    fn serial_total_equals_compute_plus_comm() {
        // The ISSUE-1 regression at engine level: a serial single-chunk
        // traversal's simulated total must be the sum of its parts.
        let stages = SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0);
        let t = input(2, 4);
        let run = run_serial(&stages, &t, 2).unwrap();
        let tm = &run.timing;
        assert!(
            (tm.total_ms - (tm.compute_ms + tm.comm_ms)).abs() < 1e-6,
            "total {} vs compute {} + comm {}",
            tm.total_ms, tm.compute_ms, tm.comm_ms
        );
        assert_eq!(tm.stages.len(), 3);
        assert!(tm.compute_ms > 0.0 && tm.comm_ms > 0.0);
    }

    #[test]
    fn streaming_beats_serial_sim_time() {
        let stages = SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0);
        let t = input(6, 4);
        let serial = run_serial(&stages, &t, 1).unwrap();
        let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: 4 };
        let streamed = run_streamed(&stages, &t, &cfg).unwrap();
        assert!(
            streamed.timing.total_ms < serial.timing.total_ms,
            "streamed {:.2} ms must beat serial {:.2} ms",
            streamed.timing.total_ms,
            serial.timing.total_ms
        );
        // Same work was done: compute totals match up to dilation noise
        // (nominal costs are fixed, so they match closely).
        assert!(
            (streamed.timing.compute_ms - serial.timing.compute_ms).abs()
                < 0.25 * serial.timing.compute_ms,
            "compute {} vs {}",
            streamed.timing.compute_ms,
            serial.timing.compute_ms
        );
        // The slowest stage stays busy: its bubble time is small relative
        // to the makespan, and every stage saw every micro-batch.
        for c in &streamed.stage_counters {
            assert_eq!(c.micro_batches, 6);
        }
    }

    #[test]
    fn errors_propagate_with_stage_context() {
        struct Failing;
        impl StageExec for Failing {
            fn num_stages(&self) -> usize {
                2
            }
            fn node_id(&self, stage: usize) -> usize {
                stage
            }
            fn comm_in(&self, _stage: usize, _bytes: u64) -> f64 {
                0.0
            }
            fn comm_out(&self, _bytes: u64) -> f64 {
                0.0
            }
            fn execute(&self, stage: usize, input: Tensor) -> Result<(Tensor, f64)> {
                anyhow::ensure!(stage == 0, "boom at stage {stage}");
                Ok((input, 1.0))
            }
        }
        let t = input(4, 2);
        let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: 2 };
        let err = run_streamed(&Failing, &t, &cfg).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("stage 1"), "unexpected error: {msg}");
        assert!(run_serial(&Failing, &t, 1).is_err());
    }

    #[test]
    fn window_of_one_reproduces_serial_schedule() {
        // max_in_flight = 1: each micro-batch is admitted only when the
        // previous one is delivered — the streamed makespan must equal
        // the serial one, and wider windows must strictly beat it.
        let stages = SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0);
        let t = input(4, 4);
        let serial = run_serial(&stages, &t, 1).unwrap();
        let w1 = run_streamed(
            &stages,
            &t,
            &EngineConfig { micro_batch_rows: 1, max_in_flight: 1 },
        )
        .unwrap();
        assert!(
            (w1.timing.total_ms - serial.timing.total_ms).abs() < 1e-9,
            "window-1 streamed {} must equal serial {}",
            w1.timing.total_ms,
            serial.timing.total_ms
        );
        let w4 = run_streamed(
            &stages,
            &t,
            &EngineConfig { micro_batch_rows: 1, max_in_flight: 4 },
        )
        .unwrap();
        assert!(
            w4.timing.total_ms < w1.timing.total_ms,
            "window 4 ({}) must beat window 1 ({})",
            w4.timing.total_ms,
            w1.timing.total_ms
        );
        assert_eq!(w1.output, w4.output);
    }

    #[test]
    fn single_stage_single_chunk_degenerates() {
        let stages = SimStages::heterogeneous(&[1.0], 1.0);
        let t = input(2, 2);
        let cfg = EngineConfig { micro_batch_rows: 2, max_in_flight: 1 };
        let run = run_streamed(&stages, &t, &cfg).unwrap();
        assert_eq!(run.output.shape, vec![2, 2]);
        assert_eq!(run.stage_counters.len(), 1);
        assert_eq!(run.stage_counters[0].micro_batches, 1);
        let tm = &run.timing;
        assert!((tm.total_ms - (tm.compute_ms + tm.comm_ms)).abs() < 1e-6);
    }

    fn input_off(rows: usize, cols: usize, off: f32) -> Tensor {
        let data =
            (0..rows * cols).map(|i| i as f32 * 0.5 - 3.0 + off).collect();
        Tensor::new(vec![rows, cols], data).unwrap()
    }

    #[test]
    fn persistent_multi_batch_bit_identical_and_faster_than_per_batch() {
        let stages = Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0));
        let cfg = PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 4,
            adaptive: None,
            ..Default::default()
        };
        let engine = PersistentEngine::new(Arc::clone(&stages), cfg).unwrap();
        let batches: Vec<Tensor> =
            (0..4).map(|i| input_off(4, 6, i as f32 * 10.0)).collect();
        // Submit everything before waiting: batches stream back-to-back.
        let handles: Vec<BatchHandle> =
            batches.iter().map(|b| engine.submit(b).unwrap()).collect();
        let runs: Vec<EngineRun> =
            handles.into_iter().map(|h| h.wait().unwrap()).collect();
        for (b, r) in batches.iter().zip(&runs) {
            let serial = run_serial(&*stages, b, 1).unwrap();
            assert_eq!(serial.output, r.output, "batch output diverged");
            for c in &r.stage_counters {
                assert_eq!(c.micro_batches, 4);
            }
        }
        // No inter-batch drain: the cross-batch makespan beats the sum of
        // independent per-batch streamed runs (each pays fill + drain).
        let cross = engine.makespan_ms();
        let mut per_batch = 0.0;
        let one_cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: 4 };
        for b in &batches {
            per_batch +=
                run_streamed(&*stages, b, &one_cfg).unwrap().timing.total_ms;
        }
        assert!(
            cross < per_batch,
            "cross-batch {cross:.2} ms must beat per-batch {per_batch:.2} ms"
        );
    }

    #[test]
    fn persistent_single_batch_matches_one_shot_schedule() {
        let t = input(6, 4);
        let one_shot = run_streamed(
            &SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0),
            &t,
            &EngineConfig { micro_batch_rows: 1, max_in_flight: 3 },
        )
        .unwrap();
        let engine = PersistentEngine::new(
            Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0)),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 3,
                adaptive: None,
                ..Default::default()
            },
        )
        .unwrap();
        let run = engine.run(&t).unwrap();
        // Same shared core, same credits: the first persistent batch must
        // reproduce the one-shot schedule exactly, in outputs and sim-ms.
        assert_eq!(run.output, one_shot.output);
        assert!(
            (run.timing.total_ms - one_shot.timing.total_ms).abs() < 1e-9,
            "persistent {} vs one-shot {}",
            run.timing.total_ms,
            one_shot.timing.total_ms
        );
        assert!(
            (run.timing.compute_ms - one_shot.timing.compute_ms).abs() < 1e-9
        );
        assert!((run.timing.comm_ms - one_shot.timing.comm_ms).abs() < 1e-9);
    }

    /// Fails at stage 1 whenever the activation carries the sentinel.
    struct FailOnMark;
    impl StageExec for FailOnMark {
        fn num_stages(&self) -> usize {
            2
        }
        fn node_id(&self, stage: usize) -> usize {
            stage
        }
        fn comm_in(&self, _stage: usize, _bytes: u64) -> f64 {
            0.0
        }
        fn comm_out(&self, _bytes: u64) -> f64 {
            0.0
        }
        fn execute(&self, stage: usize, input: Tensor) -> Result<(Tensor, f64)> {
            anyhow::ensure!(
                !(stage == 1 && input.data()[0] == 999.0),
                "sentinel failure"
            );
            Ok((input, 1.0))
        }
    }

    #[test]
    fn persistent_failure_isolated_to_its_batch() {
        let engine = PersistentEngine::new(
            Arc::new(FailOnMark),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 2,
                adaptive: None,
                ..Default::default()
            },
        )
        .unwrap();
        let good = Tensor::new(vec![2, 2], vec![1.0; 4]).unwrap();
        let bad = Tensor::new(vec![2, 2], vec![999.0; 4]).unwrap();
        let h1 = engine.submit(&good).unwrap();
        let h2 = engine.submit(&bad).unwrap();
        let h3 = engine.submit(&good).unwrap();
        let r1 = h1.wait().unwrap();
        assert_eq!(r1.output, good);
        let err = h2.wait().unwrap_err();
        assert!(
            format!("{err:#}").contains("stage 1"),
            "unexpected error: {err:#}"
        );
        // The failure drained without touching the following batch, and
        // counters stay consistent (every stage saw both micro-batches).
        let r3 = h3.wait().unwrap();
        assert_eq!(r3.output, good);
        for c in &r3.stage_counters {
            assert_eq!(c.micro_batches, 2, "stage {} counters", c.stage);
        }
        // Engine still serves after the failure.
        let r4 = engine.run(&good).unwrap();
        assert_eq!(r4.output, good);
    }

    #[test]
    fn queued_batch_reports_service_time_not_queueing() {
        // A wide window hands batch B a stale leftover credit (value 0)
        // while batch A still occupies the pipeline. B's total_ms must
        // measure B's own pass (from its stage-0 service start), not the
        // whole cross-batch makespan.
        let stages = Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0));
        let engine = PersistentEngine::new(
            Arc::clone(&stages),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 8,
                adaptive: None,
                ..Default::default()
            },
        )
        .unwrap();
        let a = input(4, 4);
        let b = input_off(1, 4, 5.0);
        let ha = engine.submit(&a).unwrap();
        let hb = engine.submit(&b).unwrap();
        let ra = ha.wait().unwrap();
        let rb = hb.wait().unwrap();
        assert_eq!(rb.output, run_serial(&*stages, &b, 1).unwrap().output);
        let makespan = engine.makespan_ms();
        assert!(
            rb.timing.total_ms < 0.9 * makespan,
            "queued batch total {:.2} ms should exclude queueing \
             (cross-batch makespan {makespan:.2} ms)",
            rb.timing.total_ms
        );
        assert!(
            rb.timing.total_ms < ra.timing.total_ms,
            "single-micro batch B ({:.2} ms) must report less service \
             time than 4-micro batch A ({:.2} ms)",
            rb.timing.total_ms,
            ra.timing.total_ms
        );
    }

    #[test]
    fn adaptive_depth_widens_until_bottleneck_saturates() {
        let stages = Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0));
        let engine = PersistentEngine::new(
            stages,
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 1,
                adaptive: Some(AdaptiveDepthConfig {
                    max_depth: 6,
                    ..AdaptiveDepthConfig::default()
                }),
                ..Default::default()
            },
        )
        .unwrap();
        let b = input(4, 4);
        let mut handles = Vec::new();
        for _ in 0..12 {
            handles.push(engine.submit(&b).unwrap());
        }
        for h in handles {
            h.wait().unwrap();
        }
        let report = engine.depth_report();
        assert_eq!(report.initial_depth, 1);
        assert!(report.widenings >= 1, "controller never widened: {report:?}");
        let depth = engine.current_depth();
        assert!(
            (2..=6).contains(&depth),
            "depth {depth} did not move off the serial window"
        );
    }

    #[test]
    fn adaptive_depth_ignores_arrival_gaps() {
        // Strictly sequential traffic (each batch waited before the next
        // is submitted): the idle time between batches is arrival
        // spacing, not credit starvation. With the window already wide
        // enough for a whole batch (4 > 3 chunks) the controller must
        // never ratchet it upward chasing those gaps — the entry-gap
        // exclusion means the observed bottleneck bubbles stay ~0.
        let stages = Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], 1.0));
        let engine = PersistentEngine::new(
            stages,
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 4,
                adaptive: Some(AdaptiveDepthConfig {
                    max_depth: 8,
                    ..AdaptiveDepthConfig::default()
                }),
                ..Default::default()
            },
        )
        .unwrap();
        let b = input(3, 4);
        for _ in 0..8 {
            engine.run(&b).unwrap();
        }
        let report = engine.depth_report();
        assert!(
            report.max_depth <= 4,
            "window ratcheted upward on arrival gaps: {report:?}"
        );
        assert!(report.final_depth >= 1 && report.final_depth <= 4);
    }

    #[test]
    fn adaptive_depth_works_with_single_chunk_batches() {
        // pipeline_depth = 1 + adaptive (the bare `--adaptive-depth`
        // serve configuration): every batch is exactly one micro-batch,
        // so there are no intra-batch bubbles at all. Back-to-back
        // submissions starve on credits at depth 1, and those starved
        // entry gaps must still widen the window.
        let stages = Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0));
        let engine = PersistentEngine::new(
            stages,
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 1,
                adaptive: Some(AdaptiveDepthConfig {
                    max_depth: 6,
                    ..AdaptiveDepthConfig::default()
                }),
                ..Default::default()
            },
        )
        .unwrap();
        let b = input(1, 4);
        let mut handles = Vec::new();
        for _ in 0..16 {
            handles.push(engine.submit(&b).unwrap());
        }
        for h in handles {
            h.wait().unwrap();
        }
        let report = engine.depth_report();
        assert!(
            report.widenings >= 1,
            "single-chunk adaptive serving never widened: {report:?}"
        );
        assert!(engine.current_depth() >= 2, "{report:?}");
    }

    #[test]
    fn adaptive_depth_widens_on_sequential_starved_batches() {
        // Solo batches can still carry genuine credit starvation: at
        // window 1 a 4-chunk batch serializes its own micro-batches, and
        // those intra-batch bubbles (entry gap excluded) must widen the
        // window even though the batches never overlap each other.
        let stages = Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0));
        let engine = PersistentEngine::new(
            stages,
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 1,
                adaptive: Some(AdaptiveDepthConfig {
                    max_depth: 6,
                    ..AdaptiveDepthConfig::default()
                }),
                ..Default::default()
            },
        )
        .unwrap();
        let b = input(4, 4);
        for _ in 0..8 {
            engine.run(&b).unwrap();
        }
        let report = engine.depth_report();
        assert!(
            report.widenings >= 1,
            "sequential starved batches must still widen: {report:?}"
        );
        assert!(engine.current_depth() >= 2, "{report:?}");
    }

    #[test]
    fn persistent_engine_rejects_bad_configs() {
        let stages = || Arc::new(SimStages::heterogeneous(&[1.0], 1.0));
        assert!(PersistentEngine::new(
            stages(),
            PersistentEngineConfig {
                micro_batch_rows: 0,
                initial_depth: 1,
                adaptive: None,
                ..Default::default()
            },
        )
        .is_err());
        assert!(PersistentEngine::new(
            stages(),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 0,
                adaptive: None,
                ..Default::default()
            },
        )
        .is_err());
        assert!(PersistentEngine::new(
            stages(),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 9,
                adaptive: Some(AdaptiveDepthConfig {
                    min_depth: 1,
                    max_depth: 8,
                    ..AdaptiveDepthConfig::default()
                }),
                ..Default::default()
            },
        )
        .is_err());
        // Inverted or non-finite bubble thresholds are rejected.
        assert!(PersistentEngine::new(
            stages(),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 1,
                adaptive: Some(AdaptiveDepthConfig {
                    widen_bubble_frac: 0.05,
                    narrow_bubble_frac: 0.20,
                    ..AdaptiveDepthConfig::default()
                }),
                ..Default::default()
            },
        )
        .is_err());
        assert!(PersistentEngine::new(
            stages(),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 1,
                adaptive: Some(AdaptiveDepthConfig {
                    widen_bubble_frac: f64::NAN,
                    ..AdaptiveDepthConfig::default()
                }),
                ..Default::default()
            },
        )
        .is_err());
        // Stage budgets must match the stage count, be >= 1, and sit
        // inside the adaptive range.
        assert!(PersistentEngine::new(
            stages(),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 1,
                stage_budgets: Some(vec![1, 2]),
                ..Default::default()
            },
        )
        .is_err());
        assert!(PersistentEngine::new(
            stages(),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 1,
                stage_budgets: Some(vec![0]),
                ..Default::default()
            },
        )
        .is_err());
        assert!(PersistentEngine::new(
            stages(),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 1,
                stage_budgets: Some(vec![9]),
                adaptive: Some(AdaptiveDepthConfig {
                    max_depth: 8,
                    ..AdaptiveDepthConfig::default()
                }),
                ..Default::default()
            },
        )
        .is_err());
    }

    #[test]
    fn chunks_for_rounds_up() {
        assert_eq!(chunks_for(1, 4), 1);
        assert_eq!(chunks_for(4, 4), 1);
        assert_eq!(chunks_for(5, 4), 2);
        assert_eq!(chunks_for(8, 4), 2);
        assert_eq!(chunks_for(0, 4), 0);
    }

    #[test]
    fn credit_windows_narrow_swallows_and_widen_cancels() {
        let (w, rxs) = CreditWindows::new(&[2, 1]);
        // Seeded credits are immediately available.
        assert!(rxs[0].try_recv().is_ok());
        assert!(rxs[0].try_recv().is_ok());
        assert!(rxs[0].try_recv().is_err());
        // Narrow: the next returned credit is absorbed, the one after
        // flows through.
        w.narrow(0);
        assert_eq!(w.budgets_snapshot(), vec![1, 1]);
        w.give(0, 0, 7.0);
        assert!(rxs[0].try_recv().is_err(), "swallowed credit leaked");
        w.give(0, 0, 9.0);
        assert_eq!(rxs[0].try_recv().unwrap(), 9.0);
        // Widen cancels a pending narrow instead of double-counting.
        w.narrow(1);
        w.widen(1, 3.0);
        assert_eq!(w.budgets_snapshot(), vec![1, 1]);
        assert!(rxs[1].try_recv().is_ok(), "seed credit");
        w.give(1, 0, 5.0);
        assert_eq!(
            rxs[1].try_recv().unwrap(),
            5.0,
            "cancelled narrow must not swallow the returned credit"
        );
        assert_eq!(w.delivery_budget(), 1);
    }

    #[test]
    fn replicated_credit_windows_slot_by_congruence_class() {
        // Stage 1 has two replicas: its micro-batches alternate between
        // two independent slots, each seeded with the stage budget.
        let (w, rxs) = CreditWindows::new_replicated(&[1, 1], &[1, 2]);
        assert_eq!(w.n(), 2, "n() counts stages, not slots");
        assert_eq!(rxs.len(), 3, "one receiver per slot");
        assert_eq!(w.slot_of(0, 5), 0);
        assert_eq!(w.slot_of(1, 4), 1);
        assert_eq!(w.slot_of(1, 5), 2);
        // Credits route by congruence class.
        assert!(rxs[1].try_recv().is_ok(), "seed");
        assert!(rxs[2].try_recv().is_ok(), "seed");
        w.give(1, 4, 7.0); // even idx -> replica slot 0
        assert_eq!(rxs[1].try_recv().unwrap(), 7.0);
        assert!(rxs[2].try_recv().is_err());
        // Stage-level resizes move every slot of the stage together.
        w.widen(1, 3.0);
        assert_eq!(rxs[1].try_recv().unwrap(), 3.0);
        assert_eq!(rxs[2].try_recv().unwrap(), 3.0);
        assert_eq!(w.budgets_snapshot(), vec![1, 2]);
        w.narrow(1);
        assert_eq!(w.budgets_snapshot(), vec![1, 1]);
        assert_eq!(w.delivery_budget(), 1);
    }

    #[test]
    fn replicated_stage_outputs_bit_identical_and_faster() {
        // Skewed chain: stage 1 is the 4x bottleneck. Replicating it
        // must leave outputs bit-identical (row-wise transform) while
        // cutting the cross-batch makespan.
        let shares = [1.0, 0.25, 1.0];
        let t = input(8, 4);
        let mk_engine = |reps: &[usize]| {
            PersistentEngine::new(
                Arc::new(SimStages::with_replicas(&shares, 1.0, reps)),
                PersistentEngineConfig {
                    micro_batch_rows: 1,
                    initial_depth: 4,
                    adaptive: None,
                    ..Default::default()
                },
            )
            .unwrap()
        };
        let base = mk_engine(&[1, 1, 1]);
        let r_base = base.run(&t).unwrap();
        let base_ms = base.makespan_ms();
        let fanout = mk_engine(&[1, 2, 1]);
        assert_eq!(fanout.replica_nodes()[1].len(), 2);
        let r_fan = fanout.run(&t).unwrap();
        let fan_ms = fanout.makespan_ms();
        assert_eq!(r_base.output, r_fan.output, "replication changed bits");
        assert!(
            fan_ms < base_ms,
            "k=2 on the bottleneck must beat k=1: {fan_ms:.2} vs \
             {base_ms:.2}"
        );
        // Both bottleneck replicas saw work.
        let rc = fanout.replica_counters();
        let lanes: Vec<_> = rc.iter().filter(|c| c.stage == 1).collect();
        assert_eq!(lanes.len(), 2);
        for lane in lanes {
            assert!(
                lane.micro_batches > 0,
                "replica {} of stage 1 idle",
                lane.replica
            );
        }
    }

    #[test]
    fn single_replica_engine_matches_unreplicated_constructor() {
        // k=1 degeneracy: an all-ones replica map must reproduce the
        // unreplicated engine bit-exactly — outputs and sim-ms both.
        let t = input(6, 4);
        let run_with = |stages: SimStages| {
            let engine = PersistentEngine::new(
                Arc::new(stages),
                PersistentEngineConfig {
                    micro_batch_rows: 1,
                    initial_depth: 3,
                    adaptive: None,
                    ..Default::default()
                },
            )
            .unwrap();
            let run = engine.run(&t).unwrap();
            (run, engine.makespan_ms())
        };
        let (plain, plain_ms) =
            run_with(SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0));
        let (mapped, mapped_ms) = run_with(SimStages::with_replicas(
            &[1.0, 0.6, 0.4],
            2.0,
            &[1, 1, 1],
        ));
        assert_eq!(plain.output, mapped.output);
        assert!((plain_ms - mapped_ms).abs() < 1e-9);
        assert!(
            (plain.timing.total_ms - mapped.timing.total_ms).abs() < 1e-9
        );
    }

    #[test]
    fn memory_pressure_narrows_window() {
        // A zero-byte pool budget is always exceeded once anything has
        // been recycled: the controller must narrow instead of widening,
        // even though the skewed chain shows bottleneck bubbles.
        let stages = Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0));
        let engine = PersistentEngine::new(
            stages,
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 4,
                adaptive: Some(AdaptiveDepthConfig {
                    max_depth: 8,
                    pool_bytes_budget: Some(0),
                    ..AdaptiveDepthConfig::default()
                }),
                ..Default::default()
            },
        )
        .unwrap();
        // Park at least one buffer in the global pool so pooled_bytes()
        // is non-zero regardless of what other tests drained.
        crate::util::pool::BufferPool::global().give(vec![0.0f32; 256]);
        let b = input(4, 4);
        let mut handles = Vec::new();
        for _ in 0..12 {
            handles.push(engine.submit(&b).unwrap());
        }
        for h in handles {
            h.wait().unwrap();
        }
        let report = engine.depth_report();
        assert!(
            report.narrowings >= 1,
            "memory pressure never narrowed: {report:?}"
        );
        assert!(
            report.max_depth <= 4,
            "widened under memory pressure: {report:?}"
        );
        assert!(engine.current_depth() < 4, "{report:?}");
    }

    #[test]
    fn slice_rows_extracts_member_ranges() {
        let t = input(4, 3);
        let head = slice_rows(&t, &(0..2)).unwrap();
        let tail = slice_rows(&t, &(2..4)).unwrap();
        assert_eq!(head.shape, vec![2, 3]);
        assert_eq!(concat_rows(&[head, tail]).unwrap(), t);
        assert!(slice_rows(&t, &(2..5)).is_err());
        assert!(slice_rows(&t, &(2..2)).is_err());
    }

    #[test]
    fn apportion_sums_to_total_and_tracks_weights() {
        assert_eq!(apportion(1, &[2, 2]).iter().sum::<u64>(), 1);
        assert_eq!(apportion(8, &[1, 3]), vec![2, 6]);
        assert_eq!(apportion(3, &[1, 1, 1, 1]).iter().sum::<u64>(), 3);
        assert_eq!(apportion(5, &[0, 0]), vec![0, 0]);
        assert_eq!(apportion(0, &[4, 4]), vec![0, 0]);
    }

    #[test]
    fn carry_and_profile_helpers_hold_invariants() {
        assert_eq!(carry_stage_budgets(&[2, 3, 5], 3), vec![2, 3, 5]);
        assert_eq!(*carry_stage_budgets(&[2, 3, 5], 7).last().unwrap(), 5);
        assert_eq!(carry_stage_budgets(&[4], 2), vec![4, 4]);
        // Endpoints survive aggressive shrinks: the learned admission
        // pacing (first) and delivery window (last) both carry.
        assert_eq!(carry_stage_budgets(&[1, 8, 8, 8], 2), vec![1, 8]);
        assert_eq!(carry_stage_budgets(&[1, 2, 8, 8], 1), vec![8]);
        let w = budgets_from_profile(&[1.0, 1.0, 1.0, 1.0, 4.0], 10);
        assert_eq!(w.iter().sum::<usize>(), 10);
        assert!(w.windows(2).all(|p| p[0] <= p[1]), "{w:?}");
        assert!(*w.last().unwrap() >= 3, "delivery window too shallow: {w:?}");
    }
}
