//! Streaming pipeline-parallel execution engine.
//!
//! `pipeline::run` walks one batch through the partition chain strictly
//! serially: stage *k+1* is idle while stage *k* computes, so a
//! heterogeneous cluster runs at the *sum* of its stage times. This
//! engine instead gives every deployment stage its own bounded work
//! queue and driver thread, splits an admitted batch into row-wise
//! micro-batches, and keeps up to `max_in_flight` micro-batches moving
//! through the chain at once — stage *k* computes micro-batch *i+1*
//! while stage *k+1* receives and computes micro-batch *i*. End-to-end
//! time drops from `Σ_k cost_k` per batch toward
//! `fill + n_micro · max_k cost_k` (the classic pipeline bound), which
//! is where AMP4EC's throughput multiple over serial execution comes
//! from.
//!
//! ## Micro-batch model
//!
//! A micro-batch is a contiguous slice of batch rows
//! ([`split_rows`]/[`concat_rows`]). Every model stage is row-wise
//! (per-sample inference), so streaming is **bit-identical** to serial
//! execution — pinned by tests and `benches/pipeline_engine.rs`. For a
//! real deployment the micro-batch row count must equal the batch the
//! stage artifacts were compiled for (`Deployment::batch`); the
//! router's admission batch is then `micro_batch · max_in_flight` rows
//! (see `DistributedService`).
//!
//! ## Sim-time model
//!
//! All engine accounting is in **simulated milliseconds** end-to-end via
//! the critical-path recurrence in [`super::timing::CriticalPath`]:
//! `ready[k] = max(ready[k-1] + comm, stage_free[k]) + compute`, with
//! leader admission gated by a credit window — micro-batch *i* enters
//! stage 0 at the simulated time micro-batch *i − max_in_flight* was
//! delivered (window 1 therefore reproduces the serial schedule
//! exactly). Wall clock still elapses the same way (nodes sleep out
//! their dilated compute, links sleep out transfers, the feeder waits
//! for delivery credits) so wall-time measurements agree with the
//! simulated makespan, but the *reported* numbers never mix host
//! wall-clock into simulated totals. Per-stage occupancy and bubble
//! (idle-gap) time are exported as [`StageCounter`]s for the metrics
//! layer.
//!
//! ## Persistent cross-batch streaming
//!
//! [`run_streamed`] tears its stage drivers down when its one batch
//! drains, so successive batches each pay a fill+drain bubble of
//! ~(stages − 1) micro-batch slots plus thread spawn/join.
//! [`PersistentEngine`] promotes the same drivers into long-lived
//! threads: per-stage bounded queues and the critical-path clock live
//! for the whole serve run, micro-batches from *successive* batches are
//! tagged `(batch, idx)` and flow back-to-back with no inter-batch
//! drain, and per-batch outputs are reassembled by sequence-numbered
//! completion tracking in the collector. The `ready[k]` recurrence and
//! shared-node serialization carry across batch boundaries unchanged —
//! stage `free` times simply keep advancing — so the accounting stays
//! device-honest while the drain bubbles disappear. Both entry points
//! share one driver/feeder/collector core, so the one-shot and
//! persistent schedules can never diverge.
//!
//! On top of the persistent credits sits an optional **adaptive depth
//! controller** ([`AdaptiveDepthConfig`]): per completed batch it reads
//! the bottleneck stage's bubble fraction from the batch-local
//! [`StageCounter`]s and widens the credit window while bubbles remain
//! (adding a credit), or narrows it after consecutive bubble-free
//! batches (swallowing a returned credit) — converging to the smallest
//! `max_in_flight` that saturates the bottleneck stage. To tell window
//! pressure from mere arrival spacing, the feeder marks a batch
//! *credit-starved* when it held one of its micro-batches while the
//! credit window was empty: starved batches are observed with their
//! full bubbles (entry gaps included — the window itself delayed them,
//! the only signal a single-chunk batch can produce), while un-starved
//! batches have each stage's entry gap excluded, so light sequential
//! traffic never ratchets the window toward the maximum.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::timing::{CriticalPath, PipelineTiming, StageTiming};
use crate::cluster::{NodeSpec, SimParams, VirtualNode};
use crate::deployer::Deployment;
use crate::metrics::StageCounter;
use crate::runtime::Tensor;

/// Streaming engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Rows per micro-batch. For a real [`Deployment`] this must equal
    /// the compiled artifact batch (`Deployment::batch`).
    pub micro_batch_rows: usize,
    /// Admission window: micro-batches allowed between leader admission
    /// and leader delivery at once (credit-based), and the bound on each
    /// stage's queue. 1 degenerates to the serial schedule; larger
    /// windows overlap more stages. Modeled in both wall clock (the
    /// feeder waits for a delivery credit) and the simulated critical
    /// path (an admitted micro-batch's clock starts at the sim time its
    /// window slot freed).
    pub max_in_flight: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { micro_batch_rows: 1, max_in_flight: 4 }
    }
}

/// What one engine traversal produces.
pub struct EngineRun {
    pub output: Tensor,
    /// Simulated critical-path timing (totals plus per-stage split).
    pub timing: PipelineTiming,
    /// Per-stage occupancy/bubble counters for the metrics layer.
    pub stage_counters: Vec<StageCounter>,
}

/// A chain of pipeline stages the engine can drive. Implemented by
/// [`DeploymentStages`] (real deployed partitions) and [`SimStages`]
/// (virtual nodes with synthetic compute, for benches and tests — no
/// PJRT artifacts needed).
///
/// `execute` blocks for the stage's simulated duration (each virtual
/// node serializes its own device), and the comm methods sleep out the
/// link model — wall time tracks sim time, while the engine separately
/// accounts sim-ms via the critical path.
pub trait StageExec: Sync {
    fn num_stages(&self) -> usize;

    /// Id of the node hosting `stage` (for accounting).
    fn node_id(&self, stage: usize) -> usize;

    /// Move `bytes` of activation into `stage` (from the leader for
    /// stage 0, from stage `k-1`'s node otherwise). Returns simulated ms.
    fn comm_in(&self, stage: usize, bytes: u64) -> f64;

    /// Final hop: last stage's node back to the leader. Simulated ms.
    fn comm_out(&self, bytes: u64) -> f64;

    /// Run one micro-batch on `stage`. Returns the output activation and
    /// the simulated compute ms.
    fn execute(&self, stage: usize, input: Tensor) -> Result<(Tensor, f64)>;
}

/// Shared link model for node-hosted stage chains: the leader is a
/// zero-latency infinite-bandwidth endpoint, so a transfer charges the
/// upstream node's send (when there is one) plus the downstream node's
/// receive. Both [`DeploymentStages`] and [`SimStages`] route through
/// these so the synthetic model used by benches/tests can never
/// silently diverge from the real deployment path.
fn node_comm_in(prev: Option<&VirtualNode>, to: &VirtualNode, bytes: u64) -> f64 {
    let mut ms = 0.0;
    if let Some(p) = prev {
        ms += p.link().send(bytes);
    }
    ms + to.link().receive(bytes)
}

fn node_comm_out(last: Option<&VirtualNode>, bytes: u64) -> f64 {
    match last {
        Some(n) => n.link().send(bytes),
        None => 0.0,
    }
}

/// [`StageExec`] over a live [`Deployment`]: real executors on virtual
/// nodes, identical per-stage semantics to `pipeline::run`. Generic
/// over how the deployment is held: `DeploymentStages<&Deployment>`
/// borrows for a one-shot traversal, while
/// `DeploymentStages<Arc<Deployment>>` owns a reference so a
/// [`PersistentEngine`]'s long-lived driver threads can keep executing
/// against it.
pub struct DeploymentStages<D: std::ops::Deref<Target = Deployment>> {
    dep: D,
}

impl<D: std::ops::Deref<Target = Deployment>> DeploymentStages<D> {
    pub fn new(dep: D) -> DeploymentStages<D> {
        DeploymentStages { dep }
    }
}

impl<D: std::ops::Deref<Target = Deployment> + Sync> StageExec for DeploymentStages<D> {
    fn num_stages(&self) -> usize {
        self.dep.stages.len()
    }

    fn node_id(&self, stage: usize) -> usize {
        self.dep.stages[stage].node.id()
    }

    fn comm_in(&self, stage: usize, bytes: u64) -> f64 {
        let prev = stage
            .checked_sub(1)
            .map(|p| &*self.dep.stages[p].node);
        node_comm_in(prev, &self.dep.stages[stage].node, bytes)
    }

    fn comm_out(&self, bytes: u64) -> f64 {
        node_comm_out(self.dep.stages.last().map(|s| &*s.node), bytes)
    }

    fn execute(&self, stage: usize, input: Tensor) -> Result<(Tensor, f64)> {
        let st = &self.dep.stages[stage];
        let executor = Arc::clone(&st.executor);
        let blocks = st.blocks.clone();
        let (out, outcome) = st
            .node
            .execute_costed(move || executor.run_chain(blocks, input))?;
        Ok((out, outcome.sim_ms))
    }
}

/// Synthetic [`StageExec`]: each stage applies a fixed row-wise
/// elementwise transform with a fixed nominal compute cost on its
/// virtual node (CPU-quota dilation applies). Lets the engine be
/// exercised, tested, and benchmarked without compiled artifacts.
pub struct SimStages {
    nodes: Vec<Arc<VirtualNode>>,
    nominal_ms: f64,
}

impl SimStages {
    pub fn new(nodes: Vec<Arc<VirtualNode>>, nominal_ms: f64) -> SimStages {
        SimStages { nodes, nominal_ms }
    }

    /// One stage per CPU share (e.g. `&[1.0, 0.6, 0.4]` — the paper's
    /// heterogeneous cluster), default LAN links, no paging.
    pub fn heterogeneous(cpu_shares: &[f64], nominal_ms: f64) -> SimStages {
        let params = SimParams {
            time_scale: 1.0,
            page_factor: 4.0,
            runtime_overhead_mb: 0.0,
        };
        let nodes = cpu_shares
            .iter()
            .enumerate()
            .map(|(i, &cpu)| {
                Arc::new(VirtualNode::new(
                    i,
                    NodeSpec::new(&format!("sim-{i}"), cpu, 1024.0),
                    params.clone(),
                ))
            })
            .collect();
        SimStages::new(nodes, nominal_ms)
    }

    pub fn nodes(&self) -> &[Arc<VirtualNode>] {
        &self.nodes
    }
}

impl StageExec for SimStages {
    fn num_stages(&self) -> usize {
        self.nodes.len()
    }

    fn node_id(&self, stage: usize) -> usize {
        self.nodes[stage].id()
    }

    fn comm_in(&self, stage: usize, bytes: u64) -> f64 {
        let prev = stage.checked_sub(1).map(|p| &*self.nodes[p]);
        node_comm_in(prev, &self.nodes[stage], bytes)
    }

    fn comm_out(&self, bytes: u64) -> f64 {
        node_comm_out(self.nodes.last().map(|n| &**n), bytes)
    }

    fn execute(&self, stage: usize, input: Tensor) -> Result<(Tensor, f64)> {
        let nominal = self.nominal_ms;
        let (out, outcome) = self.nodes[stage].execute_costed(move || {
            // Row-wise elementwise transform: bit-identical under any
            // micro-batch split.
            let data = input.data.iter().map(|v| v * 1.5 + 0.25).collect();
            let t = Tensor::new(input.shape.clone(), data)?;
            Ok((t, nominal))
        })?;
        Ok((out, outcome.sim_ms))
    }
}

/// Split a `[rows, ...]` tensor into row-contiguous chunks of up to
/// `chunk_rows` rows (the last chunk may be short).
pub fn split_rows(t: &Tensor, chunk_rows: usize) -> Result<Vec<Tensor>> {
    anyhow::ensure!(!t.shape.is_empty(), "cannot split a scalar tensor");
    anyhow::ensure!(chunk_rows > 0, "chunk_rows must be > 0");
    let rows = t.shape[0];
    anyhow::ensure!(rows > 0, "empty batch");
    let row_len: usize = t.shape.iter().skip(1).product();
    let mut out = Vec::with_capacity((rows + chunk_rows - 1) / chunk_rows);
    let mut r = 0;
    while r < rows {
        let take = chunk_rows.min(rows - r);
        let mut shape = t.shape.clone();
        shape[0] = take;
        out.push(Tensor::new(
            shape,
            t.data[r * row_len..(r + take) * row_len].to_vec(),
        )?);
        r += take;
    }
    Ok(out)
}

/// Reassemble chunks produced by [`split_rows`] (in order).
pub fn concat_rows(chunks: &[Tensor]) -> Result<Tensor> {
    anyhow::ensure!(!chunks.is_empty(), "no chunks to concatenate");
    let tail: &[usize] = &chunks[0].shape[1..];
    let mut rows = 0;
    let mut data = Vec::new();
    for c in chunks {
        anyhow::ensure!(
            !c.shape.is_empty() && &c.shape[1..] == tail,
            "mismatched chunk shapes"
        );
        rows += c.shape[0];
        data.extend_from_slice(&c.data);
    }
    let mut shape = chunks[0].shape.clone();
    shape[0] = rows;
    Tensor::new(shape, data)
}

// ---------------------------------------------------------------------------
// Shared streaming core: one driver/feeder/collector implementation used by
// both the one-shot `run_streamed` (scoped threads, single batch) and the
// `PersistentEngine` (long-lived threads, batches tagged and interleaved).
// ---------------------------------------------------------------------------

/// One micro-batch moving through the stage queues. `batch` tags which
/// admitted batch the rows belong to (always 0 for one-shot runs);
/// `ready_ms` is the simulated time it left the previous stage.
struct PMsg {
    batch: u64,
    idx: usize,
    ready_ms: f64,
    tensor: Tensor,
}

/// What flows through a stage queue: a live micro-batch or a failure
/// being forwarded to the collector so its batch can complete (and its
/// window credit return) without dropping messages.
enum PFlow {
    Item(PMsg),
    Failed { batch: u64, error: anyhow::Error },
}

/// Per-batch completion tracking: outputs keyed by micro-batch sequence
/// number plus batch-local timing/counter aggregation. The critical-path
/// lanes accumulate across batches; these aggregates carry the per-batch
/// attribution (step deltas) so each batch reports its own timing.
struct BatchAgg {
    outs: Vec<Option<Tensor>>,
    remaining: usize,
    /// Simulated time the batch began *service*: its first micro-batch's
    /// stage-0 compute start minus that step's ingress comm, set by the
    /// stage-0 driver. Batch `total_ms` is measured from here, so a
    /// batch queued behind earlier batches (e.g. admitted on a stale
    /// leftover credit) reports its own pipeline time, not the queueing
    /// time in front of it. For the first batch this is exactly 0.
    t0_ms: f64,
    last_deliver_ms: f64,
    bytes: u64,
    final_comm_ms: f64,
    counters: Vec<StageCounter>,
    /// Per-stage bubble booked by the batch's *first* micro-batch — the
    /// entry gap since the previous batch left that stage. When the
    /// batch's admission was *not* credit-starved the adaptive
    /// controller subtracts it before observing: an arrival gap is not
    /// credit starvation, and no window width can remove it. Reported
    /// counters keep the full bubble (the stage really was idle).
    lead_bubble_ms: Vec<f64>,
    /// True when the feeder had one of this batch's micro-batches in
    /// hand but found the credit window empty — the window itself
    /// delayed admission. For such batches entry gaps *are* starvation
    /// (the only widening signal a single-chunk batch can produce).
    credit_starved: bool,
    error: Option<anyhow::Error>,
    reply: Sender<Result<EngineRun>>,
}

/// State shared by drivers, feeder, and collector: the persistent
/// critical-path clock plus the in-flight batch table.
struct EngineState {
    cp: CriticalPath,
    node_ids: Vec<usize>,
    batches: HashMap<u64, BatchAgg>,
}

impl EngineState {
    fn new(node_ids: &[usize]) -> EngineState {
        EngineState {
            cp: CriticalPath::new(node_ids),
            node_ids: node_ids.to_vec(),
            batches: HashMap::new(),
        }
    }

    /// Register a batch before any of its micro-batches are fed, so
    /// drivers can attribute steps from the first one onward.
    fn register(
        &mut self,
        id: u64,
        n_chunks: usize,
        reply: Sender<Result<EngineRun>>,
    ) {
        let counters = self
            .node_ids
            .iter()
            .enumerate()
            .map(|(k, &node)| StageCounter { stage: k, node, ..StageCounter::default() })
            .collect();
        self.batches.insert(
            id,
            BatchAgg {
                outs: (0..n_chunks).map(|_| None).collect(),
                remaining: n_chunks,
                t0_ms: 0.0,
                last_deliver_ms: 0.0,
                bytes: 0,
                final_comm_ms: 0.0,
                counters,
                lead_bubble_ms: vec![0.0; self.node_ids.len()],
                credit_starved: false,
                error: None,
                reply,
            },
        );
    }
}

/// Poison-tolerant state lock: a panicking stage (a bug in a `StageExec`
/// implementation) must degrade to failed batches, not wedge every other
/// driver — and ultimately every `BatchHandle::wait` — behind a poisoned
/// mutex. Sim accounting after a panic is best-effort by design.
fn lock_state(state: &Mutex<EngineState>) -> std::sync::MutexGuard<'_, EngineState> {
    state.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Stage driver loop: receive, transfer in, execute, account one step on
/// the shared clock, forward. Failures are forwarded (never dropped) so
/// the collector's per-batch completion count stays exact.
fn drive_stage<S: StageExec + ?Sized>(
    stages: &S,
    k: usize,
    rx: Receiver<PFlow>,
    tx: SyncSender<PFlow>,
    state: &Mutex<EngineState>,
) {
    while let Ok(flow) = rx.recv() {
        let next = match flow {
            PFlow::Failed { batch, error } => PFlow::Failed { batch, error },
            PFlow::Item(m) => {
                let bytes = m.tensor.byte_len();
                let comm_ms = stages.comm_in(k, bytes);
                match stages.execute(k, m.tensor) {
                    Ok((out, compute_ms)) => {
                        let mut st = lock_state(state);
                        let d = st.cp.step_detail(
                            k, m.ready_ms, comm_ms, compute_ms, bytes,
                        );
                        if let Some(agg) = st.batches.get_mut(&m.batch) {
                            if m.idx == 0 {
                                if k == 0 {
                                    // Service start: when stage 0
                                    // actually began this batch (comm
                                    // backed out so a fresh pipeline
                                    // reports t0 = 0). Always >= the
                                    // admission credit, and > it when the
                                    // batch queued behind earlier work.
                                    agg.t0_ms = d.start_ms - comm_ms;
                                }
                                // Entry gap at this stage (see
                                // BatchAgg::lead_bubble_ms).
                                agg.lead_bubble_ms[k] = d.bubble_ms;
                            }
                            let c = &mut agg.counters[k];
                            c.busy_ms += compute_ms;
                            c.comm_ms += comm_ms;
                            c.bubble_ms += d.bubble_ms;
                            c.micro_batches += 1;
                            agg.bytes += bytes;
                        }
                        drop(st);
                        PFlow::Item(PMsg {
                            batch: m.batch,
                            idx: m.idx,
                            ready_ms: d.done_ms,
                            tensor: out,
                        })
                    }
                    Err(e) => PFlow::Failed {
                        batch: m.batch,
                        error: e.context(format!(
                            "pipeline stage {k}, micro-batch {}",
                            m.idx
                        )),
                    },
                }
            }
        };
        if tx.send(next).is_err() {
            break; // downstream gone
        }
    }
    // rx disconnected: upstream finished; dropping tx cascades shutdown
    // to the next stage.
}

/// Feed one batch's micro-batches into stage 0, spending one window
/// credit each; the credit's value is the simulated time the slot freed,
/// which becomes the admitted micro-batch's clock start. An admission
/// that finds the credit channel empty marks the batch credit-starved
/// (work was ready; the window held it back) — the signal that lets the
/// depth controller tell window pressure from mere arrival spacing.
/// Returns false when the engine is tearing down.
fn feed_batch(
    id: u64,
    chunks: Vec<Tensor>,
    credit_rx: &Receiver<f64>,
    feed_tx: &SyncSender<PFlow>,
    state: &Mutex<EngineState>,
) -> bool {
    for (idx, tensor) in chunks.into_iter().enumerate() {
        let ready_ms = match credit_rx.try_recv() {
            Ok(t) => t,
            Err(std::sync::mpsc::TryRecvError::Empty) => {
                if let Some(agg) = lock_state(state).batches.get_mut(&id) {
                    agg.credit_starved = true;
                }
                match credit_rx.recv() {
                    Ok(t) => t,
                    Err(_) => return false, // collector gone
                }
            }
            Err(std::sync::mpsc::TryRecvError::Disconnected) => return false,
        };
        if feed_tx
            .send(PFlow::Item(PMsg { batch: id, idx, ready_ms, tensor }))
            .is_err()
        {
            return false;
        }
    }
    true
}

/// Collector loop: every admitted micro-batch yields exactly one
/// terminal message (delivered output or forwarded failure); each
/// terminal returns its window credit (unless the depth controller is
/// narrowing) and decrements its batch's completion count. A batch whose
/// count reaches zero is finalized and its result sent to the waiter.
fn collect_loop<S: StageExec + ?Sized>(
    stages: &S,
    rx: Receiver<PFlow>,
    credit_tx: Sender<f64>,
    state: &Mutex<EngineState>,
    ctrl: &mut DepthCtrl,
) {
    // Armed for the whole loop: when the collector exits — orderly
    // shutdown, a driver panic's channel cascade, or a panic on this
    // very thread (e.g. a buggy `comm_out`) — any batch stranded
    // mid-flight is dropped so its reply sender closes and
    // `BatchHandle::wait` reports shutdown instead of hanging forever.
    // On an orderly shutdown every accepted batch has already
    // finalized, so this is a no-op.
    struct StrandedBatchGuard<'a>(&'a Mutex<EngineState>);
    impl Drop for StrandedBatchGuard<'_> {
        fn drop(&mut self) {
            lock_state(self.0).batches.clear();
        }
    }
    let _stranded = StrandedBatchGuard(state);

    while let Ok(flow) = rx.recv() {
        match flow {
            PFlow::Item(m) => {
                let bytes = m.tensor.byte_len();
                let hop = stages.comm_out(bytes);
                let mut st = lock_state(state);
                let done = st.cp.deliver(hop, bytes, m.ready_ms);
                let mut finished = None;
                if let Some(agg) = st.batches.get_mut(&m.batch) {
                    agg.bytes += bytes;
                    agg.final_comm_ms += hop;
                    agg.last_deliver_ms = agg.last_deliver_ms.max(done);
                    agg.outs[m.idx] = Some(m.tensor);
                    agg.remaining -= 1;
                    if agg.remaining == 0 {
                        finished = Some(m.batch);
                    }
                }
                let completed =
                    finished.and_then(|id| st.batches.remove(&id));
                drop(st);
                ctrl.credit(&credit_tx, done);
                if let Some(agg) = completed {
                    // Build the controller's view only when a controller
                    // exists — the fixed-window and one-shot paths skip
                    // the per-batch allocation. Batches that carried a
                    // failure are never observed: their dead micro-batches
                    // open gaps that read as starvation but are failure
                    // noise, not a window signal. For batches whose
                    // admission was never credit-starved, the observed
                    // counters exclude each stage's entry gap (the idle
                    // time before the batch's first micro-batch arrived):
                    // that is request-arrival spacing, which no window
                    // width can remove. A credit-starved batch keeps its
                    // entry gaps — the window itself delayed it, which is
                    // exactly the widening signal (and the only one a
                    // single-chunk batch can produce).
                    let observed = (ctrl.is_adaptive() && agg.error.is_none())
                        .then(|| {
                            if agg.credit_starved {
                                agg.counters.clone()
                            } else {
                                agg.counters
                                    .iter()
                                    .zip(&agg.lead_bubble_ms)
                                    .map(|(c, lead)| StageCounter {
                                        bubble_ms: (c.bubble_ms - lead)
                                            .max(0.0),
                                        ..c.clone()
                                    })
                                    .collect::<Vec<_>>()
                            }
                        });
                    finalize_batch(agg);
                    if let Some(counters) = observed {
                        ctrl.observe_batch(&counters, &credit_tx, state);
                    }
                }
            }
            PFlow::Failed { batch, error } => {
                let mut st = lock_state(state);
                let credit_val = st.cp.makespan_ms();
                let mut finished = None;
                if let Some(agg) = st.batches.get_mut(&batch) {
                    if agg.error.is_none() {
                        agg.error = Some(error);
                    }
                    agg.remaining -= 1;
                    if agg.remaining == 0 {
                        finished = Some(batch);
                    }
                }
                let completed =
                    finished.and_then(|id| st.batches.remove(&id));
                drop(st);
                ctrl.credit(&credit_tx, credit_val);
                if let Some(agg) = completed {
                    finalize_batch(agg);
                }
            }
        }
    }
    // `_stranded` drops here (and on unwind), failing any unfinalized
    // batches.
}

/// Assemble a completed batch's [`EngineRun`] from its aggregates and
/// send it to the waiter. Timing is batch-local: `total_ms` runs from
/// the batch's first admission to its last delivery, compute/comm are
/// the batch's own sums.
fn finalize_batch(agg: BatchAgg) {
    let BatchAgg {
        outs,
        t0_ms,
        last_deliver_ms,
        bytes,
        final_comm_ms,
        counters,
        error,
        reply,
        ..
    } = agg;
    let result = match error {
        Some(e) => Err(e),
        None => (|| {
            let collected: Vec<Tensor> = outs
                .into_iter()
                .map(|o| {
                    o.ok_or_else(|| {
                        anyhow::anyhow!("pipeline dropped a micro-batch")
                    })
                })
                .collect::<Result<_>>()?;
            let output = concat_rows(&collected)?;
            let compute_ms: f64 = counters.iter().map(|c| c.busy_ms).sum();
            let stage_comm_ms: f64 = counters.iter().map(|c| c.comm_ms).sum();
            let timing = PipelineTiming {
                total_ms: last_deliver_ms - t0_ms,
                compute_ms,
                comm_ms: stage_comm_ms + final_comm_ms,
                stages: counters
                    .iter()
                    .map(|c| StageTiming {
                        stage: c.stage,
                        node: c.node,
                        compute_ms: c.busy_ms,
                        comm_ms: c.comm_ms,
                    })
                    .collect(),
                activation_bytes: bytes,
            };
            Ok(EngineRun { output, timing, stage_counters: counters })
        })(),
    };
    let _ = reply.send(result);
}

/// Live depth bookkeeping shared between the controller (collector
/// thread) and [`PersistentEngine`] accessors.
#[derive(Debug)]
struct DepthStats {
    initial: usize,
    current: AtomicUsize,
    min_seen: AtomicUsize,
    max_seen: AtomicUsize,
    widenings: AtomicU64,
    narrowings: AtomicU64,
}

impl DepthStats {
    fn new(initial: usize) -> DepthStats {
        DepthStats {
            initial,
            current: AtomicUsize::new(initial),
            min_seen: AtomicUsize::new(initial),
            max_seen: AtomicUsize::new(initial),
            widenings: AtomicU64::new(0),
            narrowings: AtomicU64::new(0),
        }
    }

    fn set_depth(&self, d: usize) {
        self.current.store(d, Ordering::SeqCst);
        self.min_seen.fetch_min(d, Ordering::SeqCst);
        self.max_seen.fetch_max(d, Ordering::SeqCst);
    }

    fn report(&self) -> DepthReport {
        DepthReport {
            initial_depth: self.initial,
            final_depth: self.current.load(Ordering::SeqCst),
            min_depth: self.min_seen.load(Ordering::SeqCst),
            max_depth: self.max_seen.load(Ordering::SeqCst),
            widenings: self.widenings.load(Ordering::SeqCst),
            narrowings: self.narrowings.load(Ordering::SeqCst),
        }
    }
}

/// The adaptive depth controller, run inline on the collector thread.
/// Widening injects an extra credit (valued at the current makespan so
/// the new slot's clock starts "now"); narrowing swallows the next
/// returned credit. Without an [`AdaptiveDepthConfig`] it only relays
/// credits — the fixed-window behaviour.
struct DepthCtrl {
    cfg: Option<AdaptiveDepthConfig>,
    swallow: usize,
    cooldown: u32,
    clean_batches: u32,
    stats: Arc<DepthStats>,
}

impl DepthCtrl {
    fn new(cfg: Option<AdaptiveDepthConfig>, stats: Arc<DepthStats>) -> DepthCtrl {
        DepthCtrl { cfg, swallow: 0, cooldown: 0, clean_batches: 0, stats }
    }

    /// Whether completed batches are worth observing at all.
    fn is_adaptive(&self) -> bool {
        self.cfg.is_some()
    }

    /// Return a window credit, unless a pending narrowing absorbs it.
    fn credit(&mut self, credit_tx: &Sender<f64>, value: f64) {
        if self.swallow > 0 {
            self.swallow -= 1;
            return;
        }
        let _ = credit_tx.send(value);
    }

    /// Per completed batch: widen while the bottleneck stage shows
    /// bubbles, narrow after consecutive bubble-free batches. Hysteresis
    /// plus a cooldown keeps the window within one step of the smallest
    /// saturating depth.
    fn observe_batch(
        &mut self,
        counters: &[StageCounter],
        credit_tx: &Sender<f64>,
        state: &Mutex<EngineState>,
    ) {
        let Some(cfg) = self.cfg else { return };
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return;
        }
        let Some(bottleneck) = counters
            .iter()
            .max_by(|a, b| a.busy_ms.total_cmp(&b.busy_ms))
        else {
            return;
        };
        if bottleneck.busy_ms + bottleneck.bubble_ms <= 0.0 {
            return;
        }
        let frac = bottleneck.bubble_fraction();
        let depth = self.stats.current.load(Ordering::SeqCst);
        if frac > cfg.widen_bubble_frac && depth < cfg.max_depth {
            let now = lock_state(state).cp.makespan_ms();
            let _ = credit_tx.send(now);
            self.stats.set_depth(depth + 1);
            self.stats.widenings.fetch_add(1, Ordering::SeqCst);
            self.cooldown = cfg.cooldown_batches;
            self.clean_batches = 0;
        } else if frac < cfg.narrow_bubble_frac && depth > cfg.min_depth {
            self.clean_batches += 1;
            if self.clean_batches >= 2 {
                self.swallow += 1;
                self.stats.set_depth(depth - 1);
                self.stats.narrowings.fetch_add(1, Ordering::SeqCst);
                self.cooldown = cfg.cooldown_batches;
                self.clean_batches = 0;
            }
        } else {
            self.clean_batches = 0;
        }
    }
}

/// Serial comparator with identical accounting: every micro-batch runs
/// through all stages before the next one starts (chunk-major order).
/// With a single chunk this is exactly `pipeline::run`'s schedule —
/// `pipeline::run` delegates here.
pub fn run_serial<S: StageExec + ?Sized>(
    stages: &S,
    input: &Tensor,
    micro_batch_rows: usize,
) -> Result<EngineRun> {
    let n_stages = stages.num_stages();
    anyhow::ensure!(n_stages > 0, "engine needs >= 1 stage");
    let chunks = split_rows(input, micro_batch_rows)?;
    let node_ids: Vec<usize> = (0..n_stages).map(|k| stages.node_id(k)).collect();
    let mut cp = CriticalPath::new(&node_ids);
    let mut outs = Vec::with_capacity(chunks.len());
    // Serial schedule: chunk i may only enter stage 0 after chunk i-1 is
    // delivered, so `ready` carries across chunks.
    let mut prev_done = 0.0;
    for (idx, chunk) in chunks.into_iter().enumerate() {
        let mut act = chunk;
        let mut ready = prev_done;
        for k in 0..n_stages {
            let bytes = act.byte_len();
            let comm_ms = stages.comm_in(k, bytes);
            let (out, compute_ms) = stages
                .execute(k, act)
                .with_context(|| format!("pipeline stage {k}, micro-batch {idx}"))?;
            ready = cp.step(k, ready, comm_ms, compute_ms, bytes);
            act = out;
        }
        let out_bytes = act.byte_len();
        let hop = stages.comm_out(out_bytes);
        prev_done = cp.deliver(hop, out_bytes, ready);
        outs.push(act);
    }
    Ok(EngineRun {
        output: concat_rows(&outs)?,
        timing: cp.timing(),
        stage_counters: cp.counters(),
    })
}

/// Streamed execution: split `input` into micro-batches and drive them
/// through per-stage bounded queues with one driver thread per stage, up
/// to `cfg.max_in_flight` micro-batches in flight. Output rows are
/// reassembled in request order and are bit-identical to [`run_serial`].
///
/// One-shot wrapper over the shared streaming core: scoped driver
/// threads live for exactly one batch. For back-to-back batches use
/// [`PersistentEngine`], which keeps the same drivers (and the
/// critical-path clock) alive across batches.
pub fn run_streamed<S: StageExec + ?Sized>(
    stages: &S,
    input: &Tensor,
    cfg: &EngineConfig,
) -> Result<EngineRun> {
    let n_stages = stages.num_stages();
    anyhow::ensure!(n_stages > 0, "engine needs >= 1 stage");
    anyhow::ensure!(cfg.max_in_flight > 0, "max_in_flight must be > 0");
    let chunks = split_rows(input, cfg.micro_batch_rows)?;
    let node_ids: Vec<usize> = (0..n_stages).map(|k| stages.node_id(k)).collect();

    let (reply_tx, reply_rx) = channel::<Result<EngineRun>>();
    let state = Mutex::new(EngineState::new(&node_ids));
    lock_state(&state).register(0, chunks.len(), reply_tx);

    // Channel k feeds stage k; channel n_stages is the collector. The
    // global in-flight limit is the credit window below; the bounded
    // queues add per-stage back-pressure so a stalled stage blocks its
    // upstream driver instead of buffering unboundedly.
    let mut senders = Vec::with_capacity(n_stages + 1);
    let mut receivers = Vec::with_capacity(n_stages + 1);
    for _ in 0..=n_stages {
        let (tx, rx) = sync_channel::<PFlow>(cfg.max_in_flight);
        senders.push(tx);
        receivers.push(rx);
    }
    let mut senders = senders.into_iter();
    let mut receivers = receivers.into_iter();
    let feed_tx = senders.next().expect("feeder sender");

    // Credit-based admission window: the feeder spends one credit per
    // admitted micro-batch; the collector returns a credit (carrying the
    // simulated time the slot freed) per delivery. This is what makes
    // `max_in_flight` real in *both* clocks — the feeder's wall-clock
    // wait and the admitted micro-batch's simulated start time. A
    // window of 1 degenerates to the serial schedule.
    let (credit_tx, credit_rx) = channel::<f64>();
    for _ in 0..cfg.max_in_flight {
        let _ = credit_tx.send(0.0);
    }

    std::thread::scope(|scope| {
        // One driver thread per stage.
        for k in 0..n_stages {
            let rx: Receiver<PFlow> = receivers.next().expect("stage receiver");
            let tx: SyncSender<PFlow> = senders.next().expect("stage sender");
            let state = &state;
            scope.spawn(move || drive_stage(stages, k, rx, tx, state));
        }

        // Feeder: micro-batches are admitted as window credits free up.
        {
            let state = &state;
            scope.spawn(move || {
                feed_batch(0, chunks, &credit_rx, &feed_tx, state);
            });
        }

        // Collector runs inline; it exits when the last driver drops its
        // sender (after the feeder finished and the queues drained).
        let collect_rx = receivers.next().expect("collector receiver");
        let mut ctrl =
            DepthCtrl::new(None, Arc::new(DepthStats::new(cfg.max_in_flight)));
        collect_loop(stages, collect_rx, credit_tx, &state, &mut ctrl);
    });

    match reply_rx.try_recv() {
        Ok(result) => result,
        Err(_) => Err(anyhow::anyhow!("pipeline engine dropped the batch")),
    }
}

// ---------------------------------------------------------------------------
// Persistent cross-batch engine
// ---------------------------------------------------------------------------

/// Adaptive depth controller knobs (see the module docs). The window is
/// widened while the bottleneck stage's per-batch bubble fraction stays
/// above `widen_bubble_frac`, and narrowed after two consecutive batches
/// below `narrow_bubble_frac` — hysteresis that parks the window within
/// one step of the smallest depth that saturates the bottleneck. Each
/// stage's entry gap (idle before a batch's first micro-batch) is
/// excluded from observations unless the batch's admission was
/// credit-starved: arrival spacing is not credit starvation, but a
/// window that held ready work back is.
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveDepthConfig {
    pub min_depth: usize,
    pub max_depth: usize,
    /// Widen when the bottleneck stage's bubble fraction exceeds this.
    pub widen_bubble_frac: f64,
    /// Narrow (after 2 clean batches) when it stays below this.
    pub narrow_bubble_frac: f64,
    /// Batches to skip after a change so its effect is observed before
    /// the next decision.
    pub cooldown_batches: u32,
}

impl Default for AdaptiveDepthConfig {
    fn default() -> Self {
        AdaptiveDepthConfig {
            min_depth: 1,
            max_depth: 8,
            widen_bubble_frac: 0.10,
            narrow_bubble_frac: 0.02,
            cooldown_batches: 1,
        }
    }
}

/// Configuration for a [`PersistentEngine`].
#[derive(Debug, Clone)]
pub struct PersistentEngineConfig {
    /// Rows per micro-batch (the compiled artifact batch for real
    /// deployments).
    pub micro_batch_rows: usize,
    /// Starting credit window (micro-batches in flight across *all*
    /// batches at once).
    pub initial_depth: usize,
    /// Enable the adaptive depth controller.
    pub adaptive: Option<AdaptiveDepthConfig>,
}

impl Default for PersistentEngineConfig {
    fn default() -> Self {
        PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 4,
            adaptive: None,
        }
    }
}

impl PersistentEngineConfig {
    /// Queue bound: the widest window the controller may reach.
    fn depth_cap(&self) -> usize {
        match &self.adaptive {
            Some(a) => a.max_depth.max(self.initial_depth),
            None => self.initial_depth,
        }
    }
}

/// Snapshot of the adaptive controller's trajectory for reports.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DepthReport {
    pub initial_depth: usize,
    pub final_depth: usize,
    pub min_depth: usize,
    pub max_depth: usize,
    pub widenings: u64,
    pub narrowings: u64,
}

/// A waiter for one submitted batch.
pub struct BatchHandle {
    rx: Receiver<Result<EngineRun>>,
}

impl BatchHandle {
    /// Block until the batch's last micro-batch is delivered (or its
    /// first failure has drained through the pipeline).
    pub fn wait(self) -> Result<EngineRun> {
        match self.rx.recv() {
            Ok(result) => result,
            Err(_) => Err(anyhow::anyhow!(
                "persistent engine shut down before the batch completed"
            )),
        }
    }
}

/// Long-lived streaming engine: per-stage driver threads, a feeder, and
/// a collector that all survive across batches, fed through
/// [`PersistentEngine::submit`]. Successive batches stream back-to-back
/// through the same bounded queues — no inter-batch drain, no thread
/// churn — while the shared [`CriticalPath`] keeps device-honest
/// simulated accounting across batch boundaries. Dropping the engine
/// drains in-flight batches (their [`BatchHandle`]s still complete) and
/// joins every thread.
pub struct PersistentEngine {
    submit_tx: Option<SyncSender<(u64, Vec<Tensor>)>>,
    state: Arc<Mutex<EngineState>>,
    threads: Vec<std::thread::JoinHandle<()>>,
    next_batch: AtomicU64,
    micro_batch_rows: usize,
    node_ids: Vec<usize>,
    depth_stats: Arc<DepthStats>,
}

impl PersistentEngine {
    /// Spawn the engine over an owned stage chain.
    pub fn new<S: StageExec + Send + Sync + 'static>(
        stages: Arc<S>,
        cfg: PersistentEngineConfig,
    ) -> Result<PersistentEngine> {
        Self::new_dyn(stages, cfg)
    }

    /// Type-erased constructor (the engine stores `dyn StageExec`).
    pub fn new_dyn(
        stages: Arc<dyn StageExec + Send + Sync>,
        cfg: PersistentEngineConfig,
    ) -> Result<PersistentEngine> {
        let n_stages = stages.num_stages();
        anyhow::ensure!(n_stages > 0, "engine needs >= 1 stage");
        anyhow::ensure!(cfg.micro_batch_rows > 0, "micro_batch_rows must be > 0");
        anyhow::ensure!(cfg.initial_depth > 0, "initial_depth must be > 0");
        if let Some(a) = &cfg.adaptive {
            anyhow::ensure!(a.min_depth >= 1, "min_depth must be >= 1");
            anyhow::ensure!(
                a.min_depth <= a.max_depth,
                "min_depth {} > max_depth {}",
                a.min_depth,
                a.max_depth
            );
            anyhow::ensure!(
                (a.min_depth..=a.max_depth).contains(&cfg.initial_depth),
                "initial_depth {} outside adaptive range [{}, {}]",
                cfg.initial_depth,
                a.min_depth,
                a.max_depth
            );
            // Thresholds: widen must sit at or above narrow, or the
            // controller oscillates +1/-1 forever in the overlap band;
            // NaN would silently disable both comparisons.
            anyhow::ensure!(
                a.widen_bubble_frac.is_finite()
                    && a.narrow_bubble_frac.is_finite()
                    && a.narrow_bubble_frac >= 0.0
                    && a.widen_bubble_frac >= a.narrow_bubble_frac,
                "bubble thresholds must be finite with widen ({}) >= \
                 narrow ({}) >= 0",
                a.widen_bubble_frac,
                a.narrow_bubble_frac
            );
        }
        let node_ids: Vec<usize> =
            (0..n_stages).map(|k| stages.node_id(k)).collect();
        let state = Arc::new(Mutex::new(EngineState::new(&node_ids)));
        let cap = cfg.depth_cap();

        let mut senders = Vec::with_capacity(n_stages + 1);
        let mut receivers = Vec::with_capacity(n_stages + 1);
        for _ in 0..=n_stages {
            let (tx, rx) = sync_channel::<PFlow>(cap);
            senders.push(tx);
            receivers.push(rx);
        }
        let mut senders = senders.into_iter();
        let mut receivers = receivers.into_iter();
        let feed_tx = senders.next().expect("feeder sender");

        let (credit_tx, credit_rx) = channel::<f64>();
        for _ in 0..cfg.initial_depth {
            let _ = credit_tx.send(0.0);
        }
        let depth_stats = Arc::new(DepthStats::new(cfg.initial_depth));

        let mut threads = Vec::with_capacity(n_stages + 2);
        for k in 0..n_stages {
            let rx = receivers.next().expect("stage receiver");
            let tx = senders.next().expect("stage sender");
            let stages = Arc::clone(&stages);
            let state = Arc::clone(&state);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("pipe-stage-{k}"))
                    .spawn(move || drive_stage(&*stages, k, rx, tx, &state))
                    .context("spawning stage driver")?,
            );
        }
        {
            let collect_rx = receivers.next().expect("collector receiver");
            let stages = Arc::clone(&stages);
            let state = Arc::clone(&state);
            let stats = Arc::clone(&depth_stats);
            let adaptive = cfg.adaptive;
            threads.push(
                std::thread::Builder::new()
                    .name("pipe-collect".into())
                    .spawn(move || {
                        let mut ctrl = DepthCtrl::new(adaptive, stats);
                        collect_loop(&*stages, collect_rx, credit_tx, &state, &mut ctrl);
                    })
                    .context("spawning collector")?,
            );
        }
        let (submit_tx, submit_rx) =
            sync_channel::<(u64, Vec<Tensor>)>(cap.max(4));
        {
            let state = Arc::clone(&state);
            threads.push(
                std::thread::Builder::new()
                    .name("pipe-feed".into())
                    .spawn(move || {
                        while let Ok((id, chunks)) = submit_rx.recv() {
                            if !feed_batch(id, chunks, &credit_rx, &feed_tx, &state) {
                                // The pipeline died under us (panic-driven
                                // cascade): fail this batch and every
                                // submission still reaching the queue so
                                // no waiter hangs on a reply that will
                                // never come. The loop ends only when all
                                // submit senders drop.
                                lock_state(&state).batches.remove(&id);
                                while let Ok((id, _)) = submit_rx.recv() {
                                    lock_state(&state).batches.remove(&id);
                                }
                                break;
                            }
                        }
                        // Dropping feed_tx cascades shutdown through the
                        // stage drivers to the collector.
                    })
                    .context("spawning feeder")?,
            );
        }

        Ok(PersistentEngine {
            submit_tx: Some(submit_tx),
            state,
            threads,
            next_batch: AtomicU64::new(0),
            micro_batch_rows: cfg.micro_batch_rows,
            node_ids,
            depth_stats,
        })
    }

    /// Split `input` into micro-batches and enqueue them behind any
    /// batches already flowing — no drain in between. Returns a
    /// [`BatchHandle`] whose `wait` yields the reassembled, in-order
    /// output (bit-identical to a serial traversal) plus batch-local
    /// timing. Blocks only on submission-queue back-pressure, never on
    /// the batch's execution.
    pub fn submit(&self, input: &Tensor) -> Result<BatchHandle> {
        let chunks = split_rows(input, self.micro_batch_rows)?;
        let id = self.next_batch.fetch_add(1, Ordering::SeqCst);
        let (reply_tx, reply_rx) = channel::<Result<EngineRun>>();
        lock_state(&self.state).register(id, chunks.len(), reply_tx);
        let submit_tx = self.submit_tx.as_ref().expect("engine running");
        if submit_tx.send((id, chunks)).is_err() {
            lock_state(&self.state).batches.remove(&id);
            anyhow::bail!("persistent engine is shut down");
        }
        Ok(BatchHandle { rx: reply_rx })
    }

    /// Submit and wait — the synchronous convenience used by
    /// `DistributedService::infer_batch`.
    pub fn run(&self, input: &Tensor) -> Result<EngineRun> {
        self.submit(input)?.wait()
    }

    pub fn n_stages(&self) -> usize {
        self.node_ids.len()
    }

    /// Node hosting each stage of *this engine's* chain. Callers doing
    /// per-node accounting must use these (not a freshly-read
    /// deployment): during a deployment swap a batch submitted to this
    /// engine still executes on this engine's stages.
    pub fn node_ids(&self) -> &[usize] {
        &self.node_ids
    }

    /// The credit window right now (== the configured depth unless the
    /// adaptive controller moved it).
    pub fn current_depth(&self) -> usize {
        self.depth_stats.current.load(Ordering::SeqCst)
    }

    /// The adaptive controller's trajectory so far.
    pub fn depth_report(&self) -> DepthReport {
        self.depth_stats.report()
    }

    /// Simulated time of the last delivery across *all* batches — the
    /// cross-batch makespan (aggregate throughput = total rows / this).
    pub fn makespan_ms(&self) -> f64 {
        lock_state(&self.state).cp.makespan_ms()
    }

    /// Cumulative per-stage counters across every batch served.
    pub fn total_counters(&self) -> Vec<StageCounter> {
        lock_state(&self.state).cp.counters()
    }
}

impl Drop for PersistentEngine {
    fn drop(&mut self) {
        // Close the submission queue; the feeder drains what was already
        // accepted, then the shutdown cascades stage by stage. In-flight
        // batches complete and their handles resolve before the joins
        // finish.
        drop(self.submit_tx.take());
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn input(rows: usize, cols: usize) -> Tensor {
        let data = (0..rows * cols).map(|i| i as f32 * 0.5 - 3.0).collect();
        Tensor::new(vec![rows, cols], data).unwrap()
    }

    #[test]
    fn split_concat_roundtrip() {
        let t = input(5, 3);
        let chunks = split_rows(&t, 2).unwrap();
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0].shape, vec![2, 3]);
        assert_eq!(chunks[2].shape, vec![1, 3]);
        assert_eq!(concat_rows(&chunks).unwrap(), t);
        assert!(split_rows(&t, 0).is_err());
        assert!(concat_rows(&[]).is_err());
    }

    #[test]
    fn streamed_output_is_bit_identical_to_serial() {
        let stages = SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0);
        let t = input(6, 8);
        let serial = run_serial(&stages, &t, 1).unwrap();
        let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: 4 };
        let streamed = run_streamed(&stages, &t, &cfg).unwrap();
        assert_eq!(serial.output, streamed.output);
        // Also identical to a single full-batch traversal (row-wise ops).
        let whole = run_serial(&stages, &t, 6).unwrap();
        assert_eq!(whole.output, streamed.output);
    }

    #[test]
    fn serial_total_equals_compute_plus_comm() {
        // The ISSUE-1 regression at engine level: a serial single-chunk
        // traversal's simulated total must be the sum of its parts.
        let stages = SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0);
        let t = input(2, 4);
        let run = run_serial(&stages, &t, 2).unwrap();
        let tm = &run.timing;
        assert!(
            (tm.total_ms - (tm.compute_ms + tm.comm_ms)).abs() < 1e-6,
            "total {} vs compute {} + comm {}",
            tm.total_ms, tm.compute_ms, tm.comm_ms
        );
        assert_eq!(tm.stages.len(), 3);
        assert!(tm.compute_ms > 0.0 && tm.comm_ms > 0.0);
    }

    #[test]
    fn streaming_beats_serial_sim_time() {
        let stages = SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0);
        let t = input(6, 4);
        let serial = run_serial(&stages, &t, 1).unwrap();
        let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: 4 };
        let streamed = run_streamed(&stages, &t, &cfg).unwrap();
        assert!(
            streamed.timing.total_ms < serial.timing.total_ms,
            "streamed {:.2} ms must beat serial {:.2} ms",
            streamed.timing.total_ms,
            serial.timing.total_ms
        );
        // Same work was done: compute totals match up to dilation noise
        // (nominal costs are fixed, so they match closely).
        assert!(
            (streamed.timing.compute_ms - serial.timing.compute_ms).abs()
                < 0.25 * serial.timing.compute_ms,
            "compute {} vs {}",
            streamed.timing.compute_ms,
            serial.timing.compute_ms
        );
        // The slowest stage stays busy: its bubble time is small relative
        // to the makespan, and every stage saw every micro-batch.
        for c in &streamed.stage_counters {
            assert_eq!(c.micro_batches, 6);
        }
    }

    #[test]
    fn errors_propagate_with_stage_context() {
        struct Failing;
        impl StageExec for Failing {
            fn num_stages(&self) -> usize {
                2
            }
            fn node_id(&self, stage: usize) -> usize {
                stage
            }
            fn comm_in(&self, _stage: usize, _bytes: u64) -> f64 {
                0.0
            }
            fn comm_out(&self, _bytes: u64) -> f64 {
                0.0
            }
            fn execute(&self, stage: usize, input: Tensor) -> Result<(Tensor, f64)> {
                anyhow::ensure!(stage == 0, "boom at stage {stage}");
                Ok((input, 1.0))
            }
        }
        let t = input(4, 2);
        let cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: 2 };
        let err = run_streamed(&Failing, &t, &cfg).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("stage 1"), "unexpected error: {msg}");
        assert!(run_serial(&Failing, &t, 1).is_err());
    }

    #[test]
    fn window_of_one_reproduces_serial_schedule() {
        // max_in_flight = 1: each micro-batch is admitted only when the
        // previous one is delivered — the streamed makespan must equal
        // the serial one, and wider windows must strictly beat it.
        let stages = SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0);
        let t = input(4, 4);
        let serial = run_serial(&stages, &t, 1).unwrap();
        let w1 = run_streamed(
            &stages,
            &t,
            &EngineConfig { micro_batch_rows: 1, max_in_flight: 1 },
        )
        .unwrap();
        assert!(
            (w1.timing.total_ms - serial.timing.total_ms).abs() < 1e-9,
            "window-1 streamed {} must equal serial {}",
            w1.timing.total_ms,
            serial.timing.total_ms
        );
        let w4 = run_streamed(
            &stages,
            &t,
            &EngineConfig { micro_batch_rows: 1, max_in_flight: 4 },
        )
        .unwrap();
        assert!(
            w4.timing.total_ms < w1.timing.total_ms,
            "window 4 ({}) must beat window 1 ({})",
            w4.timing.total_ms,
            w1.timing.total_ms
        );
        assert_eq!(w1.output, w4.output);
    }

    #[test]
    fn single_stage_single_chunk_degenerates() {
        let stages = SimStages::heterogeneous(&[1.0], 1.0);
        let t = input(2, 2);
        let cfg = EngineConfig { micro_batch_rows: 2, max_in_flight: 1 };
        let run = run_streamed(&stages, &t, &cfg).unwrap();
        assert_eq!(run.output.shape, vec![2, 2]);
        assert_eq!(run.stage_counters.len(), 1);
        assert_eq!(run.stage_counters[0].micro_batches, 1);
        let tm = &run.timing;
        assert!((tm.total_ms - (tm.compute_ms + tm.comm_ms)).abs() < 1e-6);
    }

    fn input_off(rows: usize, cols: usize, off: f32) -> Tensor {
        let data =
            (0..rows * cols).map(|i| i as f32 * 0.5 - 3.0 + off).collect();
        Tensor::new(vec![rows, cols], data).unwrap()
    }

    #[test]
    fn persistent_multi_batch_bit_identical_and_faster_than_per_batch() {
        let stages = Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0));
        let cfg = PersistentEngineConfig {
            micro_batch_rows: 1,
            initial_depth: 4,
            adaptive: None,
        };
        let engine = PersistentEngine::new(Arc::clone(&stages), cfg).unwrap();
        let batches: Vec<Tensor> =
            (0..4).map(|i| input_off(4, 6, i as f32 * 10.0)).collect();
        // Submit everything before waiting: batches stream back-to-back.
        let handles: Vec<BatchHandle> =
            batches.iter().map(|b| engine.submit(b).unwrap()).collect();
        let runs: Vec<EngineRun> =
            handles.into_iter().map(|h| h.wait().unwrap()).collect();
        for (b, r) in batches.iter().zip(&runs) {
            let serial = run_serial(&*stages, b, 1).unwrap();
            assert_eq!(serial.output, r.output, "batch output diverged");
            for c in &r.stage_counters {
                assert_eq!(c.micro_batches, 4);
            }
        }
        // No inter-batch drain: the cross-batch makespan beats the sum of
        // independent per-batch streamed runs (each pays fill + drain).
        let cross = engine.makespan_ms();
        let mut per_batch = 0.0;
        let one_cfg = EngineConfig { micro_batch_rows: 1, max_in_flight: 4 };
        for b in &batches {
            per_batch +=
                run_streamed(&*stages, b, &one_cfg).unwrap().timing.total_ms;
        }
        assert!(
            cross < per_batch,
            "cross-batch {cross:.2} ms must beat per-batch {per_batch:.2} ms"
        );
    }

    #[test]
    fn persistent_single_batch_matches_one_shot_schedule() {
        let t = input(6, 4);
        let one_shot = run_streamed(
            &SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0),
            &t,
            &EngineConfig { micro_batch_rows: 1, max_in_flight: 3 },
        )
        .unwrap();
        let engine = PersistentEngine::new(
            Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0)),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 3,
                adaptive: None,
            },
        )
        .unwrap();
        let run = engine.run(&t).unwrap();
        // Same shared core, same credits: the first persistent batch must
        // reproduce the one-shot schedule exactly, in outputs and sim-ms.
        assert_eq!(run.output, one_shot.output);
        assert!(
            (run.timing.total_ms - one_shot.timing.total_ms).abs() < 1e-9,
            "persistent {} vs one-shot {}",
            run.timing.total_ms,
            one_shot.timing.total_ms
        );
        assert!(
            (run.timing.compute_ms - one_shot.timing.compute_ms).abs() < 1e-9
        );
        assert!((run.timing.comm_ms - one_shot.timing.comm_ms).abs() < 1e-9);
    }

    /// Fails at stage 1 whenever the activation carries the sentinel.
    struct FailOnMark;
    impl StageExec for FailOnMark {
        fn num_stages(&self) -> usize {
            2
        }
        fn node_id(&self, stage: usize) -> usize {
            stage
        }
        fn comm_in(&self, _stage: usize, _bytes: u64) -> f64 {
            0.0
        }
        fn comm_out(&self, _bytes: u64) -> f64 {
            0.0
        }
        fn execute(&self, stage: usize, input: Tensor) -> Result<(Tensor, f64)> {
            anyhow::ensure!(
                !(stage == 1 && input.data[0] == 999.0),
                "sentinel failure"
            );
            Ok((input, 1.0))
        }
    }

    #[test]
    fn persistent_failure_isolated_to_its_batch() {
        let engine = PersistentEngine::new(
            Arc::new(FailOnMark),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 2,
                adaptive: None,
            },
        )
        .unwrap();
        let good = Tensor::new(vec![2, 2], vec![1.0; 4]).unwrap();
        let bad = Tensor::new(vec![2, 2], vec![999.0; 4]).unwrap();
        let h1 = engine.submit(&good).unwrap();
        let h2 = engine.submit(&bad).unwrap();
        let h3 = engine.submit(&good).unwrap();
        let r1 = h1.wait().unwrap();
        assert_eq!(r1.output, good);
        let err = h2.wait().unwrap_err();
        assert!(
            format!("{err:#}").contains("stage 1"),
            "unexpected error: {err:#}"
        );
        // The failure drained without touching the following batch, and
        // counters stay consistent (every stage saw both micro-batches).
        let r3 = h3.wait().unwrap();
        assert_eq!(r3.output, good);
        for c in &r3.stage_counters {
            assert_eq!(c.micro_batches, 2, "stage {} counters", c.stage);
        }
        // Engine still serves after the failure.
        let r4 = engine.run(&good).unwrap();
        assert_eq!(r4.output, good);
    }

    #[test]
    fn queued_batch_reports_service_time_not_queueing() {
        // A wide window hands batch B a stale leftover credit (value 0)
        // while batch A still occupies the pipeline. B's total_ms must
        // measure B's own pass (from its stage-0 service start), not the
        // whole cross-batch makespan.
        let stages = Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0));
        let engine = PersistentEngine::new(
            Arc::clone(&stages),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 8,
                adaptive: None,
            },
        )
        .unwrap();
        let a = input(4, 4);
        let b = input_off(1, 4, 5.0);
        let ha = engine.submit(&a).unwrap();
        let hb = engine.submit(&b).unwrap();
        let ra = ha.wait().unwrap();
        let rb = hb.wait().unwrap();
        assert_eq!(rb.output, run_serial(&*stages, &b, 1).unwrap().output);
        let makespan = engine.makespan_ms();
        assert!(
            rb.timing.total_ms < 0.9 * makespan,
            "queued batch total {:.2} ms should exclude queueing \
             (cross-batch makespan {makespan:.2} ms)",
            rb.timing.total_ms
        );
        assert!(
            rb.timing.total_ms < ra.timing.total_ms,
            "single-micro batch B ({:.2} ms) must report less service \
             time than 4-micro batch A ({:.2} ms)",
            rb.timing.total_ms,
            ra.timing.total_ms
        );
    }

    #[test]
    fn adaptive_depth_widens_until_bottleneck_saturates() {
        let stages = Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0));
        let engine = PersistentEngine::new(
            stages,
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 1,
                adaptive: Some(AdaptiveDepthConfig {
                    max_depth: 6,
                    ..AdaptiveDepthConfig::default()
                }),
            },
        )
        .unwrap();
        let b = input(4, 4);
        let mut handles = Vec::new();
        for _ in 0..12 {
            handles.push(engine.submit(&b).unwrap());
        }
        for h in handles {
            h.wait().unwrap();
        }
        let report = engine.depth_report();
        assert_eq!(report.initial_depth, 1);
        assert!(report.widenings >= 1, "controller never widened: {report:?}");
        let depth = engine.current_depth();
        assert!(
            (2..=6).contains(&depth),
            "depth {depth} did not move off the serial window"
        );
    }

    #[test]
    fn adaptive_depth_ignores_arrival_gaps() {
        // Strictly sequential traffic (each batch waited before the next
        // is submitted): the idle time between batches is arrival
        // spacing, not credit starvation. With the window already wide
        // enough for a whole batch (4 > 3 chunks) the controller must
        // never ratchet it upward chasing those gaps — the entry-gap
        // exclusion means the observed bottleneck bubbles stay ~0.
        let stages = Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], 1.0));
        let engine = PersistentEngine::new(
            stages,
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 4,
                adaptive: Some(AdaptiveDepthConfig {
                    max_depth: 8,
                    ..AdaptiveDepthConfig::default()
                }),
            },
        )
        .unwrap();
        let b = input(3, 4);
        for _ in 0..8 {
            engine.run(&b).unwrap();
        }
        let report = engine.depth_report();
        assert!(
            report.max_depth <= 4,
            "window ratcheted upward on arrival gaps: {report:?}"
        );
        assert!(report.final_depth >= 1 && report.final_depth <= 4);
    }

    #[test]
    fn adaptive_depth_works_with_single_chunk_batches() {
        // pipeline_depth = 1 + adaptive (the bare `--adaptive-depth`
        // serve configuration): every batch is exactly one micro-batch,
        // so there are no intra-batch bubbles at all. Back-to-back
        // submissions starve on credits at depth 1, and those starved
        // entry gaps must still widen the window.
        let stages = Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0));
        let engine = PersistentEngine::new(
            stages,
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 1,
                adaptive: Some(AdaptiveDepthConfig {
                    max_depth: 6,
                    ..AdaptiveDepthConfig::default()
                }),
            },
        )
        .unwrap();
        let b = input(1, 4);
        let mut handles = Vec::new();
        for _ in 0..16 {
            handles.push(engine.submit(&b).unwrap());
        }
        for h in handles {
            h.wait().unwrap();
        }
        let report = engine.depth_report();
        assert!(
            report.widenings >= 1,
            "single-chunk adaptive serving never widened: {report:?}"
        );
        assert!(engine.current_depth() >= 2, "{report:?}");
    }

    #[test]
    fn adaptive_depth_widens_on_sequential_starved_batches() {
        // Solo batches can still carry genuine credit starvation: at
        // window 1 a 4-chunk batch serializes its own micro-batches, and
        // those intra-batch bubbles (entry gap excluded) must widen the
        // window even though the batches never overlap each other.
        let stages = Arc::new(SimStages::heterogeneous(&[1.0, 0.6, 0.4], 2.0));
        let engine = PersistentEngine::new(
            stages,
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 1,
                adaptive: Some(AdaptiveDepthConfig {
                    max_depth: 6,
                    ..AdaptiveDepthConfig::default()
                }),
            },
        )
        .unwrap();
        let b = input(4, 4);
        for _ in 0..8 {
            engine.run(&b).unwrap();
        }
        let report = engine.depth_report();
        assert!(
            report.widenings >= 1,
            "sequential starved batches must still widen: {report:?}"
        );
        assert!(engine.current_depth() >= 2, "{report:?}");
    }

    #[test]
    fn persistent_engine_rejects_bad_configs() {
        let stages = || Arc::new(SimStages::heterogeneous(&[1.0], 1.0));
        assert!(PersistentEngine::new(
            stages(),
            PersistentEngineConfig {
                micro_batch_rows: 0,
                initial_depth: 1,
                adaptive: None
            },
        )
        .is_err());
        assert!(PersistentEngine::new(
            stages(),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 0,
                adaptive: None
            },
        )
        .is_err());
        assert!(PersistentEngine::new(
            stages(),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 9,
                adaptive: Some(AdaptiveDepthConfig {
                    min_depth: 1,
                    max_depth: 8,
                    ..AdaptiveDepthConfig::default()
                }),
            },
        )
        .is_err());
        // Inverted or non-finite bubble thresholds are rejected.
        assert!(PersistentEngine::new(
            stages(),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 1,
                adaptive: Some(AdaptiveDepthConfig {
                    widen_bubble_frac: 0.05,
                    narrow_bubble_frac: 0.20,
                    ..AdaptiveDepthConfig::default()
                }),
            },
        )
        .is_err());
        assert!(PersistentEngine::new(
            stages(),
            PersistentEngineConfig {
                micro_batch_rows: 1,
                initial_depth: 1,
                adaptive: Some(AdaptiveDepthConfig {
                    widen_bubble_frac: f64::NAN,
                    ..AdaptiveDepthConfig::default()
                }),
            },
        )
        .is_err());
    }
}
