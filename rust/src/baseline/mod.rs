//! Monolithic baseline — the paper's comparator in Table I.
//!
//! The whole model runs as a single AOT artifact on a single node (the
//! paper used one container with 2 cores / 2 GB). No partitioning, no
//! scheduling, no pipelining: requests execute strictly serially on the
//! one device, which is why its throughput flatlines while AMP4EC
//! overlaps stages across nodes.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::cluster::VirtualNode;
use crate::manifest::Manifest;
use crate::router::InferenceService;
use crate::runtime::{BlockHandle, Executor, Tensor};

/// The paper's baseline node: 2 cores, 2 GB. We model "2 cores" as full
/// host speed (cpu_fraction 1.0 is the no-dilation ceiling), which is
/// *generous* to the baseline — AMP4EC's reported wins survive it.
pub fn baseline_node_spec() -> crate::cluster::NodeSpec {
    crate::cluster::NodeSpec::new("monolithic", 1.0, 2048.0)
}

/// Whole-model service on one virtual node with its own executor.
pub struct MonolithicService {
    node: Arc<VirtualNode>,
    executor: Arc<Executor>,
    block: BlockHandle,
    batch: usize,
    in_shape: Vec<usize>,
}

impl MonolithicService {
    /// Load the monolithic artifact at `batch` and pin it to `node`.
    pub fn new(
        manifest: &Manifest,
        node: Arc<VirtualNode>,
        batch: usize,
    ) -> Result<MonolithicService> {
        let mono = manifest
            .monolithic
            .as_ref()
            .context("manifest has no monolithic artifact")?;
        let hlo = mono
            .artifacts
            .get(&batch)
            .with_context(|| format!("no monolithic artifact for batch {batch}"))?;
        let executor = Arc::new(Executor::spawn(node.name())?);
        let block = executor.load_block(
            manifest.dir.join(hlo),
            manifest.dir.join(&mono.weights_file),
            manifest.total_params as usize,
            vec![batch, manifest.num_classes],
        )?;
        // Model transfer to the node + memory reservation.
        node.link().receive(mono.weights_bytes);
        node.mem_reserve(mono.weights_bytes);
        Ok(MonolithicService {
            node,
            executor,
            block,
            batch,
            in_shape: vec![batch, manifest.input_hw, manifest.input_hw,
                           manifest.input_channels],
        })
    }

    pub fn node(&self) -> &Arc<VirtualNode> {
        &self.node
    }

    pub fn input_shape(&self) -> &[usize] {
        &self.in_shape
    }
}

impl InferenceService for MonolithicService {
    fn infer_batch(&self, batch: &Tensor) -> Result<(Tensor, f64, f64)> {
        anyhow::ensure!(
            batch.shape == self.in_shape,
            "expected input {:?}, got {:?}",
            self.in_shape,
            batch.shape
        );
        // Input/output still traverse the node's link (clients are remote).
        let comm_in = self.node.link().receive(batch.byte_len());
        let executor = &self.executor;
        let block = self.block;
        let input = batch.clone();
        let (out, outcome) = self
            .node
            .execute_costed(move || executor.run_chain(vec![block], input))?;
        let comm_out = self.node.link().send(out.byte_len());
        Ok((out, outcome.sim_ms, comm_in + comm_out))
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn model_id(&self) -> u64 {
        0xBA5E
    }
}

// PJRT-backed tests live in rust/tests/ (need artifacts).
