//! Workload generation: deterministic synthetic inference requests.
//!
//! The paper drives each configuration with batches of 32 identical-sized
//! inference requests over MobileNetV2. We generate seeded N(0,1) image
//! tensors from a bounded *pool* of distinct inputs — the pool size
//! controls the result-cache hit rate (paper's +Cache rows), and closed-
//! vs open-loop arrival controls queueing behaviour.

use std::sync::mpsc::SyncSender;
use std::time::{Duration, Instant};

use crate::router::Request;
use crate::runtime::Tensor;
use crate::util::rng::Rng;

/// A reusable pool of distinct input tensors.
pub struct InputPool {
    inputs: Vec<Tensor>,
}

impl InputPool {
    /// `distinct` tensors of `shape`, deterministically seeded.
    pub fn new(shape: &[usize], distinct: usize, seed: u64) -> InputPool {
        assert!(distinct > 0);
        let mut rng = Rng::new(seed);
        let inputs = (0..distinct)
            .map(|_| {
                let mut t = Tensor::zeros(shape.to_vec());
                rng.fill_normal_f32(&mut t.data);
                t
            })
            .collect();
        InputPool { inputs }
    }

    pub fn len(&self) -> usize {
        self.inputs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inputs.is_empty()
    }

    pub fn get(&self, i: usize) -> &Tensor {
        &self.inputs[i % self.inputs.len()]
    }
}

/// Arrival process for open-loop workloads.
#[derive(Debug, Clone, Copy)]
pub enum Arrival {
    /// Send everything as fast as the bounded queue accepts (closed loop).
    Closed,
    /// Poisson arrivals with the given mean rate (requests/second).
    Poisson { rate_rps: f64 },
}

/// Feed `n` requests drawn round-robin from `pool` into the router channel.
/// Returns the number of requests sent. Blocks on a full queue
/// (backpressure).
pub fn feed(
    tx: &SyncSender<Request>,
    pool: &InputPool,
    n: usize,
    arrival: Arrival,
    seed: u64,
) -> usize {
    let mut rng = Rng::new(seed);
    let mut sent = 0;
    for i in 0..n {
        if let Arrival::Poisson { rate_rps } = arrival {
            let gap_s = rng.exp(1.0 / rate_rps.max(1e-9));
            std::thread::sleep(Duration::from_secs_f64(gap_s));
        }
        let req = Request {
            id: i as u64,
            input: pool.get(i).clone(),
            enqueued: Instant::now(),
        };
        if tx.send(req).is_err() {
            break; // router gone
        }
        sent += 1;
    }
    sent
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::request_channel;

    #[test]
    fn pool_is_deterministic_and_distinct() {
        let a = InputPool::new(&[1, 4], 3, 9);
        let b = InputPool::new(&[1, 4], 3, 9);
        for i in 0..3 {
            assert_eq!(a.get(i).data, b.get(i).data);
        }
        assert_ne!(a.get(0).data, a.get(1).data);
        // Round-robin wraps.
        assert_eq!(a.get(0).data, a.get(3).data);
    }

    #[test]
    fn feed_closed_loop_sends_all() {
        let pool = InputPool::new(&[1, 2], 2, 1);
        let (tx, rx) = request_channel(64);
        let sent = feed(&tx, &pool, 10, Arrival::Closed, 2);
        assert_eq!(sent, 10);
        drop(tx);
        assert_eq!(rx.iter().count(), 10);
    }

    #[test]
    fn feed_poisson_spaces_arrivals() {
        let pool = InputPool::new(&[1, 2], 1, 1);
        let (tx, rx) = request_channel(64);
        let t0 = Instant::now();
        feed(&tx, &pool, 5, Arrival::Poisson { rate_rps: 1000.0 }, 3);
        let elapsed = t0.elapsed();
        assert!(elapsed.as_micros() > 500, "arrivals too fast");
        drop(tx);
        assert_eq!(rx.iter().count(), 5);
    }
}
