//! Workload generation: deterministic synthetic inference requests.
//!
//! The paper drives each configuration with batches of 32 identical-sized
//! inference requests over MobileNetV2. We generate seeded N(0,1) image
//! tensors from a bounded *pool* of distinct inputs — the pool size
//! controls the result-cache hit rate (paper's +Cache rows), and closed-
//! vs open-loop arrival controls queueing behaviour. Requests enter
//! through the unified serving ingress ([`ServiceHandle`]) like every
//! other entry point; [`feed_with`] lets a workload mix priority
//! classes and deadlines per request.

use std::time::Duration;

use crate::runtime::Tensor;
use crate::serving::{Priority, ServiceHandle};
use crate::util::rng::Rng;

/// A reusable pool of distinct input tensors.
pub struct InputPool {
    inputs: Vec<Tensor>,
}

impl InputPool {
    /// `distinct` tensors of `shape`, deterministically seeded.
    pub fn new(shape: &[usize], distinct: usize, seed: u64) -> InputPool {
        assert!(distinct > 0);
        let mut rng = Rng::new(seed);
        let n: usize = shape.iter().product();
        let inputs = (0..distinct)
            .map(|_| {
                let mut data = vec![0.0f32; n];
                rng.fill_normal_f32(&mut data);
                Tensor::new(shape.to_vec(), data).expect("pool tensor")
            })
            .collect();
        InputPool { inputs }
    }

    pub fn len(&self) -> usize {
        self.inputs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inputs.is_empty()
    }

    pub fn get(&self, i: usize) -> &Tensor {
        &self.inputs[i % self.inputs.len()]
    }
}

/// Arrival process for open-loop workloads.
#[derive(Debug, Clone, Copy)]
pub enum Arrival {
    /// Send everything as fast as the bounded queue accepts (closed loop).
    Closed,
    /// Poisson arrivals with the given mean rate (requests/second).
    Poisson { rate_rps: f64 },
    /// On-off (MMPP-style) bursts: Poisson at `burst_rps` for `on_ms`,
    /// then at `base_rps` for `off_ms`, repeating. `base_rps` may be 0
    /// (silent between bursts). This is the flooding-tenant shape the
    /// multitenant bench uses to expose scheduler fairness.
    Bursty {
        base_rps: f64,
        burst_rps: f64,
        on_ms: f64,
        off_ms: f64,
    },
    /// A diurnal rate envelope: Poisson whose rate follows a cosine
    /// between `peak_rps` (at phase 0) and `trough_rps` (at half
    /// period) over `period_ms` — a whole "day" compressed into one
    /// run.
    Diurnal {
        peak_rps: f64,
        trough_rps: f64,
        period_ms: f64,
    },
}

impl Arrival {
    /// Instantaneous arrival rate (requests/second) at virtual time
    /// `t_ms` into the run; `None` for the closed loop. The feeders
    /// draw one exponential gap per request from this rate, so
    /// `Poisson` consumes the seeded RNG exactly as it always has.
    pub fn rate_at(&self, t_ms: f64) -> Option<f64> {
        match *self {
            Arrival::Closed => None,
            Arrival::Poisson { rate_rps } => Some(rate_rps),
            Arrival::Bursty { base_rps, burst_rps, on_ms, off_ms } => {
                let period = (on_ms + off_ms).max(1e-9);
                let phase = t_ms.rem_euclid(period);
                Some(if phase < on_ms { burst_rps } else { base_rps })
            }
            Arrival::Diurnal { peak_rps, trough_rps, period_ms } => {
                let period = period_ms.max(1e-9);
                let phase = t_ms.rem_euclid(period) / period;
                let mid = (peak_rps + trough_rps) / 2.0;
                let amp = (peak_rps - trough_rps) / 2.0;
                Some(mid + amp * (phase * std::f64::consts::TAU).cos())
            }
        }
    }
}

/// Per-request serving context a workload assigns: priority class, the
/// submitting tenant, plus an optional relative deadline.
/// [`RequestSpec::default`] is plain default-class tenant-0 no-deadline
/// traffic.
#[derive(Debug, Clone, Copy, Default)]
pub struct RequestSpec {
    pub priority: Priority,
    pub deadline: Option<Duration>,
    pub tenant: usize,
}

impl RequestSpec {
    pub fn new(priority: Priority) -> RequestSpec {
        RequestSpec { priority, deadline: None, tenant: 0 }
    }

    pub fn with_deadline(mut self, d: Duration) -> RequestSpec {
        self.deadline = Some(d);
        self
    }

    pub fn with_tenant(mut self, tenant: usize) -> RequestSpec {
        self.tenant = tenant;
        self
    }
}

/// Feed `n` default-class requests drawn round-robin from `pool` into
/// the serving ingress. Returns the number of requests submitted.
/// Blocks on a full ingress queue (backpressure). Outcomes are recorded
/// in the handle's metrics; call `handle.finish()` to collect them.
pub fn feed(
    handle: &ServiceHandle,
    pool: &InputPool,
    n: usize,
    arrival: Arrival,
    seed: u64,
) -> usize {
    feed_with(handle, pool, n, arrival, seed, |_| RequestSpec::default())
}

/// [`feed`] with a per-request spec: `spec(i)` assigns the `i`-th
/// request's priority class and optional deadline — how mixed-
/// criticality workloads (latency-critical traffic over a best-effort
/// flood) are expressed.
pub fn feed_with(
    handle: &ServiceHandle,
    pool: &InputPool,
    n: usize,
    arrival: Arrival,
    seed: u64,
    mut spec: impl FnMut(usize) -> RequestSpec,
) -> usize {
    let mut rng = Rng::new(seed);
    let mut sent = 0;
    // Virtual time drives the time-varying envelopes: it advances by the
    // drawn gaps, not wall clock, so the process is deterministic under
    // a seeded RNG even when submission itself blocks on backpressure.
    let mut t_ms = 0.0;
    for i in 0..n {
        if let Some(rate) = arrival.rate_at(t_ms) {
            let gap_s = rng.exp(1.0 / rate.max(1e-9));
            t_ms += gap_s * 1e3;
            std::thread::sleep(Duration::from_secs_f64(gap_s));
        }
        let s = spec(i);
        let mut req = handle
            .request(pool.get(i).clone())
            .priority(s.priority)
            .tenant(s.tenant);
        if let Some(d) = s.deadline {
            req = req.deadline(d);
        }
        if req.submit().is_err() {
            break; // ingress shut down
        }
        sent += 1;
    }
    sent
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Instant;

    use crate::router::InferenceService;
    use crate::serving::IngressConfig;

    #[test]
    fn pool_is_deterministic_and_distinct() {
        let a = InputPool::new(&[1, 4], 3, 9);
        let b = InputPool::new(&[1, 4], 3, 9);
        for i in 0..3 {
            assert_eq!(a.get(i).data(), b.get(i).data());
        }
        assert_ne!(a.get(0).data(), a.get(1).data());
        // Round-robin wraps.
        assert_eq!(a.get(0).data(), a.get(3).data());
    }

    /// Identity service: output = input, fixed batch of 4.
    struct Echo;
    impl InferenceService for Echo {
        fn infer_batch(
            &self,
            batch: &Tensor,
        ) -> anyhow::Result<(Tensor, f64, f64)> {
            Ok((batch.clone(), 0.0, 0.0))
        }
        fn batch_size(&self) -> usize {
            4
        }
        fn model_id(&self) -> u64 {
            11
        }
    }

    fn handle() -> ServiceHandle {
        ServiceHandle::new(Arc::new(Echo), IngressConfig::default(), None)
    }

    #[test]
    fn feed_closed_loop_sends_all() {
        let pool = InputPool::new(&[1, 2], 2, 1);
        let h = handle();
        let sent = feed(&h, &pool, 10, Arrival::Closed, 2);
        assert_eq!(sent, 10);
        let m = h.finish();
        assert_eq!(m.completed, 10);
        assert_eq!(m.failed, 0);
    }

    #[test]
    fn feed_poisson_spaces_arrivals() {
        let pool = InputPool::new(&[1, 2], 1, 1);
        let h = handle();
        let t0 = Instant::now();
        let sent = feed(&h, &pool, 5, Arrival::Poisson { rate_rps: 1000.0 }, 3);
        let elapsed = t0.elapsed();
        assert!(elapsed.as_micros() > 500, "arrivals too fast");
        assert_eq!(sent, 5);
        let m = h.finish();
        assert_eq!(m.completed, 5);
    }

    #[test]
    fn bursty_and_diurnal_rate_envelopes() {
        let b = Arrival::Bursty {
            base_rps: 10.0,
            burst_rps: 1000.0,
            on_ms: 50.0,
            off_ms: 150.0,
        };
        assert_eq!(b.rate_at(0.0), Some(1000.0));
        assert_eq!(b.rate_at(49.0), Some(1000.0));
        assert_eq!(b.rate_at(60.0), Some(10.0));
        // Periodic: one full cycle later, back in the burst.
        assert_eq!(b.rate_at(210.0), Some(1000.0));

        let d = Arrival::Diurnal {
            peak_rps: 100.0,
            trough_rps: 20.0,
            period_ms: 1000.0,
        };
        assert!((d.rate_at(0.0).unwrap() - 100.0).abs() < 1e-9);
        assert!((d.rate_at(500.0).unwrap() - 20.0).abs() < 1e-9);
        // Quarter period sits at the midpoint of the envelope.
        assert!((d.rate_at(250.0).unwrap() - 60.0).abs() < 1e-9);
        assert!((d.rate_at(1000.0).unwrap() - 100.0).abs() < 1e-9);

        // Closed loop has no rate; Poisson's is constant.
        assert_eq!(Arrival::Closed.rate_at(123.0), None);
        assert_eq!(
            Arrival::Poisson { rate_rps: 5.0 }.rate_at(9.9),
            Some(5.0)
        );
    }

    #[test]
    fn feed_bursty_completes_and_tags_tenants() {
        let pool = InputPool::new(&[1, 2], 2, 1);
        let h = handle();
        let sent = feed_with(
            &h,
            &pool,
            6,
            Arrival::Bursty {
                base_rps: 0.0,
                burst_rps: 2000.0,
                on_ms: 5.0,
                off_ms: 0.0,
            },
            7,
            |i| RequestSpec::default().with_tenant(i % 2),
        );
        assert_eq!(sent, 6);
        let m = h.finish();
        assert_eq!(m.completed, 6);
        // No weight table on the handle: every request clamps to the
        // single implicit tenant.
        assert_eq!(m.tenant_completed(0), 6);
    }

    #[test]
    fn feed_with_assigns_classes_and_deadlines() {
        let pool = InputPool::new(&[1, 2], 4, 1);
        let h = handle();
        feed_with(&h, &pool, 8, Arrival::Closed, 4, |i| {
            if i % 2 == 0 {
                RequestSpec::new(Priority::HIGH)
                    .with_deadline(Duration::from_secs(30))
            } else {
                RequestSpec::new(Priority::BEST_EFFORT)
            }
        });
        let m = h.finish();
        assert_eq!(m.completed, 8);
        let hi = m.class(Priority::HIGH.class()).expect("high class");
        assert_eq!(hi.completed, 4);
        assert_eq!(hi.deadline_total, 4);
        let be = m
            .class(Priority::BEST_EFFORT.class())
            .expect("best-effort class");
        assert_eq!(be.completed, 4);
        assert_eq!(be.deadline_total, 0);
    }
}
