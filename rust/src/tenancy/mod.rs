//! Multi-tenant serving primitives (ISSUE 9).
//!
//! Two small, independently testable pieces the serving stack composes:
//!
//! * [`DrrScheduler`] — deficit-weighted round-robin across tenant
//!   queues. The ingress keeps strict-priority dequeue across classes
//!   and runs DRR across tenants *within* a class, so a flooding tenant
//!   is capped near its configured weight share instead of starving
//!   everyone behind it. A zero-weight tenant still gets a small quantum
//!   floor ([`MIN_QUANTUM`]) — deprioritized, never starved.
//! * [`ModelRegistry`] — the named co-deployment table behind
//!   `EdgeServer::deploy_model` / `undeploy_model`: models packed onto
//!   one shared cluster, each entry healed and rebalanced independently.
//!
//! Degeneracy guarantee: with a single tenant (or none configured) the
//! ingress bypasses DRR entirely — within-class order is the plain FIFO
//! the PR-8 path used, bit for bit.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

/// The tenant every request belongs to unless it says otherwise — also
/// the only tenant that exists when no weight table is configured.
pub const DEFAULT_TENANT: usize = 0;

/// Quantum floor as a fraction of the heaviest tenant's quantum: a
/// zero-weight tenant accrues at least this much credit per round, so
/// it is served at most ~`1/MIN_QUANTUM` rounds apart while backlogged
/// (deprioritized, never starved).
pub const MIN_QUANTUM: f64 = 0.05;

/// Named tenants and their WFQ weights — the config-level table the
/// CLI resolves `name=weight` pairs into and the ingress consumes as a
/// bare weight vector (tenant id = index).
#[derive(Debug, Clone, Default)]
pub struct TenantTable {
    names: Vec<String>,
    weights: Vec<f64>,
}

impl TenantTable {
    pub fn new(names: Vec<String>, weights: Vec<f64>) -> Result<TenantTable> {
        anyhow::ensure!(
            names.len() == weights.len(),
            "tenant table needs one weight per name ({} != {})",
            names.len(),
            weights.len()
        );
        Ok(TenantTable { names, weights })
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// True when WFQ would change nothing: zero or one tenant. The
    /// ingress uses this to stay on the plain-FIFO fast path.
    pub fn is_trivial(&self) -> bool {
        self.names.len() <= 1
    }

    /// Tenant id for `name` (ids are table indices).
    pub fn resolve(&self, name: &str) -> Option<usize> {
        self.names.iter().position(|n| n == name)
    }

    pub fn name(&self, tenant: usize) -> Option<&str> {
        self.names.get(tenant).map(String::as_str)
    }

    pub fn weight(&self, tenant: usize) -> f64 {
        self.weights.get(tenant).copied().unwrap_or(0.0)
    }

    pub fn weights(&self) -> &[f64] {
        &self.weights
    }
}

/// Deficit-weighted round-robin picker over `n` tenant queues.
///
/// Each tenant's quantum is its weight normalized by the heaviest
/// weight, floored at [`MIN_QUANTUM`]. A round visits tenants in index
/// order; a visited tenant accrues its quantum and is served once per
/// whole unit of deficit. Serving does not advance the cursor, so a
/// tenant with accumulated deficit may take consecutive slots (bounded
/// by `1 + quantum` — DRR's usual per-round burst). A tenant whose
/// queue has drained loses its deficit: credit never accumulates while
/// there is nothing to spend it on, which is what keeps long-idle
/// tenants from bursting unboundedly when they return.
#[derive(Debug)]
pub struct DrrScheduler {
    quanta: Vec<f64>,
    deficit: Vec<f64>,
    cursor: usize,
    /// Whether the tenant at `cursor` received its quantum for the
    /// current visit (a visit may span several `pick` calls while the
    /// tenant spends banked deficit; it must be refilled exactly once).
    refilled: bool,
}

impl DrrScheduler {
    pub fn new(weights: &[f64]) -> DrrScheduler {
        let max = weights.iter().cloned().fold(0.0_f64, f64::max);
        let quanta: Vec<f64> = if max > 0.0 && max.is_finite() {
            weights.iter().map(|w| (w / max).max(MIN_QUANTUM)).collect()
        } else {
            // All-zero (or empty) weights: plain round-robin.
            vec![1.0; weights.len()]
        };
        DrrScheduler {
            deficit: vec![0.0; quanta.len()],
            quanta,
            cursor: 0,
            refilled: false,
        }
    }

    pub fn n(&self) -> usize {
        self.quanta.len()
    }

    fn advance(&mut self) {
        self.cursor = (self.cursor + 1) % self.quanta.len();
        self.refilled = false;
    }

    /// Pick the next tenant to serve, given each tenant's current queue
    /// length. Returns `None` only when every queue is empty. Bounded:
    /// with the [`MIN_QUANTUM`] floor every backlogged tenant crosses a
    /// whole unit of deficit within `ceil(1 / MIN_QUANTUM)` rounds.
    pub fn pick(&mut self, len_of: impl Fn(usize) -> usize) -> Option<usize> {
        let n = self.quanta.len();
        if n == 0 || (0..n).all(|t| len_of(t) == 0) {
            return None;
        }
        let rounds = (1.0 / MIN_QUANTUM).ceil() as usize + 1;
        for _ in 0..n * rounds {
            let t = self.cursor;
            if len_of(t) == 0 {
                self.deficit[t] = 0.0;
                self.advance();
                continue;
            }
            if !self.refilled {
                self.deficit[t] += self.quanta[t];
                self.refilled = true;
            }
            if self.deficit[t] >= 1.0 {
                self.deficit[t] -= 1.0;
                return Some(t);
            }
            self.advance();
        }
        // Unreachable with the floor in place; serve somebody anyway.
        (0..n).find(|&t| len_of(t) > 0)
    }
}

/// Named co-deployment registry: the table of models currently sharing
/// one cluster. Thread-safe; entries are `Arc`s so a deployment stays
/// usable while being removed from the table (in-flight requests drain
/// against the entry, not the registry).
pub struct ModelRegistry<T> {
    entries: Mutex<BTreeMap<String, Arc<T>>>,
}

impl<T> Default for ModelRegistry<T> {
    fn default() -> Self {
        ModelRegistry { entries: Mutex::new(BTreeMap::new()) }
    }
}

impl<T> ModelRegistry<T> {
    pub fn new() -> ModelRegistry<T> {
        ModelRegistry::default()
    }

    /// Register a deployment under `name`. A duplicate name is an error
    /// — silently replacing a live deployment would leak its node
    /// memory reservations.
    pub fn insert(&self, name: &str, entry: Arc<T>) -> Result<()> {
        let mut map = self.entries.lock().unwrap();
        anyhow::ensure!(
            !map.contains_key(name),
            "model '{name}' is already deployed (undeploy it first)"
        );
        map.insert(name.to_string(), entry);
        Ok(())
    }

    /// Remove and return the entry for `name` (callers release its
    /// cluster resources).
    pub fn remove(&self, name: &str) -> Option<Arc<T>> {
        self.entries.lock().unwrap().remove(name)
    }

    pub fn get(&self, name: &str) -> Option<Arc<T>> {
        self.entries.lock().unwrap().get(name).cloned()
    }

    /// Snapshot of every (name, entry) pair, name-ordered — the heal
    /// watchdog walks this without holding the registry lock across
    /// heals.
    pub fn entries(&self) -> Vec<(String, Arc<T>)> {
        self.entries
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), Arc::clone(v)))
            .collect()
    }

    pub fn names(&self) -> Vec<String> {
        self.entries.lock().unwrap().keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.lock().unwrap().is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serve `total` picks from always-backlogged queues and count per
    /// tenant.
    fn shares(weights: &[f64], total: usize) -> Vec<usize> {
        let mut drr = DrrScheduler::new(weights);
        let mut counts = vec![0usize; weights.len()];
        for _ in 0..total {
            let t = drr.pick(|_| 1).expect("backlogged queues always serve");
            counts[t] += 1;
        }
        counts
    }

    #[test]
    fn drr_shares_track_weights() {
        let counts = shares(&[3.0, 1.0], 400);
        let share0 = counts[0] as f64 / 400.0;
        assert!(
            (share0 - 0.75).abs() < 0.1,
            "tenant 0 share {share0} far from weight share 0.75"
        );
    }

    #[test]
    fn drr_three_way_shares() {
        let counts = shares(&[2.0, 1.0, 1.0], 800);
        for (t, want) in [(0, 0.5), (1, 0.25), (2, 0.25)] {
            let got = counts[t] as f64 / 800.0;
            assert!(
                (got - want).abs() < 0.1,
                "tenant {t} share {got} far from {want}"
            );
        }
    }

    #[test]
    fn zero_weight_tenant_never_starves() {
        let counts = shares(&[1.0, 0.0], 200);
        assert!(counts[1] >= 1, "zero-weight tenant starved: {counts:?}");
        // ... but stays near the quantum floor, not an equal share.
        assert!(
            counts[1] <= 30,
            "zero-weight tenant got {} of 200 picks",
            counts[1]
        );
    }

    #[test]
    fn empty_queues_return_none_and_reset_deficit() {
        let mut drr = DrrScheduler::new(&[1.0, 1.0]);
        assert_eq!(drr.pick(|_| 0), None);
        // A tenant that drained loses its banked credit: serve tenant 0
        // alone for a while, then bring tenant 1 back — it must not
        // burst ahead of its weight share.
        for _ in 0..50 {
            assert_eq!(drr.pick(|t| usize::from(t == 0)), Some(0));
        }
        let mut one = 0;
        for _ in 0..20 {
            if drr.pick(|_| 1) == Some(1) {
                one += 1;
            }
        }
        assert!((8..=12).contains(&one), "equal weights drifted: {one}");
    }

    #[test]
    fn single_tenant_is_plain_fifo_order() {
        let mut drr = DrrScheduler::new(&[1.0]);
        for _ in 0..10 {
            assert_eq!(drr.pick(|_| 3), Some(0));
        }
    }

    #[test]
    fn tenant_table_resolves_names() {
        let t = TenantTable::new(
            vec!["gold".into(), "free".into()],
            vec![3.0, 1.0],
        )
        .unwrap();
        assert_eq!(t.resolve("free"), Some(1));
        assert_eq!(t.resolve("nobody"), None);
        assert_eq!(t.weight(0), 3.0);
        assert!(!t.is_trivial());
        assert!(TenantTable::default().is_trivial());
        assert!(TenantTable::new(vec!["a".into()], vec![]).is_err());
    }

    #[test]
    fn registry_rejects_duplicates_and_removes() {
        let reg: ModelRegistry<u32> = ModelRegistry::new();
        reg.insert("m1", Arc::new(1)).unwrap();
        assert!(reg.insert("m1", Arc::new(2)).is_err());
        reg.insert("m0", Arc::new(0)).unwrap();
        assert_eq!(reg.names(), vec!["m0".to_string(), "m1".to_string()]);
        assert_eq!(*reg.get("m1").unwrap(), 1);
        assert_eq!(reg.remove("m1").map(|e| *e), Some(1));
        assert!(reg.get("m1").is_none());
        assert_eq!(reg.len(), 1);
    }
}
