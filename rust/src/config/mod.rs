//! Typed configuration system: cluster topology, scheduler weights,
//! partitioner/batcher/cache settings — with JSON load/save and presets
//! for every experiment in the paper's evaluation.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::cluster::{LinkSpec, NodeSpec, Profile, SimParams};
use crate::scheduler::ScoringWeights;
use crate::transport::{AgentAddr, TransportKind};
use crate::util::json::Json;

/// One node's configuration (mirrors the paper's Docker resource flags).
#[derive(Debug, Clone)]
pub struct NodeConfig {
    pub name: String,
    pub cpu: f64,
    pub mem_mb: f64,
    pub link_latency_ms: f64,
    pub link_bandwidth_mbps: f64,
    pub fail_rate: f64,
}

impl NodeConfig {
    pub fn new(name: &str, cpu: f64, mem_mb: f64) -> NodeConfig {
        NodeConfig {
            name: name.to_string(),
            cpu,
            mem_mb,
            link_latency_ms: 1.0,
            link_bandwidth_mbps: 1000.0,
            fail_rate: 0.0,
        }
    }

    pub fn to_spec(&self) -> NodeSpec {
        NodeSpec::new(&self.name, self.cpu, self.mem_mb)
            .with_link(LinkSpec::new(self.link_latency_ms, self.link_bandwidth_mbps))
            .with_fail_rate(self.fail_rate)
    }
}

/// One serving tenant: a name (what requests and CLI flags refer to)
/// and a WFQ weight (its share of each priority lane's capacity,
/// relative to the other tenants' weights). Zero weight is legal —
/// the tenant is deprioritized to the DRR quantum floor, never starved.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantConfig {
    pub name: String,
    pub weight: f64,
}

impl TenantConfig {
    pub fn new(name: &str, weight: f64) -> TenantConfig {
        TenantConfig { name: name.to_string(), weight }
    }

    /// Parse a CLI tenant list: `name=weight,name=weight,...`.
    pub fn parse_list(s: &str) -> Result<Vec<TenantConfig>> {
        s.split(',')
            .filter(|p| !p.trim().is_empty())
            .map(|pair| {
                let (name, w) = pair.split_once('=').ok_or_else(|| {
                    anyhow::anyhow!(
                        "tenant `{pair}` is not name=weight (e.g. \
                         --tenants gold=3,free=1)"
                    )
                })?;
                let weight: f64 = w.trim().parse().map_err(|_| {
                    anyhow::anyhow!("tenant `{pair}` has a non-numeric weight")
                })?;
                Ok(TenantConfig::new(name.trim(), weight))
            })
            .collect()
    }
}

/// Stage replication policy (scale-out): how many data-parallel copies
/// of hot stages the deployer may place. Extras are distributed
/// bottleneck-first over per-stage partition costs
/// (`partitioner::replica_counts`) and placed on fresh nodes by the
/// scheduler's replica-set extension. CLI: `--replicas auto|k`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaPolicy {
    /// No replication — every stage runs one copy (the default; the
    /// engine degenerates bit-exactly to the single-chain schedule).
    Off,
    /// Use every spare online node that can afford a replica.
    Auto,
    /// Distribute `k - 1` extra replicas bottleneck-first (so the
    /// hottest stage runs up to `k` copies). Always >= 2: `1` parses
    /// to [`ReplicaPolicy::Off`].
    Fixed(usize),
}

impl ReplicaPolicy {
    pub fn parse(s: &str) -> Result<ReplicaPolicy> {
        match s.trim() {
            "auto" => Ok(ReplicaPolicy::Auto),
            "off" => Ok(ReplicaPolicy::Off),
            n => {
                let k: usize = n.parse().map_err(|_| {
                    anyhow::anyhow!(
                        "replicas expects `auto`, `off`, or a count >= 1, \
                         got `{s}`"
                    )
                })?;
                anyhow::ensure!(k >= 1, "replica count must be >= 1, got {k}");
                Ok(if k == 1 {
                    ReplicaPolicy::Off
                } else {
                    ReplicaPolicy::Fixed(k)
                })
            }
        }
    }

    /// Extra replicas to distribute bottleneck-first, given `spare`
    /// currently-unused placeable nodes.
    pub fn extra_budget(&self, spare: usize) -> usize {
        match self {
            ReplicaPolicy::Off => 0,
            ReplicaPolicy::Auto => spare,
            ReplicaPolicy::Fixed(k) => k.saturating_sub(1),
        }
    }

    pub fn is_off(&self) -> bool {
        matches!(self, ReplicaPolicy::Off)
    }
}

/// Full framework configuration.
#[derive(Debug, Clone)]
pub struct AmpConfig {
    /// Where `manifest.json` and artifacts live.
    pub artifacts_dir: PathBuf,
    /// Batch size to deploy (must exist in the manifest's batch_sizes).
    pub batch: usize,
    /// Edge nodes.
    pub nodes: Vec<NodeConfig>,
    /// Partitions; None = one per online node.
    pub num_partitions: Option<usize>,
    /// Capability-weighted partitioning (proportional to node CPU) instead
    /// of the paper's equal-target split.
    pub weighted_partitioning: bool,
    /// Profile-guided partitioning: calibrate per-block execution time at
    /// startup and balance partitions on measured cost x node CPU share
    /// (paper §V "automate partition optimization"). Overrides
    /// `weighted_partitioning`.
    pub profiled_partitioning: bool,
    /// Scheduler scoring weights (paper defaults).
    pub weights: ScoringWeights,
    pub overload_threshold: f64,
    pub latency_threshold_ms: f64,
    /// Serving ingress: batch admission window (how long the dispatcher
    /// waits to fill a batch).
    pub max_wait_ms: u64,
    /// Serving ingress: concurrent batches in flight.
    pub workers: usize,
    /// Serving ingress: number of priority classes (strict-priority
    /// lanes; requests clamp to `priority_classes - 1`). CLI:
    /// `--priority-classes`.
    pub priority_classes: usize,
    /// Serving ingress: deadline (ms) applied to requests that don't
    /// set their own; requests that cannot meet it are shed instead of
    /// served late. None = no default deadline. CLI: `--deadline-ms`.
    pub default_deadline_ms: Option<f64>,
    /// Serving ingress: named tenants with WFQ weights. Within each
    /// priority class the ingress serves tenants deficit-weighted
    /// round-robin by these weights; a flooding tenant is capped near
    /// its weight share. Empty (the default) or a single entry means
    /// one implicit tenant and plain FIFO within each class — the
    /// pre-multitenant behavior, bit for bit. CLI:
    /// `--tenants name=weight,...`.
    pub tenants: Vec<TenantConfig>,
    /// Streaming pipeline engine: micro-batches kept in flight per
    /// admitted batch. 1 = serial `pipeline::run`; >1 makes the router
    /// admit `batch * pipeline_depth`-row super-batches that the
    /// persistent `pipeline::engine` streams across the stage nodes as
    /// `pipeline_depth` micro-batches of the compiled `batch` rows each,
    /// back-to-back across successive super-batches (no inter-batch
    /// drain).
    pub pipeline_depth: usize,
    /// Adaptive pipeline depth: let the engine's controller widen/narrow
    /// the in-flight window online from observed per-stage bubble time,
    /// starting at `pipeline_depth` and bounded by `max_pipeline_depth`.
    pub adaptive_depth: bool,
    /// Upper bound for the adaptive controller's window (ignored unless
    /// `adaptive_depth`; effective bound is
    /// `max(pipeline_depth, max_pipeline_depth)`).
    pub max_pipeline_depth: usize,
    /// Per-stage credit windows: the engine's admission window becomes
    /// one bounded credit budget per stage and the adaptive controller
    /// resizes them independently, so a slow middle stage grows the
    /// windows gating its supply instead of inflating the whole chain.
    /// Off = uniform budgets, which behave exactly like the single
    /// global window. On rebalance the learned budgets carry into the
    /// rebuilt engine. CLI: `--stage-windows`.
    pub per_stage_windows: bool,
    /// Batch coalescing: the engine feeder merges adjacent small
    /// miss-sets into shared micro-batches when that reduces the
    /// micro-batch count; results are re-split per batch at delivery.
    /// Also relaxes miss padding to exact row counts (short tails pack
    /// together instead of being padded). CLI: `--coalesce`.
    pub coalesce: bool,
    /// Stage replication (scale-out): place data-parallel copies of hot
    /// stages on spare nodes and spray micro-batches across them.
    /// Forces the persistent engine on (replicas only exist there).
    /// CLI: `--replicas auto|k`.
    pub replicas: ReplicaPolicy,
    /// Result-cache entries; None disables (plain AMP4EC).
    pub cache_entries: Option<usize>,
    /// Model/deployment cache across redeployments (+Cache bandwidth=0).
    pub model_cache: bool,
    /// Stage transport: `inproc` (default — stages run in this
    /// process), `uds`, or `tcp` (stages run in `amp4ec node` agents
    /// listed in `agents`). CLI: `--transport`.
    pub transport: TransportKind,
    /// Node-agent addresses for uds/tcp transports (socket paths or
    /// host:port; stages are assigned round-robin when there are fewer
    /// agents than stages). CLI: `--agents a,b,...`.
    pub agents: Vec<String>,
    /// Simulation parameters.
    pub time_scale: f64,
    pub page_factor: f64,
    pub runtime_overhead_mb: f64,
    /// Monitor sampling interval.
    pub monitor_interval_ms: u64,
    /// Consecutive missed monitor samples before a node is declared
    /// dead (liveness detection latency = `miss_threshold *
    /// monitor_interval_ms`). CLI: `--miss-threshold`.
    pub miss_threshold: u32,
    /// Self-healing serving: watch the monitor's liveness feed and heal
    /// on node death — re-place the dead replica's stage when every
    /// affected stage keeps a surviving replica, full re-partition
    /// otherwise — and let in-flight micro-batches replay through
    /// surviving replicas instead of failing the batch. Off = today's
    /// fail-fast behavior. CLI: `--heal`.
    pub heal: bool,
    /// Per-execute round-trip deadline on wire transports, ms: a
    /// replica that does not answer an Execute within this budget is
    /// marked suspect (its connection is failed so that micro-batch can
    /// replay/heal) instead of hanging the driver. `None` = wait
    /// forever (the pre-ISSUE-10 behavior). CLI: `--wire-timeout-ms`.
    pub wire_execute_timeout_ms: Option<f64>,
    /// Straggler hedging (ISSUE 10): re-issue a micro-batch on a
    /// surviving sibling replica when the primary runs past the
    /// stage's armed latency threshold; first completion wins. Off =
    /// bit-identical unhedged execution. CLI: `--hedge`.
    pub hedge: bool,
}

impl Default for AmpConfig {
    fn default() -> Self {
        AmpConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            batch: 1,
            nodes: vec![
                NodeConfig::new("edge-high", 1.0, 1024.0),
                NodeConfig::new("edge-med", 0.6, 512.0),
                NodeConfig::new("edge-low", 0.4, 512.0),
            ],
            num_partitions: None,
            weighted_partitioning: false,
            profiled_partitioning: false,
            weights: ScoringWeights::default(),
            overload_threshold: 0.8,
            latency_threshold_ms: 100.0,
            max_wait_ms: 10,
            workers: 4,
            priority_classes: 3,
            default_deadline_ms: None,
            tenants: Vec::new(),
            pipeline_depth: 1,
            adaptive_depth: false,
            max_pipeline_depth: 8,
            per_stage_windows: false,
            coalesce: false,
            replicas: ReplicaPolicy::Off,
            cache_entries: None,
            model_cache: false,
            transport: TransportKind::Inproc,
            agents: Vec::new(),
            time_scale: 1.0,
            page_factor: 4.0,
            runtime_overhead_mb: 384.0,
            monitor_interval_ms: 100,
            miss_threshold: 3,
            heal: false,
            wire_execute_timeout_ms: None,
            hedge: false,
        }
    }
}

impl AmpConfig {
    // ---- presets for the paper's experiments -------------------------

    /// §IV-B heterogeneous cluster: 1.0/1GB, 0.6/512MB, 0.4/512MB.
    pub fn paper_cluster(artifacts_dir: &Path) -> AmpConfig {
        AmpConfig {
            artifacts_dir: artifacts_dir.to_path_buf(),
            ..AmpConfig::default()
        }
    }

    /// §IV-B AMP4EC+Cache: result cache + warm model cache.
    pub fn paper_cluster_cached(artifacts_dir: &Path) -> AmpConfig {
        AmpConfig {
            cache_entries: Some(256),
            model_cache: true,
            ..AmpConfig::paper_cluster(artifacts_dir)
        }
    }

    /// Streaming variant of the paper cluster: the pipeline engine keeps
    /// `depth` micro-batches in flight across the partition chain.
    pub fn paper_cluster_streamed(artifacts_dir: &Path, depth: usize) -> AmpConfig {
        AmpConfig {
            pipeline_depth: depth.max(1),
            ..AmpConfig::paper_cluster(artifacts_dir)
        }
    }

    /// Adaptive streaming variant: the persistent engine starts at
    /// `pipeline_depth` and sizes its in-flight window online from
    /// observed per-stage bubble time, up to `max_depth`.
    pub fn paper_cluster_adaptive(artifacts_dir: &Path, max_depth: usize) -> AmpConfig {
        AmpConfig {
            adaptive_depth: true,
            max_pipeline_depth: max_depth.max(1),
            ..AmpConfig::paper_cluster(artifacts_dir)
        }
    }

    /// §IV-C/Table II single-profile cluster of `n` identical nodes.
    pub fn profile_cluster(artifacts_dir: &Path, profile: Profile, n: usize) -> AmpConfig {
        let spec = profile.spec();
        AmpConfig {
            artifacts_dir: artifacts_dir.to_path_buf(),
            nodes: (0..n)
                .map(|i| {
                    NodeConfig::new(
                        &format!("{}-{i}", profile.name().to_lowercase()),
                        spec.cpu_fraction,
                        spec.mem_limit_mb,
                    )
                })
                .collect(),
            ..AmpConfig::default()
        }
    }

    pub fn sim_params(&self) -> SimParams {
        SimParams {
            time_scale: self.time_scale,
            page_factor: self.page_factor,
            runtime_overhead_mb: self.runtime_overhead_mb,
        }
    }

    /// Parsed agent addresses (empty for the in-process transport).
    pub fn agent_addrs(&self) -> Result<Vec<AgentAddr>> {
        if self.transport == TransportKind::Inproc {
            return Ok(Vec::new());
        }
        self.agents
            .iter()
            .map(|a| AgentAddr::parse(self.transport, a))
            .collect()
    }

    /// The serving ingress configuration (replaces the old
    /// `router_config`): admission window and worker pool carry over,
    /// plus the request-level knobs — priority-lane count and the
    /// default per-request deadline.
    pub fn ingress_config(&self) -> crate::serving::IngressConfig {
        crate::serving::IngressConfig {
            capacity: 256,
            max_wait: Duration::from_millis(self.max_wait_ms),
            workers: self.workers,
            classes: self.priority_classes.max(1),
            default_deadline: self
                .default_deadline_ms
                .map(|ms| Duration::from_secs_f64(ms.max(0.0) / 1e3)),
            tenant_weights: self.tenant_weights(),
        }
    }

    /// The tenant WFQ weight vector (tenant id = index into `tenants`).
    /// Empty when no tenants are configured — the ingress then runs one
    /// implicit tenant with plain FIFO lanes.
    pub fn tenant_weights(&self) -> Vec<f64> {
        self.tenants.iter().map(|t| t.weight).collect()
    }

    /// Named tenant table for resolving request tenant names to ids.
    pub fn tenant_table(&self) -> crate::tenancy::TenantTable {
        crate::tenancy::TenantTable::new(
            self.tenants.iter().map(|t| t.name.clone()).collect(),
            self.tenant_weights(),
        )
        .expect("names and weights come from the same vec")
    }

    pub fn monitor_config(&self) -> crate::monitor::MonitorConfig {
        crate::monitor::MonitorConfig {
            sample_interval: Duration::from_millis(self.monitor_interval_ms),
            history_len: 4096,
            miss_threshold: self.miss_threshold.max(1),
        }
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.nodes.is_empty(), "config needs >= 1 node");
        anyhow::ensure!(self.batch >= 1, "batch must be >= 1");
        anyhow::ensure!(self.workers >= 1, "workers must be >= 1");
        anyhow::ensure!(
            self.priority_classes >= 1,
            "priority_classes must be >= 1"
        );
        if let Some(ms) = self.default_deadline_ms {
            anyhow::ensure!(
                ms.is_finite() && ms > 0.0,
                "default_deadline_ms must be a positive number"
            );
        }
        if !self.tenants.is_empty() {
            let mut seen = std::collections::HashSet::new();
            for t in &self.tenants {
                anyhow::ensure!(
                    !t.name.trim().is_empty(),
                    "tenant names must be non-empty"
                );
                anyhow::ensure!(
                    seen.insert(t.name.as_str()),
                    "duplicate tenant name '{}'",
                    t.name
                );
                anyhow::ensure!(
                    t.weight.is_finite() && t.weight >= 0.0,
                    "tenant '{}' weight must be a finite number >= 0, \
                     got {}",
                    t.name,
                    t.weight
                );
            }
            anyhow::ensure!(
                self.tenants.iter().map(|t| t.weight).sum::<f64>() > 0.0,
                "tenant weights must not all be zero (no share to divide)"
            );
        }
        anyhow::ensure!(self.pipeline_depth >= 1, "pipeline_depth must be >= 1");
        anyhow::ensure!(
            self.max_pipeline_depth >= 1,
            "max_pipeline_depth must be >= 1"
        );
        anyhow::ensure!(self.time_scale > 0.0, "time_scale must be > 0");
        anyhow::ensure!(
            self.miss_threshold >= 1,
            "miss_threshold must be >= 1 (misses before a node is dead)"
        );
        if let Some(t) = self.wire_execute_timeout_ms {
            anyhow::ensure!(
                t.is_finite() && t > 0.0,
                "wire_execute_timeout_ms = {t} must be a positive number \
                 of milliseconds (drop the key to wait forever)"
            );
        }
        if let ReplicaPolicy::Fixed(k) = self.replicas {
            anyhow::ensure!(
                k >= 2,
                "replicas = {k} is not a replicated configuration; use \
                 `off` (or drop the key) for single-copy stages"
            );
        }
        match self.transport {
            TransportKind::Inproc => anyhow::ensure!(
                self.agents.is_empty(),
                "transport `inproc` takes no agent addresses; drop `agents` \
                 or set the transport to uds/tcp"
            ),
            kind => {
                anyhow::ensure!(
                    !self.agents.is_empty(),
                    "transport `{kind}` needs at least one agent address, \
                     e.g. agents = [{}]",
                    if kind == TransportKind::Uds {
                        "\"/tmp/amp4ec-a.sock\""
                    } else {
                        "\"127.0.0.1:7070\""
                    }
                );
                for a in &self.agents {
                    AgentAddr::parse(kind, a)?;
                }
            }
        }
        self.weights.validate()?;
        for n in &self.nodes {
            n.to_spec().validate()?;
        }
        if let Some(p) = self.num_partitions {
            anyhow::ensure!(p >= 1, "num_partitions must be >= 1");
        }
        Ok(())
    }

    // ---- JSON persistence --------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert(
            "artifacts_dir".into(),
            Json::Str(self.artifacts_dir.display().to_string()),
        );
        m.insert("batch".into(), Json::from(self.batch));
        m.insert(
            "nodes".into(),
            Json::Arr(
                self.nodes
                    .iter()
                    .map(|n| {
                        let mut nm = BTreeMap::new();
                        nm.insert("name".into(), Json::from(n.name.as_str()));
                        nm.insert("cpu".into(), Json::Num(n.cpu));
                        nm.insert("mem_mb".into(), Json::Num(n.mem_mb));
                        nm.insert("link_latency_ms".into(), Json::Num(n.link_latency_ms));
                        nm.insert(
                            "link_bandwidth_mbps".into(),
                            Json::Num(n.link_bandwidth_mbps),
                        );
                        nm.insert("fail_rate".into(), Json::Num(n.fail_rate));
                        Json::Obj(nm)
                    })
                    .collect(),
            ),
        );
        if let Some(p) = self.num_partitions {
            m.insert("num_partitions".into(), Json::from(p));
        }
        m.insert(
            "weighted_partitioning".into(),
            Json::from(self.weighted_partitioning),
        );
        m.insert(
            "profiled_partitioning".into(),
            Json::from(self.profiled_partitioning),
        );
        let mut w = BTreeMap::new();
        w.insert("resource".into(), Json::Num(self.weights.resource));
        w.insert("load".into(), Json::Num(self.weights.load));
        w.insert("performance".into(), Json::Num(self.weights.performance));
        w.insert("balance".into(), Json::Num(self.weights.balance));
        m.insert("weights".into(), Json::Obj(w));
        m.insert("overload_threshold".into(), Json::Num(self.overload_threshold));
        m.insert(
            "latency_threshold_ms".into(),
            Json::Num(self.latency_threshold_ms),
        );
        m.insert("max_wait_ms".into(), Json::from(self.max_wait_ms as usize));
        m.insert("workers".into(), Json::from(self.workers));
        m.insert(
            "priority_classes".into(),
            Json::from(self.priority_classes),
        );
        if let Some(ms) = self.default_deadline_ms {
            m.insert("default_deadline_ms".into(), Json::Num(ms));
        }
        if !self.tenants.is_empty() {
            m.insert(
                "tenants".into(),
                Json::Arr(
                    self.tenants
                        .iter()
                        .map(|t| {
                            let mut tm = BTreeMap::new();
                            tm.insert("name".into(), Json::from(t.name.as_str()));
                            tm.insert("weight".into(), Json::Num(t.weight));
                            Json::Obj(tm)
                        })
                        .collect(),
                ),
            );
        }
        m.insert("pipeline_depth".into(), Json::from(self.pipeline_depth));
        m.insert("adaptive_depth".into(), Json::from(self.adaptive_depth));
        m.insert(
            "max_pipeline_depth".into(),
            Json::from(self.max_pipeline_depth),
        );
        m.insert(
            "per_stage_windows".into(),
            Json::from(self.per_stage_windows),
        );
        m.insert("coalesce".into(), Json::from(self.coalesce));
        match self.replicas {
            ReplicaPolicy::Off => {}
            ReplicaPolicy::Auto => {
                m.insert("replicas".into(), Json::Str("auto".into()));
            }
            ReplicaPolicy::Fixed(k) => {
                m.insert("replicas".into(), Json::from(k));
            }
        }
        if let Some(c) = self.cache_entries {
            m.insert("cache_entries".into(), Json::from(c));
        }
        m.insert("model_cache".into(), Json::from(self.model_cache));
        m.insert("transport".into(), Json::Str(self.transport.name().to_string()));
        if !self.agents.is_empty() {
            m.insert(
                "agents".into(),
                Json::Arr(
                    self.agents
                        .iter()
                        .map(|a| Json::Str(a.clone()))
                        .collect(),
                ),
            );
        }
        m.insert("time_scale".into(), Json::Num(self.time_scale));
        m.insert("page_factor".into(), Json::Num(self.page_factor));
        m.insert(
            "runtime_overhead_mb".into(),
            Json::Num(self.runtime_overhead_mb),
        );
        m.insert(
            "monitor_interval_ms".into(),
            Json::from(self.monitor_interval_ms as usize),
        );
        m.insert(
            "miss_threshold".into(),
            Json::from(self.miss_threshold as usize),
        );
        m.insert("heal".into(), Json::from(self.heal));
        if let Some(t) = self.wire_execute_timeout_ms {
            m.insert("wire_execute_timeout_ms".into(), Json::Num(t));
        }
        m.insert("hedge".into(), Json::from(self.hedge));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<AmpConfig> {
        let d = AmpConfig::default();
        let nodes = match j.get("nodes") {
            Some(Json::Arr(arr)) => arr
                .iter()
                .map(|nj| {
                    Ok(NodeConfig {
                        name: nj.req_str("name")?.to_string(),
                        cpu: nj.req_f64("cpu")?,
                        mem_mb: nj.req_f64("mem_mb")?,
                        link_latency_ms: nj
                            .get("link_latency_ms")
                            .and_then(Json::as_f64)
                            .unwrap_or(1.0),
                        link_bandwidth_mbps: nj
                            .get("link_bandwidth_mbps")
                            .and_then(Json::as_f64)
                            .unwrap_or(1000.0),
                        fail_rate: nj
                            .get("fail_rate")
                            .and_then(Json::as_f64)
                            .unwrap_or(0.0),
                    })
                })
                .collect::<Result<Vec<_>>>()?,
            _ => d.nodes.clone(),
        };
        let weights = match j.get("weights") {
            Some(w) => ScoringWeights {
                resource: w.req_f64("resource")?,
                load: w.req_f64("load")?,
                performance: w.req_f64("performance")?,
                balance: w.req_f64("balance")?,
            },
            None => d.weights,
        };
        let get_f = |key: &str, dv: f64| j.get(key).and_then(Json::as_f64).unwrap_or(dv);
        let get_u = |key: &str, dv: usize| j.get(key).and_then(Json::as_usize).unwrap_or(dv);
        let cfg = AmpConfig {
            artifacts_dir: j
                .get("artifacts_dir")
                .and_then(Json::as_str)
                .map(PathBuf::from)
                .unwrap_or(d.artifacts_dir),
            batch: get_u("batch", d.batch),
            nodes,
            num_partitions: j.get("num_partitions").and_then(Json::as_usize),
            weighted_partitioning: j
                .get("weighted_partitioning")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            profiled_partitioning: j
                .get("profiled_partitioning")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            weights,
            overload_threshold: get_f("overload_threshold", d.overload_threshold),
            latency_threshold_ms: get_f("latency_threshold_ms", d.latency_threshold_ms),
            max_wait_ms: get_u("max_wait_ms", d.max_wait_ms as usize) as u64,
            workers: get_u("workers", d.workers),
            priority_classes: get_u("priority_classes", d.priority_classes),
            default_deadline_ms: j
                .get("default_deadline_ms")
                .and_then(Json::as_f64),
            tenants: match j.get("tenants") {
                Some(Json::Arr(arr)) => arr
                    .iter()
                    .map(|tj| {
                        Ok(TenantConfig {
                            name: tj.req_str("name")?.to_string(),
                            weight: tj.req_f64("weight")?,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?,
                Some(_) => anyhow::bail!(
                    "`tenants` must be an array of {{name, weight}} objects"
                ),
                None => Vec::new(),
            },
            pipeline_depth: get_u("pipeline_depth", d.pipeline_depth),
            adaptive_depth: j
                .get("adaptive_depth")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            max_pipeline_depth: get_u("max_pipeline_depth", d.max_pipeline_depth),
            per_stage_windows: j
                .get("per_stage_windows")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            coalesce: j.get("coalesce").and_then(Json::as_bool).unwrap_or(false),
            replicas: match j.get("replicas") {
                None => ReplicaPolicy::Off,
                Some(Json::Str(s)) => ReplicaPolicy::parse(s)?,
                Some(v) => match v.as_usize() {
                    Some(k) => ReplicaPolicy::parse(&k.to_string())?,
                    None => anyhow::bail!(
                        "`replicas` must be `auto`, `off`, or a count"
                    ),
                },
            },
            cache_entries: j.get("cache_entries").and_then(Json::as_usize),
            model_cache: j.get("model_cache").and_then(Json::as_bool).unwrap_or(false),
            transport: match j.get("transport").and_then(Json::as_str) {
                Some(s) => TransportKind::parse(s)?,
                None => d.transport,
            },
            agents: match j.get("agents") {
                Some(Json::Arr(arr)) => arr
                    .iter()
                    .map(|a| {
                        a.as_str().map(str::to_string).ok_or_else(|| {
                            anyhow::anyhow!("`agents` entries must be strings")
                        })
                    })
                    .collect::<Result<Vec<_>>>()?,
                Some(_) => anyhow::bail!("`agents` must be an array of strings"),
                None => Vec::new(),
            },
            time_scale: get_f("time_scale", d.time_scale),
            page_factor: get_f("page_factor", d.page_factor),
            runtime_overhead_mb: get_f("runtime_overhead_mb", d.runtime_overhead_mb),
            monitor_interval_ms: get_u(
                "monitor_interval_ms",
                d.monitor_interval_ms as usize,
            ) as u64,
            miss_threshold: get_u("miss_threshold", d.miss_threshold as usize)
                as u32,
            heal: j.get("heal").and_then(Json::as_bool).unwrap_or(false),
            wire_execute_timeout_ms: j
                .get("wire_execute_timeout_ms")
                .and_then(Json::as_f64),
            hedge: j.get("hedge").and_then(Json::as_bool).unwrap_or(false),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<AmpConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_cluster() {
        let c = AmpConfig::default();
        c.validate().unwrap();
        assert_eq!(c.nodes.len(), 3);
        assert_eq!(c.nodes[0].cpu, 1.0);
        assert_eq!(c.nodes[2].cpu, 0.4);
        assert_eq!(c.weights, ScoringWeights::default());
    }

    #[test]
    fn json_roundtrip() {
        let mut c = AmpConfig::default();
        c.batch = 8;
        c.cache_entries = Some(128);
        c.model_cache = true;
        c.num_partitions = Some(3);
        c.weighted_partitioning = true;
        c.pipeline_depth = 4;
        c.adaptive_depth = true;
        c.max_pipeline_depth = 12;
        c.per_stage_windows = true;
        c.coalesce = true;
        c.priority_classes = 4;
        c.default_deadline_ms = Some(250.0);
        c.heal = true;
        c.miss_threshold = 5;
        c.wire_execute_timeout_ms = Some(750.0);
        c.hedge = true;
        let j = c.to_json();
        let back = AmpConfig::from_json(&j).unwrap();
        assert!(back.heal);
        assert_eq!(back.wire_execute_timeout_ms, Some(750.0));
        assert!(back.hedge);
        assert_eq!(back.miss_threshold, 5);
        assert_eq!(back.priority_classes, 4);
        assert_eq!(back.default_deadline_ms, Some(250.0));
        assert_eq!(back.batch, 8);
        assert_eq!(back.pipeline_depth, 4);
        assert!(back.adaptive_depth);
        assert_eq!(back.max_pipeline_depth, 12);
        assert!(back.per_stage_windows);
        assert!(back.coalesce);
        assert_eq!(back.cache_entries, Some(128));
        assert!(back.model_cache);
        assert_eq!(back.num_partitions, Some(3));
        assert!(back.weighted_partitioning);
        assert_eq!(back.nodes.len(), 3);
        assert_eq!(back.weights, c.weights);
    }

    #[test]
    fn save_load_file() {
        let dir = std::env::temp_dir().join("amp4ec_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        let c = AmpConfig::paper_cluster_cached(Path::new("artifacts"));
        c.save(&p).unwrap();
        let back = AmpConfig::load(&p).unwrap();
        assert_eq!(back.cache_entries, Some(256));
        assert!(back.model_cache);
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut c = AmpConfig::default();
        c.nodes.clear();
        assert!(c.validate().is_err());
        let mut c = AmpConfig::default();
        c.batch = 0;
        assert!(c.validate().is_err());
        let mut c = AmpConfig::default();
        c.weights.balance = 0.9;
        assert!(c.validate().is_err());
        let mut c = AmpConfig::default();
        c.nodes[0].cpu = -1.0;
        assert!(c.validate().is_err());
        let mut c = AmpConfig::default();
        c.pipeline_depth = 0;
        assert!(c.validate().is_err());
        let mut c = AmpConfig::default();
        c.max_pipeline_depth = 0;
        assert!(c.validate().is_err());
        let mut c = AmpConfig::default();
        c.priority_classes = 0;
        assert!(c.validate().is_err());
        let mut c = AmpConfig::default();
        c.default_deadline_ms = Some(-5.0);
        assert!(c.validate().is_err());
        let mut c = AmpConfig::default();
        c.miss_threshold = 0;
        assert!(c.validate().is_err());
        let mut c = AmpConfig::default();
        c.wire_execute_timeout_ms = Some(0.0);
        assert!(c.validate().is_err());
        let mut c = AmpConfig::default();
        c.wire_execute_timeout_ms = Some(f64::NAN);
        assert!(c.validate().is_err());
    }

    #[test]
    fn monitor_config_carries_miss_threshold() {
        let mut c = AmpConfig::default();
        c.miss_threshold = 7;
        assert_eq!(c.monitor_config().miss_threshold, 7);
        // Defaults stay fail-fast: healing is opt-in.
        assert!(!AmpConfig::default().heal);
    }

    #[test]
    fn transport_validation_is_actionable() {
        // inproc + agents listed: contradictory.
        let mut c = AmpConfig::default();
        c.agents = vec!["/tmp/a.sock".to_string()];
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("takes no agent addresses"), "{err}");
        // tcp with no agents: tells you what to add.
        let mut c = AmpConfig::default();
        c.transport = TransportKind::Tcp;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("at least one agent address"), "{err}");
        assert!(err.contains("127.0.0.1:7070"), "{err}");
        // tcp with a port-less address: names the offender.
        c.agents = vec!["localhost".to_string()];
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("host:port"), "{err}");
        // Valid uds and tcp configs pass.
        let mut c = AmpConfig::default();
        c.transport = TransportKind::Uds;
        c.agents = vec!["/tmp/a.sock".to_string(), "/tmp/b.sock".to_string()];
        c.validate().unwrap();
        assert_eq!(c.agent_addrs().unwrap().len(), 2);
        let mut c = AmpConfig::default();
        c.transport = TransportKind::Tcp;
        c.agents = vec!["127.0.0.1:7070".to_string()];
        c.validate().unwrap();
    }

    #[test]
    fn transport_json_roundtrip() {
        let mut c = AmpConfig::default();
        c.transport = TransportKind::Uds;
        c.agents = vec!["/tmp/a.sock".to_string(), "/tmp/b.sock".to_string()];
        let back = AmpConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.transport, TransportKind::Uds);
        assert_eq!(back.agents, c.agents);
        // Default round-trips as inproc with no agents key.
        let d = AmpConfig::default();
        let j = d.to_json();
        assert!(j.get("agents").is_none());
        let back = AmpConfig::from_json(&j).unwrap();
        assert_eq!(back.transport, TransportKind::Inproc);
        assert!(back.agents.is_empty());
        // Unknown transport strings and non-string agents are rejected
        // at parse time (from_json validates).
        let mut m = match d.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        m.insert("transport".into(), Json::Str("pigeon".into()));
        assert!(AmpConfig::from_json(&Json::Obj(m.clone())).is_err());
        m.insert("transport".into(), Json::Str("tcp".into()));
        m.insert("agents".into(), Json::Arr(vec![Json::Num(1.0)]));
        assert!(AmpConfig::from_json(&Json::Obj(m)).is_err());
    }

    #[test]
    fn replica_policy_parses_and_roundtrips() {
        assert_eq!(ReplicaPolicy::parse("auto").unwrap(), ReplicaPolicy::Auto);
        assert_eq!(ReplicaPolicy::parse("off").unwrap(), ReplicaPolicy::Off);
        // k=1 normalizes to Off — the degenerate single-copy plan.
        assert_eq!(ReplicaPolicy::parse("1").unwrap(), ReplicaPolicy::Off);
        assert_eq!(
            ReplicaPolicy::parse("4").unwrap(),
            ReplicaPolicy::Fixed(4)
        );
        assert!(ReplicaPolicy::parse("0").is_err());
        assert!(ReplicaPolicy::parse("many").is_err());
        assert_eq!(ReplicaPolicy::Auto.extra_budget(3), 3);
        assert_eq!(ReplicaPolicy::Fixed(4).extra_budget(99), 3);
        assert_eq!(ReplicaPolicy::Off.extra_budget(99), 0);

        // JSON: Off omits the key; auto/k round-trip.
        let d = AmpConfig::default();
        assert!(d.to_json().get("replicas").is_none());
        let mut c = AmpConfig::default();
        c.replicas = ReplicaPolicy::Auto;
        let back = AmpConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.replicas, ReplicaPolicy::Auto);
        c.replicas = ReplicaPolicy::Fixed(3);
        let back = AmpConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.replicas, ReplicaPolicy::Fixed(3));
        // Fixed(1) is rejected by validation (parse never produces it).
        c.replicas = ReplicaPolicy::Fixed(1);
        assert!(c.validate().is_err());
    }

    #[test]
    fn ingress_config_carries_request_knobs() {
        let mut c = AmpConfig::default();
        c.priority_classes = 2;
        c.default_deadline_ms = Some(100.0);
        let ing = c.ingress_config();
        assert_eq!(ing.classes, 2);
        assert_eq!(ing.workers, c.workers);
        assert_eq!(ing.max_wait, Duration::from_millis(c.max_wait_ms));
        assert_eq!(ing.default_deadline, Some(Duration::from_millis(100)));
        c.default_deadline_ms = None;
        assert_eq!(c.ingress_config().default_deadline, None);
    }

    #[test]
    fn tenant_config_roundtrips_and_validates() {
        // Default: no tenants key, empty weights, trivial table.
        let d = AmpConfig::default();
        assert!(d.to_json().get("tenants").is_none());
        assert!(d.tenant_weights().is_empty());
        assert!(d.tenant_table().is_trivial());

        let mut c = AmpConfig::default();
        c.tenants = vec![
            TenantConfig::new("gold", 3.0),
            TenantConfig::new("free", 1.0),
        ];
        c.validate().unwrap();
        let back = AmpConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.tenants, c.tenants);
        assert_eq!(back.tenant_weights(), vec![3.0, 1.0]);
        assert_eq!(back.tenant_table().resolve("free"), Some(1));
        assert_eq!(back.ingress_config().tenant_weights, vec![3.0, 1.0]);

        // Rejections: empty name, duplicate, negative / non-finite /
        // all-zero weights.
        let mut c = AmpConfig::default();
        c.tenants = vec![TenantConfig::new("", 1.0)];
        assert!(c.validate().is_err());
        c.tenants = vec![
            TenantConfig::new("a", 1.0),
            TenantConfig::new("a", 2.0),
        ];
        assert!(c.validate().is_err());
        c.tenants = vec![TenantConfig::new("a", -1.0)];
        assert!(c.validate().is_err());
        c.tenants = vec![TenantConfig::new("a", f64::NAN)];
        assert!(c.validate().is_err());
        c.tenants = vec![
            TenantConfig::new("a", 0.0),
            TenantConfig::new("b", 0.0),
        ];
        assert!(c.validate().is_err());
        // Zero weight is fine as long as someone has a share.
        c.tenants = vec![
            TenantConfig::new("a", 1.0),
            TenantConfig::new("b", 0.0),
        ];
        c.validate().unwrap();
    }

    #[test]
    fn tenant_cli_list_parses() {
        let ts = TenantConfig::parse_list("gold=3,free=1").unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0], TenantConfig::new("gold", 3.0));
        assert_eq!(ts[1], TenantConfig::new("free", 1.0));
        assert!(TenantConfig::parse_list("gold").is_err());
        assert!(TenantConfig::parse_list("gold=shiny").is_err());
        assert!(TenantConfig::parse_list("").unwrap().is_empty());
    }

    #[test]
    fn adaptive_preset_sets_bounds() {
        let c = AmpConfig::paper_cluster_adaptive(Path::new("a"), 16);
        assert!(c.adaptive_depth);
        assert_eq!(c.max_pipeline_depth, 16);
        c.validate().unwrap();
    }

    #[test]
    fn streamed_preset_sets_depth() {
        let c = AmpConfig::paper_cluster_streamed(Path::new("a"), 4);
        assert_eq!(c.pipeline_depth, 4);
        c.validate().unwrap();
        assert_eq!(AmpConfig::paper_cluster_streamed(Path::new("a"), 0).pipeline_depth, 1);
    }

    #[test]
    fn profile_cluster_preset() {
        let c = AmpConfig::profile_cluster(Path::new("a"), Profile::Low, 3);
        assert_eq!(c.nodes.len(), 3);
        assert!(c.nodes.iter().all(|n| n.cpu == 0.4 && n.mem_mb == 512.0));
    }
}
